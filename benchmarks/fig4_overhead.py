"""Fig. 4 / App. D.3 reproduction: runtime overhead of the DTR machinery.

Two measurements:
  1. metadata accesses per run for h_dtr vs h_dtr_eq vs h_dtr_local (the
     1-3 orders-of-magnitude separation of App. D.3);
  2. wall-clock planner cost: the trace-time DTR plan for a real JAX model
     (the "milliseconds, not ILP-minutes" claim of Sec. 4.3), plus the
     E.2 search optimizations (small-tensor filter, √n sampling).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.core import graphs, planner, simulator
from repro.core.heuristics import by_name


def run_meta_accesses():
    rows = []
    for mname, fn in (("resnet", lambda: graphs.resnet(blocks=24)),
                      ("treelstm", lambda: graphs.treelstm(depth=6)),
                      ("transformer",
                       lambda: graphs.transformer(layers=8, d=32, seq=16))):
        log = fn()
        peak, _ = simulator.measure_baseline(log)
        # index=False throughout so every cell runs ONE engine (the linear
        # scan) — the eviction index, and the automatic scan fallback the
        # E.2 sampling modes would take, would mix two engines into one
        # comparison.  Note the scan itself now uses scoped (per-component)
        # e*/eq cache invalidation, so absolute counts sit below the
        # seed's global-invalidation numbers; the *relative* separations
        # (h_dtr >> h_dtr_eq >> h_dtr_local, exact vs E.2 sampling) are
        # what reproduce App. D.3.  benchmarks/perf_runtime.py is the
        # scan-vs-index study.
        for h in ("h_dtr", "h_dtr_eq", "h_dtr_local"):
            for frac in (0.6, 0.4):
                r = simulator.simulate(log, by_name(h), budget=frac * peak,
                                       index=False)
                rows.append(dict(
                    bench="meta", model=mname, heuristic=h, budget=frac,
                    ok=r.ok, meta_accesses=r.meta_accesses,
                    value=r.meta_accesses))
        # E.2 optimizations at 0.5 budget
        for opts, tag in (
                (dict(), "exact"),
                (dict(ignore_small_frac=0.01), "no_small"),
                (dict(sample_sqrt=True), "sqrt_sample"),
                (dict(ignore_small_frac=0.01, sample_sqrt=True), "both")):
            r = simulator.simulate(log, by_name("h_dtr_eq"),
                                   budget=0.5 * peak, index=False, **opts)
            rows.append(dict(
                bench="e2_opts", model=mname, heuristic=f"h_dtr_eq/{tag}",
                budget=0.5, ok=r.ok, meta_accesses=r.meta_accesses,
                value=r.meta_accesses))
    return rows


def run_planner_wallclock():
    """Plan cost for a real traced model (msec — the paper's selling point)."""
    d, layers = 128, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, layers)
    params = [dict(w1=jax.random.normal(k, (d, 4 * d)) * 0.02,
                   w2=jax.random.normal(k, (4 * d, d)) * 0.02) for k in ks]
    x = jax.random.normal(key, (256, d))

    def fwd(params, x):
        h = x
        for i, p in enumerate(params):
            a = checkpoint_name(jax.nn.gelu(h @ p["w1"]), f"act{i}")
            h = h + checkpoint_name(a @ p["w2"], f"proj{i}")
        return h

    g = jax.grad(lambda p, xx: jnp.mean(fwd(p, xx) ** 2))
    tg = planner.trace_to_log(g, params, x)
    peak, _ = simulator.measure_baseline(tg.log)
    rows = []
    for frac in (0.8, 0.6, 0.4):
        t0 = time.perf_counter()
        pl = planner.plan(g, params, x, budget_bytes=frac * peak)
        wall_ms = (time.perf_counter() - t0) * 1e3
        rows.append(dict(bench="planner_ms", model="mlp8x128",
                         heuristic="h_dtr_eq", budget=frac,
                         ok=pl.feasible, meta_accesses="",
                         value=round(wall_ms, 2)))
    return rows


def main(argv=()):
    rows = run_meta_accesses() + run_planner_wallclock()
    print("bench,model,heuristic,budget,ok,value")
    for r in rows:
        print(",".join(str(r[k]) for k in
                       ("bench", "model", "heuristic", "budget", "ok",
                        "value")))
    return rows


if __name__ == "__main__":
    main()
