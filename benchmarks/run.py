"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV summary lines (detailed per-row CSVs
are printed by each module's own main, reachable via
``python -m benchmarks.<name>``).
"""
from __future__ import annotations

import sys
import time


def _timed(name, fn, derive):
    t0 = time.perf_counter()
    rows = fn()
    us = int((time.perf_counter() - t0) * 1e6 / max(len(rows), 1))
    print(f"{name},{us},{derive(rows)}", flush=True)
    return rows


def fig2_heuristics():
    from . import fig2_heuristics as m

    def derive(rows):
        # min feasible budget fraction for h_dtr_eq vs h_lru (avg over models)
        def min_ok(h):
            per = {}
            for r in rows:
                if r["heuristic"] == h and r["ok"]:
                    per.setdefault(r["model"], []).append(r["budget"])
            vals = [min(v) for v in per.values() if v]
            return round(sum(vals) / max(len(vals), 1), 3)
        return (f"min_budget h_dtr_eq={min_ok('h_dtr_eq')} "
                f"h_lru={min_ok('h_lru')}")

    return _timed("fig2_heuristics", m.run, derive)


def fig3_static():
    from . import fig3_static as m

    def derive(rows):
        dtr = [r["overhead"] for r in rows
               if r["planner"] == "dtr_dtr" and r["ok"]]
        opt = [r["overhead"] for r in rows
               if r["planner"] == "revolve" and r["ok"]]
        a = sum(dtr) / max(len(dtr), 1)
        b = sum(opt) / max(len(opt), 1)
        return f"mean_overhead dtr={a:.3f} revolve_optimal={b:.3f}"

    return _timed("fig3_static", m.run, derive)


def fig4_overhead():
    from . import fig4_overhead as m

    def derive(rows):
        acc = {}
        for r in rows:
            if r["bench"] == "meta" and r["ok"]:
                acc.setdefault(r["heuristic"], []).append(
                    r["meta_accesses"])
        parts = [f"{h}={int(sum(v)/len(v))}" for h, v in sorted(acc.items())]
        return "mean_meta_accesses " + " ".join(parts)

    return _timed("fig4_overhead",
                  lambda: m.run_meta_accesses() + m.run_planner_wallclock(),
                  derive)


def fig5_theorem():
    from . import fig5_theorem as m

    def derive(rows):
        t31 = [r for r in rows if r["bench"] == "thm31"]
        first, last = t31[0]["ops_per_n"], t31[-1]["ops_per_n"]
        return f"thm31 ops/N {first}->{last} (flat=O(N) confirmed)"

    return _timed("fig5_theorem", lambda: m.run_thm31() + m.run_thm32(),
                  derive)


def table1_maxinput():
    from . import table1_maxinput as m

    def derive(rows):
        gains = [r["gain"] for r in rows]
        return f"mean_input_gain={sum(gains)/len(gains):.2f}x"

    return _timed("table1_maxinput",
                  lambda: m.run_simulated() + m.run_eager_treelstm(), derive)


def fig_fragmentation():
    from . import fig_fragmentation as m

    def derive(rows):
        gaps = [e["budget_gap"] for e in rows if e["budget_gap"] is not None]
        mean = sum(gaps) / max(len(gaps), 1)
        return f"models={len(rows)} mean_counter_vs_pool_gap={mean:.3f}"

    return _timed("fig_fragmentation",
                  lambda: list(m.run()["models"].values()), derive)


def perf_runtime():
    from . import perf_runtime as m

    def derive(rows):
        head = rows[0]["headline"]
        if rows[0]["equivalence_failures"]:
            return f"EQUIVALENCE FAILURES={rows[0]['equivalence_failures']}"
        parts = [f"{h}={v['pick_speedup']}x" for h, v in sorted(head.items())]
        return (f"pick_speedup[{rows[0]['headline_chain']}] "
                + " ".join(parts))

    return _timed("perf_runtime", lambda: [m.run(smoke=True)], derive)


def serving():
    """Captured serving/train traces -> budget curves (BENCH_serving.json)."""
    import json

    def fn():
        from repro.trace.__main__ import main as trace_main
        code = trace_main([
            "report", "--smoke", "--heuristics", "h_dtr_eq", "h_lru",
            "--fractions", "0.9", "0.7", "0.5", "0.3",
            "--thrash-factor", "10", "--out", "BENCH_serving.json"])
        with open("BENCH_serving.json") as f:
            rep = json.load(f)
        rep["exit"] = code
        return [rep]

    def derive(rows):
        rep = rows[0]
        if rep["equivalence_failures"]:
            return f"EQUIVALENCE FAILURES={rep['equivalence_failures']}"
        serve = [c["min_feasible_fraction"] for c in rep["curves"]
                 if c["trace"].startswith("serve")
                 and c["heuristic"] == "h_dtr_eq"]
        return (f"traces={len(rep['traces'])} oracle-equivalent; "
                f"serve min_budget(h_dtr_eq)="
                f"{[round(x, 2) if x else None for x in serve]}")

    return _timed("serving", fn, derive)


def perf_offload():
    from . import perf_offload as m

    def derive(rows):
        rep = rows[0]
        if not rep["gate"]["ok"] or not rep["equivalence"]["ok"]:
            return "OFFLOAD GATE FAILED"
        return (f"cells={len(rep['rows'])} "
                f"hybrid_wins={len(rep['hybrid_wins'])}")

    return _timed("perf_offload", lambda: [m.run(smoke=True)], derive)


def perf_static():
    from . import perf_static as m

    def derive(rows):
        rep = rows[0]
        if not rep["ok"]:
            return f"STATIC INVARIANTS FAILED({len(rep['violations'])})"
        gaps = [cell["dtr"]["h_dtr"]["gap_vs_static"]
                for c in rep["curves"] for cell in c["cells"]
                if cell["dtr"].get("h_dtr", {}).get("gap_vs_static")]
        mean = sum(gaps) / max(len(gaps), 1)
        n_feas = sum(1 for c in rep["curves"] for cell in c["cells"]
                     if cell["static"] is not None)
        return (f"feasible_cells={n_feas} "
                f"mean_dtr_vs_static_gap={mean:.3f}")

    return _timed("perf_static", lambda: [m.run(smoke=True)], derive)


def perf_faults():
    from . import perf_faults as m

    def derive(rows):
        rep = rows[0]
        if not rep["gates"]["ok"]:
            return "FAULTS GATE FAILED"
        surv = min((s["survival"] for s in rep["survival"]), default=1.0)
        return (f"cells={len(rep['rows'])} min_survival={surv} "
                f"degradations="
                f"{sum(s['degradations'] for s in rep['survival'])}")

    return _timed("perf_faults", lambda: [m.run(smoke=True)], derive)


def roofline():
    from . import roofline as m

    def derive(rows):
        if not rows:
            return "no dryrun artifacts (run repro.launch.dryrun --all)"
        best = max(rows, key=lambda r: r["roofline_frac"])
        return (f"cells={len(rows)} best={best['arch']}/{best['shape']}"
                f"@{best['roofline_frac']}")

    return _timed("roofline", m.load, derive)


def main() -> None:
    print("name,us_per_call,derived")
    fig2_heuristics()
    fig3_static()
    fig4_overhead()
    fig5_theorem()
    table1_maxinput()
    fig_fragmentation()
    perf_runtime()
    serving()
    perf_offload()
    perf_static()
    perf_faults()
    roofline()


if __name__ == "__main__":
    main()
