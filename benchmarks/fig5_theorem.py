"""Sec. 3 formal-bound validation (Fig. 5 trace behaviour).

Thm 3.1: linear network, B = 2⌈√N⌉, heuristic h_e* → total ops must be O(N).
We report ops/N across N (the constant must not grow) and the checkpoint-gap
statistics at the end of the forward pass (Lemma A.1's even spacing).

Thm 3.2: adversarial graph forces Ω(N²/B) ops for any deterministic
heuristic; we report the measured exponent.
"""
from __future__ import annotations

import math

from repro.core import graphs
from repro.core.graph import replay
from repro.core.heuristics import HEStar, by_name
from repro.core.runtime import DTRRuntime


def run_thm31(ns=(100, 400, 900, 1600, 2500)):
    rows = []
    for n in ns:
        b = 2 * math.ceil(math.sqrt(n))
        rt = DTRRuntime(budget=b, heuristic=HEStar())
        replay(graphs.linear_network(n), rt)
        rows.append(dict(bench="thm31", n=n, budget=b,
                         total_ops=rt.ops_executed,
                         ops_per_n=round(rt.ops_executed / n, 3)))
    return rows


def run_thm32(n=480, bs=(4, 8, 16, 32)):
    rows = []
    for b in bs:
        rt = DTRRuntime(budget=b + 1, heuristic=by_name("h_lru"))
        ops = graphs.AdversarialDriver(n, b).run(rt)
        rows.append(dict(bench="thm32", n=n, budget=b, total_ops=ops,
                         ops_per_n=round(ops / n, 3)))
    return rows


def main(argv=()):
    rows = run_thm31() + run_thm32()
    print("bench,n,budget,total_ops,ops_per_n")
    for r in rows:
        print(",".join(str(r[k]) for k in
                       ("bench", "n", "budget", "total_ops", "ops_per_n")))
    return rows


if __name__ == "__main__":
    main()
