"""Hybrid rematerialize-or-offload benchmark (``BENCH_offload.json``).

Replays the golden trace corpus (``tests/traces``) under three memory
policies at each grid cell:

  * ``dtr``     — plain rematerialization (the paper's engine, no host tier);
  * ``offload`` — every victim moves to the host tier over the modeled
    channels (swapping, never recompute) while host capacity lasts;
  * ``hybrid``  — the two-choice policy of ``repro.offload``: per victim,
    ``min(heuristic recompute cost, round-trip transfer cost)``, with async
    prefetch-back.

The grid spans device budget (fractions of the activation range) × host
budget (fractions of the same range) × transfer bandwidth (relative to the
trace's *characteristic bandwidth*, peak bytes per unit baseline compute —
``bw_rel < 1`` models a slow interconnect where transfers rarely pay,
``bw_rel >> 1`` a fast one where swapping dominates recompute).  The figure
of merit is ``overhead`` = (compute + transfer stalls) / baseline compute;
``slowdown`` counts recompute only.

``--smoke`` runs the CI gate: a reduced golden grid, plus two assertions
on the unit-cost chain log (the App. A.1 family) —

  1. at the pinned gate cells the hybrid policy's overhead is <= both
     single-mechanism baselines (the two-choice min can't lose to either
     arm where both are viable);
  2. scan-vs-index equivalence holds for every cost-aware heuristic with
     the offload key family active (bit-exact victims and counters).

Emits ``BENCH_offload.json``::

    {"gate": {...}, "equivalence": {...}, "rows": [...],
     "hybrid_wins": [...]}   # golden-trace cells where hybrid beats BOTH
"""
from __future__ import annotations

import json
import os

from repro.core import graphs
from repro.core.graph import Log
from repro.core.simulator import measure_baseline, resolve_budget, simulate
from repro.offload import OffloadConfig
from repro.trace.replay import run_to_dict, verify_oracle_equivalence

TRACES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "tests", "traces")
GOLDEN = ("treelstm", "random_dag", "serve_smoke_s4", "train_smoke")
SMOKE_GOLDEN = ("treelstm", "random_dag")

#: Heuristics whose key prices recomputation — the valid hybrid bases.
COST_AWARE = ("h_dtr", "h_dtr_eq", "h_dtr_local", "h_msps", "h_estar")

HEURISTIC = "h_dtr_eq"
THRASH = 10.0

#: CI gate cells on the unit chain: at budget fraction GATE_FRAC of peak
#: with these relative bandwidths, hybrid must not lose to either baseline.
#: (The two-choice greedy is not pointwise-dominant everywhere — at
#: near-feasible budgets mixing can lose slightly to a pure policy — so
#: the gate pins cells where dominance is the expected behavior.)
GATE_CHAIN_N = 64
GATE_FRAC = 0.15
GATE_BW_RELS = (0.5, 1.0)


def _golden(name: str) -> Log:
    with open(os.path.join(TRACES_DIR, name + ".log")) as f:
        return Log.loads(f.read(), name=name)


def _cell(log, policy, budget, host_budget, bw):
    if policy == "dtr" or host_budget <= 0:
        return simulate(log, HEURISTIC, budget, thrash_factor=THRASH)
    cfg = OffloadConfig(host_budget=host_budget, h2d_bandwidth=bw,
                        d2h_bandwidth=bw,
                        policy="offload" if policy == "offload" else "hybrid")
    return simulate(log, HEURISTIC, budget, offload=cfg,
                    thrash_factor=THRASH)


def _row(trace, dev, hf, bwr, policy, r) -> dict:
    return {"trace": trace, "device_frac": dev, "host_frac": hf,
            "bw_rel": bwr, "policy": policy, **run_to_dict(r)}


def run_grid(smoke: bool = False) -> list[dict]:
    traces = SMOKE_GOLDEN if smoke else GOLDEN
    dev_fracs = (0.5,) if smoke else (0.7, 0.5, 0.3)
    host_fracs = (1.0,) if smoke else (0.5, 1.0)
    bw_rels = (2.0, 8.0) if smoke else (0.5, 2.0, 8.0)
    rows: list[dict] = []
    for name in traces:
        log = _golden(name)
        peak, cost = measure_baseline(log)
        pinned = log.pinned_bytes()
        span = max(peak - pinned, 0.0)
        for dev in dev_fracs:
            budget = resolve_budget(dev, peak, pinned, "activation")
            rows.append(_row(name, dev, None, None, "dtr",
                             _cell(log, "dtr", budget, 0.0, 0.0)))
            for hf in host_fracs:
                for bwr in bw_rels:
                    bw = bwr * peak / max(cost, 1e-12)
                    for policy in ("offload", "hybrid"):
                        rows.append(_row(name, dev, hf, bwr, policy,
                                         _cell(log, policy, budget,
                                               hf * span, bw)))
    return rows


def hybrid_wins(rows: list[dict]) -> list[dict]:
    """Cells where hybrid strictly beats BOTH single-mechanism baselines."""
    dtr = {(r["trace"], r["device_frac"]): r for r in rows
           if r["policy"] == "dtr"}
    cells: dict[tuple, dict] = {}
    for r in rows:
        if r["policy"] in ("offload", "hybrid"):
            key = (r["trace"], r["device_frac"], r["host_frac"], r["bw_rel"])
            cells.setdefault(key, {})[r["policy"]] = r
    wins = []
    for (trace, dev, hf, bwr), pair in sorted(cells.items()):
        base = dtr.get((trace, dev))
        hyb, off = pair.get("hybrid"), pair.get("offload")
        if not (base and hyb and off and hyb["ok"]):
            continue
        floor = min(x["overhead"] for x in (base, off)
                    if x["ok"] and x["overhead"] is not None)\
            if any(x["ok"] for x in (base, off)) else None
        # A hybrid cell also "wins" when both baselines failed outright.
        if floor is None or hyb["overhead"] < floor:
            wins.append({
                "trace": trace, "device_frac": dev, "host_frac": hf,
                "bw_rel": bwr, "hybrid_overhead": hyb["overhead"],
                "dtr_overhead": base["overhead"] if base["ok"] else None,
                "offload_overhead": off["overhead"] if off["ok"] else None})
    return wins


def run_chain_gate() -> dict:
    """Hybrid <= min(dtr, offload) on the unit chain at the pinned cells."""
    log = graphs.linear_network(GATE_CHAIN_N)
    peak, cost = measure_baseline(log)
    budget = GATE_FRAC * peak
    cells = []
    ok = True
    for bwr in GATE_BW_RELS:
        bw = bwr * peak / cost
        r0 = _cell(log, "dtr", budget, 0.0, 0.0)
        ro = _cell(log, "offload", budget, peak, bw)
        rh = _cell(log, "hybrid", budget, peak, bw)
        passed = (r0.ok and ro.ok and rh.ok
                  and rh.overhead <= min(r0.overhead, ro.overhead) + 1e-12)
        ok = ok and passed
        cells.append({"bw_rel": bwr, "ok": passed,
                      "dtr": round(r0.overhead, 6) if r0.ok else None,
                      "offload": round(ro.overhead, 6) if ro.ok else None,
                      "hybrid": round(rh.overhead, 6) if rh.ok else None})
    return {"chain_n": GATE_CHAIN_N, "fraction": GATE_FRAC,
            "cells": cells, "ok": ok}


def run_equivalence_gate() -> dict:
    """Scan-vs-index bit-exactness with the offload key family active."""
    log = graphs.linear_network(GATE_CHAIN_N)
    peak, cost = measure_baseline(log)
    bw = peak / cost
    cfg = OffloadConfig(host_budget=peak, h2d_bandwidth=bw, d2h_bandwidth=bw)
    rep = verify_oracle_equivalence(
        log, heuristics=COST_AWARE, fractions=(0.5, 0.25, GATE_FRAC),
        thrash_factor=20.0, offload=cfg)
    rep.pop("index_results")
    return rep


def run(smoke: bool = False, out: str = "BENCH_offload.json") -> dict:
    gate = run_chain_gate()
    equiv = run_equivalence_gate()
    rows = run_grid(smoke=smoke)
    wins = hybrid_wins(rows)
    report = {"gate": gate, "equivalence": equiv, "rows": rows,
              "hybrid_wins": wins, "smoke": bool(smoke),
              "heuristic": HEURISTIC, "thrash_factor": THRASH}
    with open(out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True, allow_nan=False)
    n_fail = len(equiv["mismatches"])
    print(f"perf_offload: {len(rows)} cells -> {out}; "
          f"chain gate {'OK' if gate['ok'] else 'FAILED'}, "
          f"equivalence {'OK' if equiv['ok'] else f'FAILED({n_fail})'}, "
          f"hybrid_wins={len(wins)}")
    for w in wins:
        print(f"  WIN {w['trace']} dev={w['device_frac']} "
              f"host={w['host_frac']} bw={w['bw_rel']}: "
              f"hybrid={w['hybrid_overhead']:.4f} vs "
              f"dtr={w['dtr_overhead']} offload={w['offload_overhead']}")
    return report


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid + hard gate (CI)")
    ap.add_argument("--out", default="BENCH_offload.json")
    args = ap.parse_args(argv)
    report = run(smoke=args.smoke, out=args.out)
    if args.smoke and not (report["gate"]["ok"]
                           and report["equivalence"]["ok"]):
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
