"""Static-optimal baseline panel on the golden corpus (``BENCH_static.json``).

The Checkmate bridge (arXiv:1910.02653): for every captured trace and
activation-budget fraction, compare online DTR against the best *honest*
static checkpointing plan —

  * **model ladder** — heterogeneous optimal DP vs Chen √n / Chen greedy
    on the chain extracted from the trace (``repro.static.solvers``);
    the DP is structurally <= both Chen costs (it takes the min over a
    candidate pool containing them);
  * **panel winner** — the cheapest plan whose *evaluated* peak fits the
    budget (``repro.static.panel``: solo-screened greedy frontier pooled
    with the solver proposals, all judged by the bit-exact runtime
    mirror).  Cells where no known static plan fits are reported as
    ``static: null`` — that is DTR's adaptivity headroom, not an error;
  * **LP floor** — Checkmate's LP-relaxation lower bound on extra
    recompute (``repro.static.lpbound``), valid for *any* order-
    preserving schedule at the budget, so it floors both the static
    winner and every feasible DTR run;
  * **DTR rows** — ``h_dtr`` / ``h_dtr_eq`` at the same budgets, with
    ``gap_vs_static`` = DTR compute / static compute where both exist.

Every winning plan is replayed through the real ``DTRRuntime`` with the
heuristic disabled and must match the evaluator bit-for-bit (remats,
evictions, compute, peak) — static and online rows share one accounting.

``--smoke`` runs a reduced corpus and hard-gates CI on the invariants:
DP <= Chen at every cell, LP <= executed extra compute of every feasible
plan (static and DTR), and executor/evaluator parity on every winner.
"""
from __future__ import annotations

import json
import os

from repro.core.graph import Log
from repro.trace.replay import static_gap_curve

TRACES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "tests", "traces")
GOLDEN = ("train_smoke", "eager_mlp", "treelstm", "random_dag",
          "serve_smoke_s2")
SMOKE_GOLDEN = ("eager_mlp", "treelstm")

FRACTIONS = (0.9, 0.7, 0.5)
HEURISTICS = ("h_dtr", "h_dtr_eq")
THRASH = 10.0


def _golden(name: str) -> Log:
    with open(os.path.join(TRACES_DIR, name + ".log")) as f:
        return Log.loads(f.read(), name=name)


def check_invariants(curves: list[dict]) -> list[str]:
    """The differential gates; empty list == all invariants hold."""
    bad: list[str] = []
    for cur in curves:
        for cell in cur["cells"]:
            where = f"{cur['trace']}@{cell['fraction']}"
            m = cell["model"]
            if m["dp_le_chen"] is False:
                bad.append(f"{where}: model DP cost above a Chen baseline")
            st = cell["static"]
            if st is not None:
                if st["peak"] > cell["budget"]:
                    bad.append(f"{where}: winner peak exceeds budget")
                if not st["lp_le_extra"]:
                    bad.append(f"{where}: LP floor above static extra "
                               f"compute")
                ex = st.get("exec")
                if ex is not None and not all(ex.values()):
                    bad.append(f"{where}: executor/evaluator parity "
                               f"broken {ex}")
            for h, row in cell["dtr"].items():
                if row["ok"] and row["extra_ge_lp"] is False:
                    bad.append(f"{where}/{h}: LP floor above DTR extra "
                               f"compute")
    return bad


def run(smoke: bool = False, out: str = "BENCH_static.json") -> dict:
    traces = SMOKE_GOLDEN if smoke else GOLDEN
    curves = []
    for name in traces:
        log = _golden(name)
        curves.append(static_gap_curve(
            log, fractions=FRACTIONS, heuristics=HEURISTICS,
            thrash_factor=THRASH, execute=True))
    violations = check_invariants(curves)
    report = {"curves": curves, "violations": violations,
              "ok": not violations, "smoke": bool(smoke),
              "fractions": list(FRACTIONS), "heuristics": list(HEURISTICS),
              "thrash_factor": THRASH}
    with open(out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True, allow_nan=False)
    n_cells = sum(len(c["cells"]) for c in curves)
    n_feas = sum(1 for c in curves for cell in c["cells"]
                 if cell["static"] is not None)
    print(f"perf_static: {n_cells} cells -> {out}; "
          f"static feasible in {n_feas}/{n_cells}, "
          f"invariants {'OK' if not violations else 'FAILED'}")
    for cur in curves:
        for cell in cur["cells"]:
            st = cell["static"]
            s = (f"static oh={st['overhead']:.3f} ({st['source']}, "
                 f"drop {st['n_drop']})" if st else "static infeasible")
            d = cell["dtr"].get("h_dtr", {})
            g = d.get("gap_vs_static")
            print(f"  {cur['trace']}@{cell['fraction']}: {s}; "
                  f"h_dtr {'oh=' + format(d['overhead'], '.3f') if d.get('ok') else 'FAIL'}"
                  f"{f' gap={g:.3f}' if g else ''}")
    for v in violations:
        print(f"  VIOLATION {v}")
    return report


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced corpus + hard invariant gate (CI)")
    ap.add_argument("--out", default="BENCH_static.json")
    args = ap.parse_args(argv)
    report = run(smoke=args.smoke, out=args.out)
    if args.smoke and not report["ok"]:
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
