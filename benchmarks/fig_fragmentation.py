"""Counter-model vs pool-model achievable budgets (the Coop realism gap).

The DTR paper's simulator treats device memory as a fungible byte counter; a
real allocator needs a *contiguous* block per tensor.  This benchmark sweeps
budget fractions on the model-shaped graphs under both memory models
(``alloc_mode="counter"`` vs ``"pool"``, see ``repro.core.simulator``) and
reports, per model:

  * the smallest feasible budget fraction under each model (and the smallest
    with slowdown < 2x, the paper's dashed-line criterion);
  * the counter-vs-pool budget gap — how optimistic the byte counter is;
  * fragmentation telemetry at the tightest pool-feasible budget (largest
    free block, external-fragmentation ratio, failed fits, window evictions).

Emits a JSON report (stdout, or ``--out PATH``).  ``--placement`` selects the
pool placement policy; ``--heuristic`` the eviction heuristic.
"""
from __future__ import annotations

import json
import sys

from repro.core import graphs, simulator

BUDGETS = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.25, 0.2, 0.15]

MODELS = {
    "mlp": lambda: graphs.mlp(depth=16),
    "resnet": lambda: graphs.resnet(blocks=12),
    "unet": lambda: graphs.unet(depth=4),
    "transformer": lambda: graphs.transformer(layers=4, d=16, seq=8),
    "treelstm": lambda: graphs.treelstm(depth=5),
}

SLOWDOWN_THRESH = 2.0


def _sweep(log, heuristic, peak, alloc_mode, placement):
    rows = []
    for frac in BUDGETS:
        r = simulator.simulate(log, heuristic, budget=frac * peak,
                               alloc_mode=alloc_mode, placement=placement)
        rows.append(dict(
            budget=frac, ok=r.ok,
            slowdown=round(r.slowdown, 4) if r.ok else None,
            evictions=r.evictions, remats=r.remat_ops,
            largest_free=r.largest_free, frag_ratio=round(r.frag_ratio, 4),
            failed_fits=r.failed_fits, evict_windows=r.evict_windows,
            error=r.error[:120] if r.error else ""))
    return rows


def _min_budget(rows, thresh=None):
    ok = [r["budget"] for r in rows
          if r["ok"] and (thresh is None or r["slowdown"] < thresh)]
    return min(ok, default=None)


def run(heuristic: str = "h_dtr_eq", placement: str = "best_fit",
        models=None) -> dict:
    report = {"heuristic": heuristic, "placement": placement,
              "slowdown_thresh": SLOWDOWN_THRESH, "models": {}}
    for name, fn in (models or MODELS).items():
        log = fn()
        peak, _ = simulator.measure_baseline(log)
        counter = _sweep(log, heuristic, peak, "counter", placement)
        pool = _sweep(log, heuristic, peak, "pool", placement)
        c_min = _min_budget(counter)
        p_min = _min_budget(pool)
        entry = {
            "baseline_peak": peak,
            "counter": {"min_budget": c_min,
                        "min_budget_2x": _min_budget(counter,
                                                     SLOWDOWN_THRESH),
                        "runs": counter},
            "pool": {"min_budget": p_min,
                     "min_budget_2x": _min_budget(pool, SLOWDOWN_THRESH),
                     "runs": pool},
            # How many budget points the byte counter over-promises.
            "budget_gap": (round(p_min - c_min, 4)
                           if c_min is not None and p_min is not None
                           else None),
        }
        tight = [r for r in pool if r["ok"] and r["budget"] == p_min]
        if tight:
            entry["pool_frag_at_min_budget"] = {
                k: tight[0][k] for k in
                ("largest_free", "frag_ratio", "failed_fits",
                 "evict_windows")}
        report["models"][name] = entry
    return report


def main(argv=()):
    argv = list(argv)
    heuristic = (argv[argv.index("--heuristic") + 1]
                 if "--heuristic" in argv else "h_dtr_eq")
    placement = (argv[argv.index("--placement") + 1]
                 if "--placement" in argv else "best_fit")
    report = run(heuristic=heuristic, placement=placement)
    text = json.dumps(report, indent=2, allow_nan=False)
    if "--out" in argv:
        path = argv[argv.index("--out") + 1]
        with open(path, "w") as f:
            f.write(text + "\n")
        print(f"wrote {path}")
    else:
        print(text)
    return report


if __name__ == "__main__":
    main(sys.argv[1:])
