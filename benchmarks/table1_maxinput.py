"""Table 1 reproduction: larger-than-memory inputs via DTR.

Two forms:
  1. Simulated (like the paper's Table 1): for each model graph, find the
     largest batch multiplier trainable at a FIXED byte budget with DTR vs
     without (no-DTR = fails as soon as unconstrained peak exceeds budget).
  2. Real buffers: the eager executor trains a TreeLSTM on growing trees
     under a fixed byte budget — actual allocations, actual evictions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import graphs, simulator
from repro.core.heuristics import by_name
from repro.core.runtime import OOMError, ThrashError
from repro.eager import DTRContext


def run_simulated():
    rows = []
    cases = {
        "mlp": lambda m: graphs.mlp(depth=16, batch=8 * m),
        "transformer": lambda m: graphs.transformer(layers=6, d=32, seq=8,
                                                    batch=2 * m),
        "treelstm": lambda m: graphs.treelstm(depth=3 + m),
        "lstm": lambda m: graphs.lstm(steps=16 * m),
    }
    for mname, fn in cases.items():
        base_peak, _ = simulator.measure_baseline(fn(1))
        budget = 1.05 * base_peak  # fits multiplier 1 without DTR, barely
        max_plain, max_dtr = 0, 0
        for m in range(1, 9):
            log = fn(m)
            peak, _ = simulator.measure_baseline(log)
            if peak <= budget:
                max_plain = m
            r = simulator.simulate(log, by_name("h_dtr_eq"), budget=budget)
            if r.ok and r.slowdown < 2.0:   # paper's thrash threshold
                max_dtr = m
        rows.append(dict(bench="sim", model=mname,
                         budget=int(budget), max_plain=max_plain,
                         max_dtr=max_dtr,
                         gain=round(max_dtr / max(max_plain, 1), 2)))
    return rows


def run_eager_treelstm():
    """Real-buffer version: largest complete tree trainable at fixed bytes."""
    dim = 128
    budget = (dim * dim + 40 * dim) * 4  # weight + ~40 activation slots

    def try_depth(depth, use_dtr):
        ctx = DTRContext(budget_bytes=budget if use_dtr else float("inf"))
        w = ctx.wrap(jnp.eye(dim) * 0.3, name="w")

        def build(d, v):
            if d == 0:
                return ctx.wrap(jnp.full((dim,), v), name="leaf")
            a, b = build(d - 1, v), build(d - 1, v + .01)
            s = ctx.call("add", jnp.add, [a, b])[0]
            return ctx.call("cell", lambda s_, w_: jnp.tanh(s_ @ w_),
                            [s, w])[0]

        try:
            root = build(depth, 0.1)
            _ = root.value
            if not use_dtr:
                # "plain" framework: peak live bytes must fit the budget
                n_leaves = 2 ** depth
                n_inner = 2 ** depth - 1
                peak = (dim * dim + (n_leaves + 2 * n_inner) * dim) * 4
                return peak <= budget
            return True
        except (OOMError, ThrashError):
            return False

    max_plain = max_dtr = 0
    for depth in range(1, 9):
        if try_depth(depth, use_dtr=False):
            max_plain = depth
        if try_depth(depth, use_dtr=True):
            max_dtr = depth
    return [dict(bench="eager", model="treelstm_real", budget=budget,
                 max_plain=max_plain, max_dtr=max_dtr,
                 gain=round(2 ** max_dtr / 2 ** max(max_plain, 0), 2))]


def main(argv=()):
    rows = run_simulated() + run_eager_treelstm()
    print("bench,model,budget,max_plain,max_dtr,gain")
    for r in rows:
        print(",".join(str(r[k]) for k in
                       ("bench", "model", "budget", "max_plain", "max_dtr",
                        "gain")))
    return rows


if __name__ == "__main__":
    main()
