"""Victim-selection performance benchmark (the eviction-index trajectory).

Measures, for each workload x heuristic, the wall-clock spent *inside*
``DTRRuntime._pick_victim`` (victim selection only), the wall-clock of the
index's key flush (``EvictIndex._flush_dirty`` — the eq-path hotspot),
total run wall-clock, ``meta_accesses``, subscriber registrations per
victim pick, and evictions/sec — once with the incremental eviction index
(``index=True``, the default) and once with the exhaustive linear-scan
oracle (``index=False``).  Both runs are asserted bit-exact (same
evictions / compute / peak) before any ratio is reported, so a speedup can
never come from making different decisions.

Workloads: N-op linear chains (the App. A.1 family; the 1000-op chain at
budget fraction 0.3 is the headline configuration), the
resnet / unet / transformer / treelstm model logs, and the golden captured
train-step trace (``tests/traces/train_smoke.log``, activation-mode
budget) — the real workload whose e*-walk subscriber growth and eq flush
cost this file gates.

Emits ``BENCH_runtime.json``::

    {"headline": {...},            # chain-1000 @ 0.3 summary per heuristic
     "rows": [...],                # every measured cell (incl. flush_s,
                                   # subscribes, subs_per_pick columns)
     "train_trace": [...],         # the captured-trace cells
     "equivalence_failures": 0}

``--smoke`` runs a reduced grid (fast enough for CI) and exits nonzero on
any oracle-equivalence mismatch *or* when subscribes-per-pick on the
captured train trace exceeds the pinned ceilings (the e*-walk-growth
regression gate).
"""
from __future__ import annotations

import json
import os
import time

from repro.core import graphs, simulator
from repro.core.graph import Log, replay
from repro.core.heuristics import by_name
from repro.core.runtime import DTRRuntime, OOMError, ThrashError

PARITY_FIELDS = ("evictions", "total_compute", "base_compute", "remat_ops",
                 "ops_executed", "peak_memory")

TRAIN_TRACE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "tests", "traces", "train_smoke.log")
#: subscribes-per-pick ceilings on the golden train trace @ 0.9 activation
#: budget (measured 3385.3 / 12.7 post-fix; the pre-fix engine sat at
#: ~3751 for h_dtr and ~33 for h_dtr_eq) — the walk-cost bug regressing
#: fails the smoke gate.
SUBS_PER_PICK_CEILING = {"h_dtr": 3600.0, "h_dtr_eq": 25.0}


def _timed_run(log, heuristic, budget, index, thrash_factor=50.0):
    """One replay; returns wall/pick/flush timings + the runtime."""
    rt = DTRRuntime(budget=budget, heuristic=by_name(heuristic),
                    compute_limit=thrash_factor * log.baseline_cost(),
                    index=index)
    pick_time = [0.0]
    inner = rt._pick_victim

    def timed_pick(exclude):
        t0 = time.perf_counter()
        victim = inner(exclude)
        pick_time[0] += time.perf_counter() - t0
        return victim

    rt._pick_victim = timed_pick
    flush_time = [0.0]
    if rt.index is not None:
        inner_flush = rt.index._flush_dirty

        def timed_flush():
            t0 = time.perf_counter()
            inner_flush()
            flush_time[0] += time.perf_counter() - t0

        rt.index._flush_dirty = timed_flush
    t0 = time.perf_counter()
    ok, err = True, ""
    try:
        replay(log, rt)
    except (OOMError, ThrashError) as e:
        ok, err = False, str(e)
    return dict(wall_s=time.perf_counter() - t0, pick_s=pick_time[0],
                flush_s=flush_time[0], ok=ok, error=err, rt=rt)


def bench_cell(log, name, heuristic, frac, peak, rows, budget=None):
    """Measure oracle vs index on one (log, heuristic, frac) cell.

    ``budget`` overrides the default ``frac * peak`` (captured traces use
    activation-mode budgets resolved by the caller; ``frac`` stays the
    reported label either way).
    """
    budget = frac * peak if budget is None else budget
    oracle = _timed_run(log, heuristic, budget, index=False)
    indexed = _timed_run(log, heuristic, budget, index=True)
    mismatches = [f for f in PARITY_FIELDS
                  if getattr(oracle["rt"], f) != getattr(indexed["rt"], f)]
    if oracle["ok"] != indexed["ok"]:
        mismatches.append("ok")
    for mode, run in (("scan", oracle), ("index", indexed)):
        rt = run["rt"]
        idx = rt.index
        rows.append(dict(
            log=name, n_ops=log.op_count(), heuristic=heuristic,
            budget=frac, mode=mode, ok=run["ok"],
            wall_s=round(run["wall_s"], 6), pick_s=round(run["pick_s"], 6),
            flush_s=round(run["flush_s"], 6),
            meta_accesses=rt.meta_accesses
            + (rt.uf.accesses if rt.uf else 0),
            evictions=rt.evictions,
            picks=rt.victim_picks,
            subscribes=rt._invalidator.subscribes,
            subs_per_pick=round(rt._invalidator.subscribes
                                / max(rt.victim_picks, 1), 1),
            key_recomputes=idx.key_recomputes if idx is not None else 0,
            evictions_per_s=round(rt.evictions / max(run["wall_s"], 1e-9)),
            error=run["error"]))
    def _meta(rt):
        # Same quantity the per-mode rows report (uf hops included), so
        # meta_reduction can be recomputed from the rows.
        return rt.meta_accesses + (rt.uf.accesses if rt.uf else 0)

    return dict(
        log=name, heuristic=heuristic, budget=frac,
        ok=oracle["ok"] and indexed["ok"],
        pick_speedup=round(oracle["pick_s"] / max(indexed["pick_s"], 1e-9), 2),
        wall_speedup=round(oracle["wall_s"] / max(indexed["wall_s"], 1e-9), 2),
        meta_reduction=round(
            _meta(oracle["rt"]) / max(_meta(indexed["rt"]), 1), 2),
        flush_s=round(indexed["flush_s"], 6),
        subs_per_pick=round(
            indexed["rt"]._invalidator.subscribes
            / max(indexed["rt"].victim_picks, 1), 1),
        equivalent=not mismatches, mismatched_fields=mismatches)


def bench_train_trace(rows, heuristics=("h_dtr", "h_dtr_eq"), frac=0.9):
    """Cells for the golden captured train trace (activation budget)."""
    with open(TRAIN_TRACE) as f:
        log = Log.loads(f.read())
    peak, _ = simulator.measure_baseline(log)
    budget = simulator.resolve_budget(frac, peak, log.pinned_bytes(),
                                      "activation")
    return [bench_cell(log, "train839", h, frac, peak, rows, budget=budget)
            for h in heuristics]


def run(smoke=False):
    if smoke:
        chain_sizes = [200]
        models = {"mlp": lambda: graphs.mlp(depth=8),
                  "resnet": lambda: graphs.resnet(blocks=4)}
        heuristics = ["h_dtr", "h_dtr_eq", "h_lru"]
        fracs = [0.4]
        headline_chain = 200
    else:
        chain_sizes = [250, 500, 1000, 2000]
        models = {"resnet": lambda: graphs.resnet(blocks=24),
                  "unet": lambda: graphs.unet(depth=5),
                  "transformer": lambda: graphs.transformer(
                      layers=8, d=32, seq=16),
                  "treelstm": lambda: graphs.treelstm(depth=6)}
        heuristics = ["h_dtr", "h_dtr_eq", "h_lru", "h_dtr_local",
                      "h_size", "h_msps", "h_estar"]
        fracs = [0.3]
        headline_chain = 1000

    rows, summaries = [], []
    for n in chain_sizes:
        log = graphs.linear_network(n)
        peak, _ = simulator.measure_baseline(log)
        for h in heuristics:
            for frac in fracs:
                summaries.append(
                    bench_cell(log, f"chain{n}", h, frac, peak, rows))
    for mname, fn in models.items():
        log = fn()
        peak, _ = simulator.measure_baseline(log)
        for h in heuristics[:3] if not smoke else heuristics:
            summaries.append(bench_cell(log, mname, h, 0.5, peak, rows))
    train_cells = bench_train_trace(rows)
    summaries.extend(train_cells)

    headline = {
        s["heuristic"]: dict(pick_speedup=s["pick_speedup"],
                             wall_speedup=s["wall_speedup"],
                             meta_reduction=s["meta_reduction"],
                             equivalent=s["equivalent"])
        for s in summaries
        if s["log"] == f"chain{headline_chain}" and s["budget"] == fracs[0]}
    failures = [s for s in summaries if not s["equivalent"]]
    subs_violations = [
        dict(heuristic=s["heuristic"], subs_per_pick=s["subs_per_pick"],
             ceiling=SUBS_PER_PICK_CEILING[s["heuristic"]])
        for s in train_cells
        if s["subs_per_pick"] > SUBS_PER_PICK_CEILING.get(
            s["heuristic"], float("inf"))]
    return dict(headline_chain=f"chain{headline_chain}@{fracs[0]}",
                headline=headline, summaries=summaries, rows=rows,
                train_trace=train_cells,
                subs_per_pick_ceiling=SUBS_PER_PICK_CEILING,
                subs_ceiling_violations=subs_violations,
                equivalence_failures=len(failures))


def main(argv=()):
    smoke = "--smoke" in argv
    out_path = "BENCH_runtime.json"
    for i, a in enumerate(argv):
        if a == "--out" and i + 1 < len(argv):
            out_path = argv[i + 1]
    report = run(smoke=smoke)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True, allow_nan=False)
    print(f"# wrote {out_path}")
    print("log,heuristic,budget,pick_speedup,wall_speedup,"
          "meta_reduction,equivalent")
    for s in report["summaries"]:
        print(",".join(str(s[k]) for k in
                       ("log", "heuristic", "budget", "pick_speedup",
                        "wall_speedup", "meta_reduction", "equivalent")))
    if report["equivalence_failures"]:
        print(f"FAIL: {report['equivalence_failures']} cell(s) broke "
              f"oracle equivalence")
        return 1
    if report["subs_ceiling_violations"]:
        for v in report["subs_ceiling_violations"]:
            print(f"FAIL: train839 {v['heuristic']} subscribes-per-pick "
                  f"{v['subs_per_pick']} over ceiling {v['ceiling']} "
                  f"(e*-walk growth regression)")
        return 1
    print(f"headline ({report['headline_chain']}): "
          + " ".join(f"{h}={v['pick_speedup']}x"
                     for h, v in sorted(report["headline"].items())))
    print("train839 (@0.9 activation): "
          + " ".join(f"{s['heuristic']}: subs/pick={s['subs_per_pick']} "
                     f"flush_s={s['flush_s']}"
                     for s in report["train_trace"]))
    return 0


if __name__ == "__main__":
    import sys
    raise SystemExit(main(sys.argv[1:]))
