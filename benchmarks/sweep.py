"""CLI driver for the process-parallel budget sweep (ROADMAP: "parallel
sweep ergonomics").

Sweeps budgets × heuristics × models through ``simulator.sweep_parallel``
and writes one JSON report.  Models are synthetic graph builders by name
(``core.graphs``) and/or captured trace files (``repro.trace``); traces are
swept over the activation budget range by default (their pinned weights
would otherwise put every interesting fraction below the feasibility floor).

  PYTHONPATH=src python -m benchmarks.sweep --smoke
  PYTHONPATH=src python -m benchmarks.sweep \
      --models mlp resnet transformer --heuristics h_dtr h_dtr_eq h_lru \
      --fractions 0.9 0.7 0.5 0.4 0.3 --out sweep.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import graphs
from repro.core.graph import Log
from repro.core.heuristics import ALL_NAMES
from repro.core.simulator import sweep_parallel

BUILDERS = {
    "mlp": lambda: graphs.mlp(),
    "resnet": lambda: graphs.resnet(),
    "unet": lambda: graphs.unet(),
    "transformer": lambda: graphs.transformer(),
    "lstm": lambda: graphs.lstm(),
    "treelstm": lambda: graphs.treelstm(),
    "random_dag": lambda: graphs.random_dag(200, seed=0),
    "linear200": lambda: graphs.linear_network(200),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.sweep")
    ap.add_argument("--models", nargs="+", default=["mlp", "transformer"],
                    choices=sorted(BUILDERS))
    ap.add_argument("--traces", nargs="*", default=[],
                    help="captured trace files to sweep as well")
    ap.add_argument("--heuristics", nargs="+", default=["h_dtr_eq", "h_lru"],
                    choices=ALL_NAMES + ["h_estar"])
    ap.add_argument("--fractions", nargs="+", type=float,
                    default=[0.9, 0.7, 0.5, 0.4, 0.3])
    ap.add_argument("--dealloc", default="eager",
                    choices=["ignore", "eager", "banish"])
    ap.add_argument("--alloc-mode", default=None,
                    choices=[None, "counter", "pool", "pool_nofrag"])
    ap.add_argument("--budget-mode", default=None,
                    choices=["peak", "activation"],
                    help="default: peak for synthetic models, activation "
                         "for captured traces")
    ap.add_argument("--scan", action="store_true",
                    help="linear-scan oracle instead of the eviction index")
    ap.add_argument("--processes", type=int, default=None,
                    help="0 forces the serial path")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI grid (2 models x 2 heuristics x 3 "
                         "budgets, serial-equivalence asserted)")
    ap.add_argument("--out", default="sweep.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.models = ["mlp", "treelstm"]
        args.heuristics = ["h_dtr_eq", "h_lru"]
        args.fractions = [0.9, 0.6, 0.4]

    model_logs = [BUILDERS[m]() for m in args.models]
    trace_logs = []
    for path in args.traces:
        with open(path) as f:
            trace_logs.append(Log.loads(f.read()))

    t0 = time.perf_counter()
    results = []
    for logs, default_mode in ((model_logs, "peak"),
                               (trace_logs, "activation")):
        if not logs:
            continue
        results += sweep_parallel(
            logs, args.heuristics, args.fractions, dealloc=args.dealloc,
            alloc_mode=args.alloc_mode, index=not args.scan,
            processes=args.processes,
            budget_mode=args.budget_mode or default_mode)
    wall = time.perf_counter() - t0

    if args.smoke:
        # CI gate: the parallel grid must equal a serial re-run cell by cell.
        serial = []
        for logs, default_mode in ((model_logs, "peak"),
                                   (trace_logs, "activation")):
            if not logs:
                continue
            serial += sweep_parallel(
                logs, args.heuristics, args.fractions, dealloc=args.dealloc,
                alloc_mode=args.alloc_mode, index=not args.scan, processes=0,
                budget_mode=args.budget_mode or default_mode)
        if [s.runs for s in serial] != [r.runs for r in results]:
            print("SMOKE FAILURE: parallel sweep != serial sweep")
            return 1
        print("smoke: parallel == serial over "
              f"{sum(len(r.runs) for r in results)} cells")

    report = {"wall_s": round(wall, 3), "grid": []}
    print(f"model,heuristic,fraction,ok,slowdown,evictions,remats")
    for sw in results:
        entry = {"model": sw.log_name, "heuristic": sw.heuristic,
                 "baseline_peak": sw.baseline_peak,
                 "alloc_mode": sw.alloc_mode,
                 "min_feasible": min((r.budget for r in sw.runs if r.ok),
                                     default=None),
                 "runs": [vars(r) for r in sw.runs]}
        report["grid"].append(entry)
        for r in sw.runs:
            slow = f"{r.slowdown:.3f}" if r.ok else "inf"
            print(f"{sw.log_name},{sw.heuristic},{r.budget},{int(r.ok)},"
                  f"{slow},{r.evictions},{r.remat_ops}")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True, allow_nan=False)
    print(f"-> {args.out} ({len(report['grid'])} rows, {wall:.2f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
