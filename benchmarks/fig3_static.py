"""Fig. 3 reproduction: DTR vs static checkpointing planners.

On linear chains (where optimal static planning is tractable in closed form /
DP — Checkmate's ILP solver is unavailable offline, noted in EXPERIMENTS.md),
compares total executed forward ops:

  dtr_*        — online, no advance knowledge (h_dtr, h_dtr_eq, h_lru)
  chen_sqrt    — Chen et al. √N segmentation (budget-oblivious)
  chen_greedy  — Chen greedy at the same budget
  revolve      — Griewank binomial schedule (optimal one-shot reversal)

Overhead ratio = total_ops / (2N) (the unconstrained fwd+bwd op count).
"""
from __future__ import annotations

import math
import time

from repro.core import baselines, graphs
from repro.core.graph import replay
from repro.core.heuristics import by_name
from repro.core.runtime import DTRRuntime, OOMError, ThrashError


def run(ns=(64, 128, 256, 512), budget_fracs=(0.5, 0.25, 0.125)):
    rows = []
    for n in ns:
        for bf in budget_fracs:
            budget = max(int(n * bf), 6)
            # --- DTR variants (budget counts tensors; unit sizes) ---
            for h in ("h_dtr", "h_dtr_eq", "h_lru"):
                log = graphs.linear_network(n)
                rt = DTRRuntime(budget=budget, heuristic=by_name(h),
                                compute_limit=500.0 * n)
                t0 = time.perf_counter()
                try:
                    replay(log, rt)
                    ops = rt.ops_executed
                    ok = True
                except (OOMError, ThrashError):
                    ops, ok = 0, False
                wall = time.perf_counter() - t0
                rows.append(dict(
                    planner=f"dtr_{h[2:]}", n=n, budget=budget, ok=ok,
                    total_ops=ops,
                    overhead=round(ops / (2 * n), 3) if ok else "",
                    plan_us=int(wall * 1e6)))
            # --- static planners (forward ops + N backward ops) ---
            for name in ("chen_sqrt", "chen_greedy", "revolve"):
                t0 = time.perf_counter()
                fwd_ops, peak = baselines.BASELINES[name](n, budget)
                wall = time.perf_counter() - t0
                total = fwd_ops + n
                feasible = peak <= budget
                rows.append(dict(
                    planner=name, n=n, budget=budget, ok=feasible,
                    total_ops=total, overhead=round(total / (2 * n), 3),
                    plan_us=int(wall * 1e6)))
    return rows


def main(argv=()):
    rows = run()
    print("planner,n,budget,ok,total_ops,overhead,plan_us")
    for r in rows:
        print(",".join(str(r[k]) for k in
                       ("planner", "n", "budget", "ok", "total_ops",
                        "overhead", "plan_us")))
    return rows


if __name__ == "__main__":
    main()
