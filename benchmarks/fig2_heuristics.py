"""Fig. 2 reproduction: heuristic comparison — slowdown vs memory budget.

Simulates DTR on six model-shaped graphs (the paper's model families) across
heuristics and budget fractions; also covers the Appendix D.1 ablation grid
(--ablate) and the D.2 deallocation-policy comparison (--dealloc).

Emits CSV rows: model,heuristic,budget_frac,ok,slowdown,evictions,remats,
meta_accesses.

Runs under the incremental eviction index (the default engine): slowdown /
evictions / remats are bit-identical to the linear scan, and the sweep is
several times faster.  The meta_accesses column therefore reflects the
indexed engine's accounting; use benchmarks/fig4_overhead.py (pinned to
index=False) for the paper's App. D.3 metadata-overhead comparison.
"""
from __future__ import annotations

import time

from repro.core import graphs, simulator
from repro.core.heuristics import ALL_NAMES, by_name, make_ablation

BUDGETS = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.25, 0.2, 0.15, 0.1]

MODELS = {
    "mlp": lambda: graphs.mlp(depth=32),
    "resnet": lambda: graphs.resnet(blocks=24),
    "unet": lambda: graphs.unet(depth=5),
    "transformer": lambda: graphs.transformer(layers=8, d=32, seq=16),
    "lstm": lambda: graphs.lstm(steps=48),
    "treelstm": lambda: graphs.treelstm(depth=6),
}


def run(heuristics=None, budgets=None, models=None, dealloc="eager"):
    rows = []
    heuristics = heuristics or ALL_NAMES
    budgets = budgets or BUDGETS
    models = models or MODELS
    for mname, fn in models.items():
        log = fn()
        peak, base = simulator.measure_baseline(log)
        for h in heuristics:
            hs = h if isinstance(h, str) else h.name
            for frac in budgets:
                t0 = time.perf_counter()
                hobj = by_name(h) if isinstance(h, str) else h
                r = simulator.simulate(log, hobj, budget=frac * peak,
                                       dealloc=dealloc)
                wall = time.perf_counter() - t0
                rows.append(dict(
                    model=mname, heuristic=hs, budget=frac, ok=r.ok,
                    slowdown=round(r.slowdown, 4) if r.ok else "",
                    evictions=r.evictions, remats=r.remat_ops,
                    meta_accesses=r.meta_accesses,
                    wall_us=int(wall * 1e6)))
    return rows


def run_ablation():
    hs = [make_ablation(s, m, c)
          for s in (True, False) for m in (True, False)
          for c in ("estar", "eq", "local", "no")]
    return run(heuristics=hs, budgets=[0.8, 0.6, 0.4],
               models={k: MODELS[k] for k in ("resnet", "treelstm")})


def run_dealloc():
    rows = []
    for pol in ("ignore", "eager", "banish"):
        rr = run(heuristics=["h_dtr"], budgets=[0.8, 0.6, 0.4, 0.25],
                 models={k: MODELS[k] for k in ("resnet", "unet", "lstm")},
                 dealloc=pol)
        for r in rr:
            r["heuristic"] = f"h_dtr/{pol}"
        rows += rr
    return rows


def main(argv=()):
    rows = run()
    if "--ablate" in argv:
        rows += run_ablation()
    if "--dealloc" in argv:
        rows += run_dealloc()
    print("model,heuristic,budget,ok,slowdown,evictions,remats,"
          "meta_accesses,wall_us")
    for r in rows:
        print(",".join(str(r[k]) for k in
                       ("model", "heuristic", "budget", "ok", "slowdown",
                        "evictions", "remats", "meta_accesses", "wall_us")))
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
