"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and prints per-(arch × shape × mesh):
all three terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and the
roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os


def load(dirname="experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        r = d["roofline"]
        rows.append(dict(
            arch=d["arch"], shape=d["shape"],
            mesh="multi" if "pod" in d["mesh"] else "single",
            chips=d["chips"],
            compute_ms=round(r["compute_s"] * 1e3, 3),
            memory_ms=round(r["memory_s"] * 1e3, 3),
            collective_ms=round(r["collective_s"] * 1e3, 3),
            dominant=r["dominant"],
            useful_flops=round(r["useful_flops_frac"], 3),
            roofline_frac=round(r["roofline_frac"], 4),
            mem_gib=round(d["memory"]["peak_bytes_per_device"] / 2**30, 2),
        ))
    return rows


def main(argv=()):
    rows = load()
    print("arch,shape,mesh,chips,compute_ms,memory_ms,collective_ms,"
          "dominant,useful_flops,roofline_frac,mem_gib")
    for r in rows:
        print(",".join(str(r[k]) for k in
                       ("arch", "shape", "mesh", "chips", "compute_ms",
                        "memory_ms", "collective_ms", "dominant",
                        "useful_flops", "roofline_frac", "mem_gib")))
    return rows


if __name__ == "__main__":
    main()
