"""Fault-injection benchmark (``BENCH_faults.json``).

Replays the golden trace corpus (``tests/traces``) under the
``repro.faults`` chaos schedules and the runtime's graceful-degradation
ladder, over a fault-profile × fault-rate × budget grid:

  * ``alloc``    — transient allocator admission failures (the ladder's
    headroom-eviction recovery must absorb every one: alloc faults alone
    can never kill a run);
  * ``cost``     — lognormal per-operator charged-cost misestimation
    (heuristics keep scoring the unperturbed estimates);
  * ``squeeze``  — a square-wave co-tenant stealing device memory
    mid-run (budget shrink/restore);
  * ``transfer`` — flaky/contended H2D+D2H channels: faults retried with
    capped exponential backoff, latency spikes, lost prefetches (runs
    with the hybrid offload tier attached, else channels never move);
  * ``mixed``    — all of the above at once.

Figures of merit per (profile, rate): **survival** (fraction of cells
finishing, ok or recovered) and **degraded overhead** (mean overhead of
surviving cells vs the same cells fault-free).

``--smoke`` runs the CI gate:

  1. *zero-rate bit-exactness* — attaching an all-rates-zero
     ``FaultConfig`` replays every smoke trace with victim sequences and
     counters identical to a plain run (fault machinery off == absent);
  2. *zero unrecovered failures at the pinned cells* — alloc and cost
     profiles at the pinned rates must survive via the recovery ladder;
  3. *determinism* — a pinned mixed-profile schedule produces identical
     victims, degradation counts, and event streams across two runs and
     across the scan/index engines.

Emits ``BENCH_faults.json``::

    {"gates": {...}, "rows": [...], "survival": [...], "smoke": bool}
"""
from __future__ import annotations

import json
import os

from repro.core.graph import Log
from repro.core.simulator import measure_baseline, resolve_budget, simulate
from repro.faults import FaultConfig, RecoveryConfig
from repro.offload import OffloadConfig
from repro.trace.replay import PARITY_FIELDS, run_to_dict, run_trace

TRACES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "tests", "traces")
GOLDEN = ("treelstm", "random_dag", "serve_smoke_s4", "train_smoke")
SMOKE_GOLDEN = ("treelstm", "random_dag")

HEURISTIC = "h_dtr_eq"
THRASH = 10.0
PROFILES = ("alloc", "cost", "squeeze", "transfer", "mixed")

#: CI gate cells: (trace, profile, rate, budget fraction).  Alloc faults
#: are recoverable by construction (the ladder retries the admission);
#: small cost noise moves charged compute but not feasibility.  Zero
#: unrecovered failures here is the hard smoke gate.
PINNED_CELLS = (
    ("treelstm", "alloc", 0.10, 0.6),
    ("random_dag", "alloc", 0.10, 0.6),
    ("treelstm", "cost", 0.02, 0.6),
    ("random_dag", "cost", 0.02, 0.6),
)
#: Determinism gate: a mixed schedule on this cell must replay
#: bit-identically (victims + events) across runs and engines.
DETERMINISM_CELL = ("treelstm", "mixed", 0.05, 0.6)


def _golden(name: str) -> Log:
    with open(os.path.join(TRACES_DIR, name + ".log")) as f:
        return Log.loads(f.read(), name=name)


def profile_config(profile: str, rate: float, seed: int = 0) -> FaultConfig:
    """Map a scalar rate onto one fault profile's FaultConfig."""
    if profile == "alloc":
        return FaultConfig(seed=seed, alloc_rate=rate)
    if profile == "cost":
        return FaultConfig(seed=seed, cost_noise=rate)
    if profile == "squeeze":
        return FaultConfig(seed=seed, budget_shrink=min(2 * rate, 0.9),
                           budget_period=64)
    if profile == "transfer":
        return FaultConfig(seed=seed, transfer_rate=rate, spike_rate=rate,
                           prefetch_rate=rate)
    if profile == "mixed":
        return FaultConfig(seed=seed, alloc_rate=rate, transfer_rate=rate,
                           spike_rate=rate, prefetch_rate=rate,
                           cost_noise=rate / 2,
                           budget_shrink=min(rate, 0.5), budget_period=64)
    raise ValueError(f"unknown fault profile {profile!r}")


def _offload_for(profile: str, peak: float, pinned: float, cost: float):
    """Transfer-class faults need channels to fault: attach the hybrid
    tier for the profiles that rate them."""
    if profile not in ("transfer", "mixed"):
        return None
    span = max(peak - pinned, 0.0)
    bw = 2.0 * peak / max(cost, 1e-12)
    return OffloadConfig(host_budget=span, h2d_bandwidth=bw,
                         d2h_bandwidth=bw)


def _cell(log, profile, rate, budget, peak, pinned, cost, seed=0):
    cfg = profile_config(profile, rate, seed) if rate > 0 else None
    off = _offload_for(profile, peak, pinned, cost)
    return simulate(log, HEURISTIC, budget, thrash_factor=THRASH,
                    offload=off, faults=cfg,
                    recovery=RecoveryConfig() if cfg is not None else None)


def run_grid(smoke: bool = False) -> list[dict]:
    traces = SMOKE_GOLDEN if smoke else GOLDEN
    rates = (0.0, 0.05) if smoke else (0.0, 0.02, 0.1)
    fracs = (0.6,) if smoke else (0.7, 0.5)
    rows: list[dict] = []
    for name in traces:
        log = _golden(name)
        peak, cost = measure_baseline(log)
        pinned = log.pinned_bytes()
        for frac in fracs:
            budget = resolve_budget(frac, peak, pinned, "activation")
            for profile in PROFILES:
                for rate in rates:
                    r = _cell(log, profile, rate, budget, peak, pinned,
                              cost)
                    rows.append({"trace": name, "profile": profile,
                                 "rate": rate, "fraction": frac,
                                 **run_to_dict(r)})
    return rows


def survival(rows: list[dict]) -> list[dict]:
    """Survival fraction + degraded overhead per (profile, rate)."""
    cells: dict[tuple, list[dict]] = {}
    base: dict[tuple, dict] = {}
    for r in rows:
        if r["rate"] == 0.0:
            base[(r["trace"], r["profile"], r["fraction"])] = r
        cells.setdefault((r["profile"], r["rate"]), []).append(r)
    out = []
    for (profile, rate), rs in sorted(cells.items()):
        if rate == 0.0:
            continue
        ok = [r for r in rs if r["ok"]]
        ratios = []
        for r in ok:
            b = base.get((r["trace"], r["profile"], r["fraction"]))
            if b and b["ok"] and b["overhead"]:
                ratios.append(r["overhead"] / b["overhead"])
        out.append({
            "profile": profile, "rate": rate, "cells": len(rs),
            "survived": len(ok),
            "survival": round(len(ok) / max(len(rs), 1), 4),
            "degradations": sum(r["degradations"] for r in rs),
            "mean_overhead_ratio": round(sum(ratios) / len(ratios), 4)
            if ratios else None})
    return out


# ---------------------------------------------------------------------------
# Smoke gates
# ---------------------------------------------------------------------------

def gate_zero_rate_exact() -> dict:
    """Attaching an all-zero FaultConfig must be bit-exact with no config."""
    cells, ok = [], True
    zero = FaultConfig(seed=3)   # every rate 0 -> schedule never attaches
    for name in SMOKE_GOLDEN:
        log = _golden(name)
        peak, _ = measure_baseline(log)
        pinned = log.pinned_bytes()
        for frac in (0.8, 0.5):
            budget = resolve_budget(frac, peak, pinned, "activation")
            plain_res, plain_vic = run_trace(log, HEURISTIC, budget,
                                             thrash_factor=THRASH)
            zero_res, zero_vic = run_trace(log, HEURISTIC, budget,
                                           thrash_factor=THRASH,
                                           faults=zero)
            bad = [f for f in PARITY_FIELDS
                   if getattr(plain_res, f) != getattr(zero_res, f)]
            if plain_vic != zero_vic:
                bad.append("victims")
            if zero_res.degradations or zero_res.events:
                bad.append("spurious_events")
            ok = ok and not bad
            cells.append({"trace": name, "fraction": frac,
                          "mismatches": bad})
    return {"ok": ok, "cells": cells}


def gate_pinned_survival(rows: list[dict]) -> dict:
    """Zero unrecovered failures at the pinned smoke cells."""
    cells, ok = [], True
    for trace, profile, rate, frac in PINNED_CELLS:
        log = _golden(trace)
        peak, cost = measure_baseline(log)
        pinned = log.pinned_bytes()
        budget = resolve_budget(frac, peak, pinned, "activation")
        r = _cell(log, profile, rate, budget, peak, pinned, cost)
        ok = ok and r.ok
        cells.append({"trace": trace, "profile": profile, "rate": rate,
                      "fraction": frac, "ok": r.ok,
                      "degradations": r.degradations,
                      "error": r.error[:80]})
    return {"ok": ok, "cells": cells}


def gate_determinism() -> dict:
    """Pinned mixed schedule: identical across runs and engines."""
    trace, profile, rate, frac = DETERMINISM_CELL
    log = _golden(trace)
    peak, cost = measure_baseline(log)
    pinned = log.pinned_bytes()
    budget = resolve_budget(frac, peak, pinned, "activation")
    cfg = profile_config(profile, rate)
    off = _offload_for(profile, peak, pinned, cost)
    runs = [run_trace(log, HEURISTIC, budget, thrash_factor=THRASH,
                      offload=off, faults=cfg, recovery=RecoveryConfig(),
                      index=idx) for idx in (True, True, False)]
    (r1, v1), (r2, v2), (r3, v3) = runs
    repeat_ok = (v1 == v2 and r1.events == r2.events
                 and r1.degradations == r2.degradations)
    engine_ok = (v1 == v3 and r1.events == r3.events
                 and all(getattr(r1, f) == getattr(r3, f)
                         for f in PARITY_FIELDS))
    return {"ok": repeat_ok and engine_ok, "repeat_ok": repeat_ok,
            "engine_ok": engine_ok, "cell": list(DETERMINISM_CELL),
            "victims": len(v1), "events": len(r1.events),
            "degradations": r1.degradations}


def run(smoke: bool = False, out: str = "BENCH_faults.json") -> dict:
    rows = run_grid(smoke=smoke)
    gates = {"zero_rate_exact": gate_zero_rate_exact(),
             "pinned_survival": gate_pinned_survival(rows),
             "determinism": gate_determinism()}
    gates["ok"] = all(g["ok"] for g in gates.values()
                      if isinstance(g, dict))
    report = {"gates": gates, "rows": rows, "survival": survival(rows),
              "smoke": bool(smoke), "heuristic": HEURISTIC,
              "thrash_factor": THRASH}
    with open(out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True, allow_nan=False)
    print(f"perf_faults: {len(rows)} cells -> {out}; "
          f"zero_rate {'OK' if gates['zero_rate_exact']['ok'] else 'FAILED'}"
          f", pinned {'OK' if gates['pinned_survival']['ok'] else 'FAILED'}"
          f", determinism "
          f"{'OK' if gates['determinism']['ok'] else 'FAILED'}")
    for s in report["survival"]:
        print(f"  {s['profile']}@{s['rate']}: "
              f"survival={s['survival']} ({s['survived']}/{s['cells']}) "
              f"degradations={s['degradations']} "
              f"overhead_ratio={s['mean_overhead_ratio']}")
    return report


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid + hard gates (CI)")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args(argv)
    report = run(smoke=args.smoke, out=args.out)
    if args.smoke and not report["gates"]["ok"]:
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
