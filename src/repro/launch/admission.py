"""Serve-side admission control and preemption-with-requeue.

The continuous-batching loop in ``launch.serve`` historically had no
failure handling: a request whose KV cache could not fit simply could not
exist — slot width was the only limit, and memory pressure was someone
else's problem.  This module gives the loop the same graceful-degradation
contract the DTR runtime got in ``repro.faults``:

  * **Admission control** — each request is priced at its *projected* KV
    footprint (``(prompt + gen) tokens x per-token KV bytes``, what a paged
    allocator would have to guarantee to finish the request without a
    mid-decode OOM).  A request is admitted only when the projected bytes
    of all active slots plus its own fit the KV budget.

  * **Preemption** — when an eligible request does not fit, the controller
    preempts the *cheapest-to-rematerialize* active slots: victims are
    ranked by replayed-compute-per-freed-KV-byte (``tokens_done /
    projected_bytes``), the same key family the runtime's eviction index
    orders storages by (replay cost per byte); at slot counts the scan is
    exact and O(slots).  A preempted request loses its progress — exactly
    a DTR eviction of its KV chunks — and is requeued.

  * **Bounded retries + backoff** — each requeue costs a retry and delays
    the request's next eligibility by ``backoff_steps * 2**(retries-1)``
    decode steps (capped).  Requests out of retries are never chosen as
    victims; a request whose projected bytes exceed the whole budget is
    rejected up front.  Because every preemption consumes a retry, total
    preemptions are bounded by ``max_retries x requests`` — no livelock.

  * **Chaos coupling** — an optional ``repro.faults.FaultSchedule`` drives
    mid-run budget squeezes (a co-tenant stealing device memory): the
    effective budget follows the schedule's square wave, and ``enforce``
    preempts already-running slots to get back under it.

Every decision lands in ``events`` (same structured shape as
``DTRRuntime.events``), and ``counters()`` reports the per-request
completed / requeued / rejected accounting the serve driver prints.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: Admission decisions.
ADMIT, WAIT, REJECT = "admit", "wait", "reject"


@dataclass
class Ticket:
    """Admission-facing view of one request (the prompt stays with the
    serve loop; the controller only prices and schedules)."""

    rid: int
    prompt_len: int
    gen: int
    retries: int = 0
    eligible_step: int = 0

    @property
    def tokens(self) -> int:
        return self.prompt_len + self.gen


class AdmissionController:
    """KV-budget admission + cheapest-first preemption for the serve loop.

    ``kv_budget`` and ``per_token_bytes`` are in the same (byte) units;
    ``faults`` is an optional ``repro.faults.FaultSchedule`` whose budget
    squeeze (if configured) modulates the effective budget by decode step.
    """

    def __init__(self, kv_budget: float, per_token_bytes: float, *,
                 max_retries: int = 3, backoff_steps: int = 8,
                 backoff_cap: int = 256, faults=None) -> None:
        if kv_budget <= 0 or per_token_bytes <= 0:
            raise ValueError("kv_budget and per_token_bytes must be > 0")
        self.kv_budget = float(kv_budget)
        self.per_token_bytes = float(per_token_bytes)
        self.max_retries = int(max_retries)
        self.backoff_steps = int(backoff_steps)
        self.backoff_cap = int(backoff_cap)
        self.faults = faults
        self._factor = 1.0
        self.admitted = 0
        self.completed = 0
        self.requeued = 0
        self.rejected = 0
        self.preemptions = 0
        self.events: list[dict] = []

    # -- pricing ---------------------------------------------------------
    def projected_bytes(self, t: Ticket) -> float:
        return t.tokens * self.per_token_bytes

    def remat_key(self, t: Ticket, tokens_done: int) -> float:
        """Replay cost per freed KV byte — lower is cheaper to preempt."""
        return tokens_done / max(self.projected_bytes(t), 1e-12)

    def effective_budget(self, step: int) -> float:
        """KV budget at ``step``, after any injected squeeze."""
        if self.faults is not None and self.faults.cfg.squeezes:
            f = self.faults.budget_factor(step)
            if f != self._factor:
                self._factor = f
                self._event("budget_shrink" if f < 1.0 else "budget_restore",
                            step=step, factor=f)
        return self.kv_budget * self._factor

    # -- decisions -------------------------------------------------------
    def decide(self, ticket: Ticket, active: dict, step: int):
        """Admission decision for ``ticket`` against ``active`` slots.

        ``active`` maps slot index -> ``(Ticket, tokens_done)``.  Returns
        ``(ADMIT, [victim slots])`` (empty list = plain admit),
        ``(WAIT, [])`` or ``(REJECT, [])``.  Choosing victims does NOT
        mutate state — the caller preempts and then calls ``requeue``.
        """
        if ticket.eligible_step > step:
            return WAIT, []
        need = self.projected_bytes(ticket)
        if need > self.kv_budget:
            # Structurally impossible: exceeds the unsqueezed capacity of
            # an empty system.  Transient squeezes only make requests WAIT.
            self.rejected += 1
            self._event("reject", rid=ticket.rid, step=step, need=need,
                        budget=self.kv_budget)
            return REJECT, []
        budget = self.effective_budget(step)
        if need > budget:
            return WAIT, []
        used = sum(self.projected_bytes(t) for t, _ in active.values())
        if used + need <= budget:
            self.admitted += 1
            return ADMIT, []
        # Preempt cheapest-to-rematerialize slots until the ticket fits.
        # Victims must have retries left (tossing work only to reject the
        # request at requeue time would waste both); ties break on lower
        # slot index, so the choice is deterministic.
        ranked = sorted(
            ((self.remat_key(t, done), slot)
             for slot, (t, done) in active.items()
             if t.retries < self.max_retries),
            key=lambda kv: (kv[0], kv[1]))
        victims = []
        for _, slot in ranked:
            victims.append(slot)
            used -= self.projected_bytes(active[slot][0])
            if used + need <= budget:
                self.admitted += 1
                self.preemptions += len(victims)
                return ADMIT, victims
        return WAIT, []

    def enforce(self, active: dict, step: int) -> list:
        """Slots to preempt so current usage fits a squeezed budget.

        Cheapest-to-rematerialize first; requests out of retries are
        spared (they would be rejected, losing finished work for nothing
        — the squeeze model is a transient co-tenant, not a hard cap).
        """
        budget = self.effective_budget(step)
        used = sum(self.projected_bytes(t) for t, _ in active.values())
        if used <= budget:
            return []
        ranked = sorted(
            ((self.remat_key(t, done), slot)
             for slot, (t, done) in active.items()
             if t.retries < self.max_retries),
            key=lambda kv: (kv[0], kv[1]))
        victims = []
        for _, slot in ranked:
            if used <= budget:
                break
            victims.append(slot)
            used -= self.projected_bytes(active[slot][0])
        self.preemptions += len(victims)
        return victims

    def requeue(self, ticket: Ticket, step: int) -> None:
        """Record a preemption: bounded retry + exponential backoff."""
        ticket.retries += 1
        delay = min(self.backoff_steps * (2 ** (ticket.retries - 1)),
                    self.backoff_cap)
        ticket.eligible_step = step + delay
        self.requeued += 1
        self._event("preempt_requeue", rid=ticket.rid, step=step,
                    retries=ticket.retries, eligible=ticket.eligible_step)

    def retire(self, ticket: Ticket) -> None:
        self.completed += 1

    # -- accounting ------------------------------------------------------
    def _event(self, kind: str, **fields) -> None:
        self.events.append(dict(kind=kind, **fields))

    def counters(self) -> dict:
        return {"admitted": self.admitted, "completed": self.completed,
                "requeued": self.requeued, "rejected": self.rejected,
                "preemptions": self.preemptions}
