"""Train/serve step builders with production sharding.

``make_train_step``: value_and_grad -> clip -> optimizer, with optional
gradient accumulation (scan over microbatches, f32 accumulators) — the
standard overlap structure (each microbatch's backward overlaps the implicit
DP reduction of the previous one under the XLA latency-hiding scheduler).

``state_shardings``: NamedShardings for (params, opt_state) from the ParamInfo
tree — optimizer states inherit the param's logical axes; Adafactor's factored
moments drop the corresponding dim; ZeRO-1 additionally shards states over the
data axes via the param's fsdp_dim.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import ParamInfo, param_pspec, pspec
from ..models import model as M
from ..models.config import ModelConfig
from ..optim import OptState, apply_updates, clip_by_global_norm
from ..optim.optimizers import Optimizer


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt: Optimizer, grad_accum: int = 1,
                    max_grad_norm: float = 1.0):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics)."""

    def loss_of(p, b):
        return M.loss_fn(cfg, p, b)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape(grad_accum,
                                        x.shape[0] // grad_accum,
                                        *x.shape[1:]), b)

            mb = micro(batch)

            def body(acc, b):
                l, g = jax.value_and_grad(loss_of)(params, b)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(lambda a, x:
                                     a + x.astype(jnp.float32), acc_g, g)), \
                    None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss_sum, grads), _ = jax.lax.scan(body, zero, mb)
            loss = loss_sum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """One-token decode step (the lowering target for decode_* shapes)."""

    def serve_step(params, cache, token, pos, img_embed=None):
        logits, cache = M.decode_step(cfg, params, token, cache, pos,
                                      img_embed=img_embed)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def serve_step_structs(arch: str, *, smoke: bool = True, slots: int = 4,
                       max_len: int = 64):
    """(cfg, example_args) for tracing ``make_serve_step`` without params.

    The args are ``ShapeDtypeStruct`` trees, so the step can be lowered or
    jaxpr-captured (``repro.trace``) with zero parameter allocation.
    """
    from .. import configs
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    params = M.param_structs(cfg)
    cache = M.cache_structs(cfg, slots, max_len)
    token = jax.ShapeDtypeStruct(
        (slots, 1) if not cfg.n_codebooks else (slots, 1, cfg.n_codebooks),
        np.dtype("int32"))
    pos = jax.ShapeDtypeStruct((), np.dtype("int32"))
    return cfg, (params, cache, token, pos)


# ---------------------------------------------------------------------------
# Sharding of the full train state
# ---------------------------------------------------------------------------

def _opt_state_infos(opt_name: str, defs, zero1: bool):
    """ParamInfo tree for the optimizer's inner state."""

    def promote(info: ParamInfo) -> ParamInfo:
        # ZeRO-1: force state sharding over data via fsdp_dim.
        return ParamInfo(info.shape, "float32", info.axes,
                         fsdp_dim=info.fsdp_dim, init_scale=0.0)

    is_info = lambda x: isinstance(x, ParamInfo)  # noqa: E731
    if opt_name == "adamw":
        # Layout matches optimizers.adamw: {"m": tree, "v": tree}.
        return {"m": jax.tree.map(promote, defs, is_leaf=is_info),
                "v": jax.tree.map(promote, defs, is_leaf=is_info)}
    if opt_name == "sgdm":
        return jax.tree.map(promote, defs, is_leaf=is_info)
    if opt_name == "adafactor":
        def one(info: ParamInfo):
            if len(info.shape) >= 2:
                axes = info.axes or (None,) * len(info.shape)
                vr = ParamInfo(info.shape[:-1], "float32", axes[:-1],
                               init_scale=0.0)
                vc = ParamInfo(info.shape[:-2] + info.shape[-1:],
                               "float32", axes[:-2] + axes[-1:],
                               init_scale=0.0)
                return {"vr": vr, "vc": vc}
            return {"v": ParamInfo(info.shape, "float32", info.axes,
                                   init_scale=0.0)}
        return jax.tree.map(one, defs, is_leaf=is_info)
    raise ValueError(opt_name)


def state_shardings(cfg: ModelConfig, mesh: Mesh, opt_name: str,
                    fsdp: bool = False, zero1: bool = True):
    """(param_shardings, opt_state_shardings) NamedSharding trees."""
    defs = M.param_defs(cfg)

    def of(info: ParamInfo, force_fsdp: bool):
        return NamedSharding(
            mesh, param_pspec(info, mesh=mesh, fsdp=fsdp or force_fsdp))

    p_sh = jax.tree.map(lambda i: of(i, False), defs,
                        is_leaf=lambda x: isinstance(x, ParamInfo))
    o_infos = _opt_state_infos(opt_name, defs, zero1)
    o_sh = jax.tree.map(lambda i: of(i, zero1), o_infos,
                        is_leaf=lambda x: isinstance(x, ParamInfo))
    scalar = NamedSharding(mesh, P())
    return p_sh, OptState(step=scalar, inner=o_sh)


def opt_state_structs(cfg: ModelConfig, opt_name: str):
    """ShapeDtypeStruct tree of the optimizer state (dry-run input)."""
    defs = M.param_defs(cfg)
    infos = _opt_state_infos(opt_name, defs, zero1=True)
    structs = jax.tree.map(
        lambda i: jax.ShapeDtypeStruct(i.shape, np.dtype(i.dtype)),
        infos, is_leaf=lambda x: isinstance(x, ParamInfo))
    return OptState(step=jax.ShapeDtypeStruct((), np.dtype("int32")),
                    inner=structs)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs: dict):
    def of(struct):
        ndim = len(struct.shape)
        axes = ["batch"] + [None] * (ndim - 1)
        return NamedSharding(mesh, pspec(*axes, mesh=mesh))
    return jax.tree.map(of, specs)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    defs = M.cache_defs(cfg, batch, max_len)
    return jax.tree.map(
        lambda i: NamedSharding(mesh, param_pspec(i, mesh=mesh, fsdp=False)),
        defs, is_leaf=lambda x: isinstance(x, ParamInfo))
