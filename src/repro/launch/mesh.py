"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run sets XLA_FLAGS before any jax initialization.

Single pod:  (16, 16)      axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    assert len(devices) >= n, (
        f"need {n} devices for mesh {shape}, have {len(devices)} — the "
        f"dry-run must set --xla_force_host_platform_device_count")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    devices = jax.devices()
    n = len(devices)
    model_axis = min(model_axis, n)
    data_axis = n // model_axis
    return Mesh(
        np.asarray(devices[: data_axis * model_axis]).reshape(
            data_axis, model_axis),
        ("data", "model"))
