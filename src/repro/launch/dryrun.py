import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh (16,16) single-pod or (2,16,16) multi-pod,
  2. constructs ShapeDtypeStruct stand-ins (no allocation) for params,
     optimizer state, data batch / KV caches, with NamedShardings attached,
  3. ``jax.jit(step).lower(...).compile()`` — proving the sharding config is
     coherent end-to-end,
  4. records memory_analysis / cost_analysis / parsed collective bytes and
     the roofline terms to experiments/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.analysis.hlo import parse_collectives, xla_cost_dict
from repro.analysis.hlo_cost import analyze as analyze_hlo
from repro.analysis.roofline import (
    model_flops_decode, model_flops_prefill, model_flops_train, roofline)
from repro.data.pipeline import make_batch_specs
from repro.distributed.sharding import mesh_context, pspec
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    batch_shardings, cache_shardings, make_serve_step, make_train_step,
    opt_state_structs, state_shardings)
from repro.models import model as M
from repro.optim import adafactor, adamw

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic context handling: run for SSM/hybrid/
# windowed archs, skip for pure full-attention archs (DESIGN.md §4).
LONG_OK = {"recurrentgemma-2b", "rwkv6-1.6b", "gemma3-1b", "mixtral-8x7b"}

# Arch-specific dry-run settings.
FSDP_ARCHS = {"deepseek-v3-671b", "mixtral-8x7b", "llama-3.2-vision-11b"}
OPTIMIZER = {"deepseek-v3-671b": "adafactor"}
# Gradient accumulation (microbatching): bounds per-step activation memory;
# cost_analysis counts the accumulation loop body once, so per-step roofline
# numbers are rescaled by this factor below.
GRAD_ACCUM = {"deepseek-v3-671b": 8, "mixtral-8x7b": 4,
              "llama-3.2-vision-11b": 4, "musicgen-large": 2}
# bf16 params for the two giants (DeepSeek-V3 trained in FP8; bf16 is the
# conservative TPU equivalent — DESIGN.md §5).
BF16_PARAMS = {"deepseek-v3-671b", "mixtral-8x7b", "llama-3.2-vision-11b"}

_CANONICAL = [
    "recurrentgemma-2b", "smollm-135m", "llama3.2-1b", "qwen2-0.5b",
    "gemma3-1b", "llama-3.2-vision-11b", "musicgen-large", "rwkv6-1.6b",
    "deepseek-v3-671b", "mixtral-8x7b",
]
CELLS = [(a, s) for a in _CANONICAL for s in SHAPES]


def build_cell(arch: str, shape: str, mesh, overrides=None, remat="full",
               extra_cfg=None, grad_accum=None, flash_analytic=False,
               fsdp=None):
    """Lower + compile one cell; returns result dict."""
    spec = SHAPES[shape]
    cfg = configs.get(arch)
    if arch in BF16_PARAMS:
        cfg = cfg.replace(param_dtype="bfloat16")
    cfg = cfg.replace(remat=remat, **(extra_cfg or {}))
    if fsdp is None:
        fsdp = arch in FSDP_ARCHS
    opt_name = OPTIMIZER.get(arch, "adamw")
    chips = int(np.prod(list(mesh.shape.values())))

    rule_overrides = {"seq": "model"} if spec["kind"] == "train" else {}
    if shape == "prefill_32k":
        rule_overrides = {"seq": "model"}
    if shape == "decode_32k":
        # Context parallelism: KV cache sequence dim over the model axis
        # (batch is already over pod×data).
        rule_overrides = {"kv_seq": "model"}
    if shape == "long_500k":
        # Batch=1: all parallelism comes from sharding the 512k context.
        rule_overrides = {"batch": None,
                          "kv_seq": ("pod", "data", "model")}
    rule_overrides.update(overrides or {})

    with mesh_context(mesh, overrides=rule_overrides, fsdp=fsdp):
        p_sh, o_sh = state_shardings(cfg, mesh, opt_name, fsdp=fsdp)
        p_structs = jax.tree.map(
            lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                                sharding=sh),
            M.param_structs(cfg), p_sh)

        if spec["kind"] == "train":
            opt = (adafactor() if opt_name == "adafactor" else
                   adamw(lr=3e-4))
            o_structs = jax.tree.map(
                lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                                    sharding=sh),
                opt_state_structs(cfg, opt_name), o_sh)
            b_specs = make_batch_specs(cfg, spec["batch"], spec["seq"])
            b_sh = batch_shardings(cfg, mesh, b_specs)
            b_structs = jax.tree.map(
                lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                                    sharding=sh),
                b_specs, b_sh)
            ga = grad_accum or GRAD_ACCUM.get(arch, 1)
            step = make_train_step(cfg, opt, grad_accum=ga)
            # Donate params/opt-state: in-place update, halves live bytes.
            jitted = jax.jit(step, out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_structs, o_structs, b_structs)
            mf = model_flops_train(cfg, spec["batch"] * spec["seq"])
            cost_scale = ga
        elif spec["kind"] == "prefill":
            b_specs = make_batch_specs(cfg, spec["batch"], spec["seq"])
            b_sh = batch_shardings(cfg, mesh, b_specs)
            b_structs = jax.tree.map(
                lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                                    sharding=sh),
                b_specs, b_sh)

            def prefill(params, batch):
                logits = M.forward(cfg, params, batch["tokens"],
                                   batch.get("img_embed"))
                return logits[:, -1].astype(jnp.float32)

            lowered = jax.jit(prefill).lower(p_structs, b_structs)
            mf = model_flops_prefill(cfg, spec["batch"] * spec["seq"])
        else:  # decode
            b = spec["batch"]
            c_sh = cache_shardings(cfg, mesh, b, spec["seq"])
            c_structs = jax.tree.map(
                lambda st, sh: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                                    sharding=sh),
                M.cache_structs(cfg, b, spec["seq"]), c_sh)
            tok_shape = (b, 1, cfg.n_codebooks) if cfg.n_codebooks else (b, 1)
            tok = jax.ShapeDtypeStruct(
                tok_shape, np.dtype("int32"),
                sharding=NamedSharding(mesh, pspec("batch", mesh=mesh)
                                       if b > 1 else P()))
            pos = jax.ShapeDtypeStruct((), np.dtype("int32"),
                                       sharding=NamedSharding(mesh, P()))
            serve = make_serve_step(cfg)
            # Donate the KV cache: updated in place across decode steps.
            jitted = jax.jit(serve, donate_argnums=(1,))
            args = [p_structs, c_structs, tok, pos]
            if cfg.cross_attn_dim:
                img = jax.ShapeDtypeStruct(
                    (b, cfg.cross_attn_tokens, cfg.cross_attn_dim),
                    np.dtype("bfloat16"),
                    sharding=NamedSharding(mesh, pspec("batch", mesh=mesh)
                                           if b > 1 else P()))
                lowered = jitted.lower(*args, img)
            else:
                lowered = jitted.lower(*args)
            mf = model_flops_decode(cfg, b)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
        mem = compiled.memory_analysis()
        cost_xla = xla_cost_dict(compiled.cost_analysis())
        hlo = compiled.as_text()
        # Loop-aware analyzer: while bodies (layer scans, grad-accum,
        # blocked attention) weighted by known_trip_count — XLA's own
        # cost_analysis counts each body once (analysis/hlo_cost.py).
        thr = None
        if flash_analytic:
            ga = GRAD_ACCUM.get(arch, 1) if spec["kind"] == "train" else 1
            if grad_accum and spec["kind"] == "train":
                ga = grad_accum
            thr = ga * cfg.n_layers
        hc = analyze_hlo(hlo, flash_tile_threshold=thr)
        cost = hc.as_cost_dict()
        coll = parse_collectives(hlo)   # unweighted, kept for reference
        rt = roofline(cost, hc.collective_bytes, chips,
                      model_flops=mf, per_device=True)

    return {
        "arch": arch, "shape": shape,
        "mesh": dict(mesh.shape), "chips": chips,
        "remat": remat, "fsdp": fsdp, "optimizer": opt_name,
        "grad_accum": locals().get("cost_scale", 1) if spec["kind"] == "train" else 1,
        "rule_overrides": {k: str(v) for k, v in rule_overrides.items()},
        "compile_s": compile_s,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "cost": {k: cost.get(k, 0.0)
                 for k in ("flops", "bytes accessed", "transcendentals")},
        "cost_xla_unscaled": {k: cost_xla.get(k, 0.0)
                              for k in ("flops", "bytes accessed")},
        "collectives": {"total_bytes": hc.collective_bytes,
                        "by_kind": {k: float(v)
                                    for k, v in hc.coll_by_kind.items()},
                        "unweighted": coll.summary()},
        "roofline": rt.as_dict(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of logical-rule overrides")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = CELLS if args.all else [(args.arch, args.shape)]
    overrides = json.loads(args.overrides) if args.overrides else None

    failures = []
    for arch, shape in cells:
        if shape == "long_500k" and arch not in LONG_OK:
            print(f"SKIP {arch} x {shape} (full-attention arch; DESIGN.md)")
            continue
        for multi in meshes:
            mesh = make_production_mesh(multi_pod=multi)
            tag = f"{arch}_{shape}_{'multi' if multi else 'single'}"
            t0 = time.time()
            try:
                res = build_cell(arch, shape, mesh, overrides=overrides,
                                 remat=args.remat)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1, allow_nan=False)
                r = res["roofline"]
                print(f"OK   {tag}: compile={res['compile_s']:.1f}s "
                      f"mem/dev={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                      f"compute={r['compute_s']*1e3:.2f}ms "
                      f"memory={r['memory_s']*1e3:.2f}ms "
                      f"coll={r['collective_s']*1e3:.2f}ms "
                      f"dom={r['dominant']} "
                      f"roofline={r['roofline_frac']*100:.1f}%",
                      flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"FAIL {tag} ({time.time()-t0:.0f}s): {e!r}",
                      flush=True)
                traceback.print_exc(limit=3)
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
