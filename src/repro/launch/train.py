"""Production training driver.

Config-driven: pick an arch, a mesh (production 16×16 / 2×16×16 or a host
mesh for local runs), parallelism knobs (fsdp, seq sharding, grad accum,
remat policy) and run a fault-tolerant training loop: sharded train state,
deterministic seekable data, periodic atomic checkpoints, NaN/divergence
guard with restore, straggler monitoring.

  # local smoke (CPU, host mesh):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50
  # production lowering check without hardware is the dry-run; on a real
  # slice the same flags drive the full mesh:
  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
      --mesh production --batch 256 --seq 4096 --fsdp --remat dtr
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.monitor import (DivergenceGuard, MemoryMonitor,
                                       StragglerMonitor, Timer)
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step, state_shardings
from repro.models import model as M
from repro.optim import adafactor, adamw, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production", "multipod"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="dtr")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="Megatron-style sequence sharding (seq->model)")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    cfg = cfg.replace(remat=args.remat)
    if args.smoke:
        cfg = cfg.replace(dtype="float32")

    mesh = {"host": make_host_mesh,
            "production": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[
        args.mesh]()
    overrides = {"seq": "model"} if args.seq_shard else {}

    opt = (adafactor(lr=args.lr) if args.optimizer == "adafactor"
           else adamw(lr=cosine_schedule(args.lr, warmup=20,
                                         total=args.steps)))

    with mesh_context(mesh, overrides=overrides, fsdp=args.fsdp):
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        opt_state = opt.init(params)
        p_sh, o_sh = state_shardings(cfg, mesh, opt.name, fsdp=args.fsdp)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(
            make_train_step(cfg, opt, grad_accum=args.grad_accum),
            out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))

        n = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n/1e6:.1f}M mesh={dict(mesh.shape)} "
              f"remat={cfg.remat} fsdp={args.fsdp} ga={args.grad_accum}")

        data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                           batch=args.batch, n_codebooks=cfg.n_codebooks)
        ckpt = CheckpointManager(args.ckpt_dir,
                                 every_steps=args.ckpt_every, keep=2)
        monitor = StragglerMonitor()
        memmon = MemoryMonitor()
        guard = DivergenceGuard()

        from repro.alloc import FragStats
        dev0 = jax.local_devices()[0]

        def device_memory():
            """(peak_bytes, frag_stats|None) from the device allocator.

            ``memory_stats`` exposes largest_free_block on TPU/GPU backends;
            CPU returns None — telemetry degrades to peak-bytes only."""
            stats = (dev0.memory_stats() or {}
                     if hasattr(dev0, "memory_stats") else {})
            peak = stats.get("peak_bytes_in_use", 0)
            frag = None
            if "largest_free_block_bytes" in stats:
                limit = stats.get("bytes_limit", 0)
                used = stats.get("bytes_in_use", 0)
                free = max(limit - used, 0)
                largest = stats["largest_free_block_bytes"]
                frag = FragStats(
                    capacity=limit, used=used, free=free,
                    largest_free=largest,
                    frag_ratio=(1 - largest / free) if free else 0.0)
            return peak, frag

        start, restored, extra = ckpt.restore(
            {"params": params, "opt": opt_state})
        if start is not None:
            params = jax.device_put(restored["params"], p_sh)
            opt_state = jax.device_put(restored["opt"], o_sh)
            start += 1
            print(f"resumed at step {start}")
        else:
            start = 0

        prefetch = Prefetcher(data, start_step=start)
        try:
            for step in range(start, args.steps):
                _, host_batch = prefetch.next()
                batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
                with Timer() as t:
                    new_p, new_o, metrics = step_fn(params, opt_state, batch)
                    jax.block_until_ready(metrics["loss"])
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                action = guard.check(loss, gn)
                if action == "skip":
                    print(f"step {step}: bad step ({loss=:.3g}) — skipped")
                    continue
                if action == "restore":
                    s, restored, _ = ckpt.restore(
                        {"params": params, "opt": opt_state})
                    if s is not None:
                        params = jax.device_put(restored["params"], p_sh)
                        opt_state = jax.device_put(restored["opt"], o_sh)
                        print(f"step {step}: restored from {s}")
                    continue
                params, opt_state = new_p, new_o
                st = monitor.record(step, t.seconds, loss, gn)
                peak_bytes, frag = device_memory()
                ms = memmon.record(step, peak_bytes, frag=frag)
                if step % 10 == 0 or step == args.steps - 1:
                    mem = (f" mem {peak_bytes/1e6:.0f}MB"
                           if peak_bytes else "")
                    if frag is not None:
                        mem += (f" free_blk {ms.largest_free/1e6:.0f}MB"
                                f" frag {ms.frag_ratio:.2f}")
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"gnorm {gn:7.3f} {t.seconds*1e3:6.0f} ms"
                          + mem
                          + (" [straggler]" if st.flagged else ""),
                          flush=True)
                ckpt.maybe_save(step, {"params": params, "opt": opt_state},
                                extra={"data_step": step})
        finally:
            prefetch.stop()
        ms = memmon.summary()
        frag_note = ("" if ms["min_largest_free"] is None else
                     f" min_free_blk {ms['min_largest_free']/1e6:.0f}MB"
                     f" max_frag {ms['max_frag_ratio']:.2f}")
        print(f"mem summary: peak {ms['peak_bytes']/1e6:.0f}MB" + frag_note)
    print("done")


if __name__ == "__main__":
    main()
