"""Production serving driver: continuous-batching decode loop.

A request queue feeds a fixed-width decode batch; finished slots are
immediately refilled from the queue (continuous batching).  The step function
is the same `make_serve_step` the dry-run lowers at decode_32k / long_500k
scale; on hardware the mesh flag drives the full slice.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 12 --slots 4 --gen 16

``--capture PATH`` additionally records the executed per-request/slot
operator stream as a DTR log (``repro.trace``): every admission, decode
step, and retirement the loop actually performs is mirrored into the trace,
so budget sweeps replay *this* serving run, not a synthetic stand-in.
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_serve_step
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production", "multipod"])
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch width (continuous batching slots)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16,
                    help="tokens to generate per request")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--capture", default=None, metavar="PATH",
                    help="record the executed operator stream as a DTR "
                         "trace log (repro.trace)")
    ap.add_argument("--kv-budget", type=float, default=None, metavar="FRAC",
                    help="admission control: cap the projected KV footprint "
                         "of admitted requests at FRAC x the full cache "
                         "size; overflow preempts the cheapest-to-"
                         "rematerialize slot and requeues it with bounded "
                         "retries + backoff (default: off)")
    ap.add_argument("--admit-retries", type=int, default=3,
                    help="max requeues per request before rejection")
    ap.add_argument("--admit-backoff", type=int, default=8,
                    help="base requeue backoff in decode steps (doubles "
                         "per retry, capped)")
    ap.add_argument("--chaos-shrink", type=float, default=0.0,
                    help="repro.faults: periodically shrink the admission "
                         "KV budget to this fraction (a co-tenant stealing "
                         "device memory); 0 = off")
    ap.add_argument("--chaos-period", type=int, default=64,
                    help="squeeze period in decode steps")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--offload-sweep", action="store_true",
                    help="after capture, replay the captured trace through "
                         "the hybrid remat-or-offload tier (repro.offload): "
                         "the per-slot KV chunks and activations become "
                         "offload candidates (weights stay pinned)")
    ap.add_argument("--device-fracs", nargs="+", type=float,
                    default=[0.5, 0.3],
                    help="device budgets, as fractions of the activation "
                         "range (offload sweep)")
    ap.add_argument("--host-fracs", nargs="+", type=float,
                    default=[0.0, 0.5, 1.0],
                    help="host-tier budgets, as fractions of the activation "
                         "range; 0 = DTR-only baseline (offload sweep)")
    ap.add_argument("--offload-bw", type=float, default=2.0,
                    help="transfer bandwidth relative to the trace's "
                         "characteristic bandwidth (peak bytes per unit "
                         "baseline compute)")
    args = ap.parse_args(argv)
    if args.offload_sweep and not args.capture:
        ap.error("--offload-sweep needs --capture (it replays the "
                 "captured trace)")

    tracer = None
    if args.capture:
        from repro.trace.capture import (WorkloadTrace,
                                         step_model_from_config)
        tracer = WorkloadTrace(
            step_model_from_config(args.arch, smoke=args.smoke),
            name=f"serve_{args.arch}_s{args.slots}",
            meta={"source": "launch.serve", "arch": args.arch,
                  "slots": args.slots, "requests": args.requests,
                  "gen": args.gen, "smoke": bool(args.smoke)})

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    mesh = {"host": make_host_mesh,
            "production": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[
        args.mesh]()

    rng = np.random.default_rng(0)
    queue = deque(
        (i, rng.integers(0, cfg.vocab, (int(rng.integers(4, 12)),))
         .astype(np.int32)) for i in range(args.requests))

    with mesh_context(mesh):
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        cache = M.init_cache(cfg, args.slots, args.max_len)

        # Optional admission control + preemption-with-requeue
        # (repro.launch.admission): requests are priced at their projected
        # KV footprint against a fraction of the full cache size; a
        # request that cannot fit preempts the cheapest-to-rematerialize
        # slot instead of the loop dying or the request silently queueing
        # forever.  Default off — the loop below is bit-identical without
        # --kv-budget.
        admit = None
        tickets = {}
        if args.kv_budget is not None:
            from repro.launch.admission import (ADMIT, REJECT,
                                                AdmissionController, Ticket)
            cache_bytes = sum(int(x.nbytes) for x in jax.tree.leaves(cache))
            per_tok = cache_bytes / (args.slots * args.max_len)
            chaos = None
            if args.chaos_shrink > 0:
                from repro.faults import FaultConfig, FaultSchedule
                chaos = FaultSchedule(FaultConfig(
                    seed=args.chaos_seed, budget_shrink=args.chaos_shrink,
                    budget_period=args.chaos_period))
            admit = AdmissionController(
                args.kv_budget * cache_bytes, per_tok,
                max_retries=args.admit_retries,
                backoff_steps=args.admit_backoff, faults=chaos)
            tickets = {rid: Ticket(rid, len(prompt), args.gen)
                       for rid, prompt in queue}

        # True continuous batching: each slot carries its own position
        # clock (decode_step accepts a [slots] pos vector — per-slot cache
        # scatter + per-slot masks/rope), so a finished slot is refilled
        # on the very next global step while its neighbors keep decoding.
        slots = [None] * args.slots
        tok = np.zeros((args.slots, 1), np.int32)
        pos = np.zeros(args.slots, np.int32)
        completed = {}
        t0 = time.perf_counter()
        steps = 0

        def reset_slot_cache(cache, i):
            """Zero slot ``i``'s rows so recurrent-state blocks (RWKV /
            RG-LRU carry no ``pos`` and are *not* masked by it) start the
            new request clean.  Attention caches are position-masked, so
            zeroing them is merely hygienic.  Every leaf of
            ``M.init_cache`` is a per-layer scan stack ``[layers, slots,
            ...]`` — the slot axis is always axis 1 (sizes are not used to
            guess it, so a leaf whose later dims happen to equal the slot
            count cannot be zeroed along the wrong axis)."""
            return jax.tree.map(
                lambda x: x.at[:, i].set(0)
                if x.ndim >= 2 and x.shape[1] == args.slots else x,
                cache)

        def admit_into(i, rid, prompt):
            nonlocal cache
            slots[i] = {"rid": rid, "prompt": prompt, "i": 0, "out": []}
            pos[i] = 0
            cache = reset_slot_cache(cache, i)

        def active_map():
            """slot -> (Ticket, tokens processed) for the controller."""
            return {j: (tickets[s["rid"]], int(pos[j]))
                    for j, s in enumerate(slots) if s is not None}

        def preempt(j, tick):
            """Preempt slot ``j``: its KV chunks are dropped (a DTR
            eviction of the whole request) and the request requeues with
            backoff; replaying it later is the rematerialization."""
            nonlocal cache
            s = slots[j]
            admit.requeue(tickets[s["rid"]], tick)
            queue.append((s["rid"], s["prompt"]))
            if tracer is not None and s["i"] > 0:
                tracer.retire(s["rid"], j)
            slots[j] = None
            pos[j] = 0
            cache = reset_slot_cache(cache, j)

        def refill(tick=0):
            nonlocal cache
            fresh = set()   # admitted this pass: not preemption candidates
            for i in range(args.slots):
                if slots[i] is None and queue:
                    if admit is None:
                        rid, prompt = queue.popleft()
                        admit_into(i, rid, prompt)
                        continue
                    # Arrival order, but requests backing off or waiting
                    # for space do not block eligible ones behind them.
                    for k in range(len(queue)):
                        rid, prompt = queue[k]
                        verdict, victims = admit.decide(
                            tickets[rid],
                            {j: v for j, v in active_map().items()
                             if j not in fresh}, tick)
                        if verdict == REJECT:
                            del queue[k]
                            break
                        if verdict == ADMIT:
                            del queue[k]
                            for j in victims:
                                preempt(j, tick)
                            admit_into(i, rid, prompt)
                            fresh.add(i)
                            break

        tick = idle = 0
        while queue or any(s is not None for s in slots):
            if admit is not None:
                # Injected budget squeeze (a co-tenant stole device
                # memory): shed load until usage fits again.
                for j in admit.enforce(active_map(), tick):
                    preempt(j, tick)
            refill(tick)   # mid-stream: neighbors keep their positions
            if not any(s is not None for s in slots):
                if admit is None or not queue:
                    break
                # Everything queued is backing off / waiting out a
                # squeeze: idle ticks pass without decode work.  The
                # guard bounds pathological schedules (e.g. a permanent
                # squeeze no request fits under).
                tick += 1
                idle += 1
                if idle > 10000:
                    for rid, _ in queue:
                        admit.rejected += 1
                        admit._event("reject", rid=rid, step=tick,
                                     reason="idle_guard")
                    queue.clear()
                    break
                continue
            idle = 0
            for i, s in enumerate(slots):
                if s is None:
                    tok[i, 0] = 0
                elif pos[i] < len(s["prompt"]):
                    tok[i, 0] = s["prompt"][pos[i]]
                # else: keep the model-generated token for this slot
            nxt, cache = serve(params, cache,
                               jnp.asarray(tok), jnp.asarray(pos))
            steps += 1
            tick += 1
            nxt_np = np.asarray(nxt)[..., 0] if cfg.n_codebooks else \
                np.asarray(nxt)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                if tracer is not None:
                    if s["i"] == 0:
                        tracer.prefill(s["rid"], i, 1)
                    else:
                        tracer.decode(
                            s["rid"], i, int(pos[i]),
                            phase="prompt" if pos[i] < len(s["prompt"])
                            else "decode")
                    s["i"] += 1
                if pos[i] >= len(s["prompt"]) - 1:
                    s["out"].append(int(nxt_np[i, 0]))
                    tok[i, 0] = nxt_np[i, 0]
                pos[i] += 1
                if len(s["out"]) >= args.gen or pos[i] >= args.max_len:
                    completed[s["rid"]] = s["out"]
                    if tracer is not None:
                        tracer.retire(s["rid"], i)
                    if admit is not None:
                        admit.retire(tickets[s["rid"]])
                    slots[i] = None
                    # the freed slot refills on the next loop iteration —
                    # captured traces now exercise interleaved lifetimes

        dt = time.perf_counter() - t0
        print(f"served {len(completed)}/{args.requests} requests, "
              f"{steps} decode steps, {dt:.2f}s "
              f"({dt/max(steps,1)*1e3:.1f} ms/step batched x{args.slots})")
        if admit is not None:
            c = admit.counters()
            print(f"admission: admitted={c['admitted']} "
                  f"completed={c['completed']} requeued={c['requeued']} "
                  f"rejected={c['rejected']} "
                  f"preemptions={c['preemptions']} "
                  f"(kv_budget={args.kv_budget:.2f}x cache)")
        for rid in sorted(completed)[:4]:
            print(f"  req{rid}: {completed[rid][:10]}...")
        if tracer is not None:
            log = tracer.finish()
            with open(args.capture, "w") as f:
                f.write(log.dumps() + "\n")
            print(f"captured trace {log.name}: {log.op_count()} ops "
                  f"-> {args.capture}")
            if args.offload_sweep:
                _offload_sweep(log, args.device_fracs, args.host_fracs,
                               args.offload_bw)


def _offload_sweep(log, device_fracs, host_fracs, bw_rel,
                   heuristic="h_dtr_eq"):
    """Replay a captured serve trace over a device × host budget grid.

    The host tier gives the serving loop a second lever for its dominant
    memory consumer: per-slot KV chunks (and layer activations) can be
    parked in host memory over the modeled channels instead of being
    recomputed, whichever the two-choice policy prices cheaper.  Budgets
    scan the activation range (weights are pinned and cannot move);
    ``host_frac=0`` is the plain DTR baseline.
    """
    from repro.core.simulator import (measure_baseline, resolve_budget,
                                      simulate)
    from repro.offload import OffloadConfig

    peak, base_cost = measure_baseline(log)
    pinned = log.pinned_bytes()
    span = max(peak - pinned, 0.0)
    bw = bw_rel * peak / max(base_cost, 1e-12)
    print(f"offload sweep [{log.name}]: peak={peak:.4g} pinned={pinned:.4g} "
          f"bw={bw:.4g} bytes/unit-compute")
    for f in device_fracs:
        budget = resolve_budget(f, peak, pinned, "activation")
        for hf in host_fracs:
            if hf <= 0:
                r = simulate(log, heuristic, budget)
                tag = "dtr-only "
            else:
                cfg = OffloadConfig(host_budget=hf * span,
                                    h2d_bandwidth=bw, d2h_bandwidth=bw)
                r = simulate(log, heuristic, budget, offload=cfg)
                tag = f"host={hf:.2f}"
            state = (f"overhead={r.overhead:.3f} "
                     f"(compute {r.slowdown:.3f}x, stall {r.stall_time:.3g}) "
                     f"offloads={r.offloads} fetches={r.fetches} "
                     f"prefetch_hits={r.prefetch_hits}"
                     if r.ok else f"FAIL({r.error[:48]})")
            print(f"  dev={f:.2f} {tag}: {state}")


if __name__ == "__main__":
    main()
