"""Production serving driver: continuous-batching decode loop.

A request queue feeds a fixed-width decode batch; finished slots are
immediately refilled from the queue (continuous batching).  The step function
is the same `make_serve_step` the dry-run lowers at decode_32k / long_500k
scale; on hardware the mesh flag drives the full slice.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 12 --slots 4 --gen 16

``--capture PATH`` additionally records the executed per-request/slot
operator stream as a DTR log (``repro.trace``): every admission, decode
step, and retirement the loop actually performs is mirrored into the trace,
so budget sweeps replay *this* serving run, not a synthetic stand-in.
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_serve_step
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production", "multipod"])
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch width (continuous batching slots)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16,
                    help="tokens to generate per request")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--capture", default=None, metavar="PATH",
                    help="record the executed operator stream as a DTR "
                         "trace log (repro.trace)")
    args = ap.parse_args(argv)

    tracer = None
    if args.capture:
        from repro.trace.capture import (WorkloadTrace,
                                         step_model_from_config)
        tracer = WorkloadTrace(
            step_model_from_config(args.arch, smoke=args.smoke),
            name=f"serve_{args.arch}_s{args.slots}",
            meta={"source": "launch.serve", "arch": args.arch,
                  "slots": args.slots, "requests": args.requests,
                  "gen": args.gen, "smoke": bool(args.smoke)})

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    mesh = {"host": make_host_mesh,
            "production": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[
        args.mesh]()

    rng = np.random.default_rng(0)
    queue = deque(
        (i, rng.integers(0, cfg.vocab, (int(rng.integers(4, 12)),))
         .astype(np.int32)) for i in range(args.requests))

    with mesh_context(mesh):
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        cache = M.init_cache(cfg, args.slots, args.max_len)

        # Per-slot state: (req_id, prompt, consumed, generated, done_at)
        slots = [None] * args.slots
        tok = np.zeros((args.slots, 1), np.int32)
        pos = 0
        completed = {}
        t0 = time.perf_counter()
        steps = 0

        def refill():
            for s in range(args.slots):
                if slots[s] is None and queue:
                    rid, prompt = queue.popleft()
                    # NOTE: per-slot positions require a batched-pos decode
                    # path; this driver uses a shared position clock and
                    # fresh-cache batches per wave (simple + correct).
                    slots[s] = {"rid": rid, "prompt": prompt, "i": 0,
                                "out": []}

        # Wave-based continuous batching: all active slots share the
        # position clock; when every slot finishes, the cache resets and the
        # next wave starts (per-slot position offsets are the next step —
        # noted in DESIGN.md).
        while queue or any(s is not None for s in slots):
            refill()
            cache = M.init_cache(cfg, args.slots, args.max_len)
            pos = 0
            active = [s for s in slots if s is not None]
            if not active:
                break
            horizon = max(len(s["prompt"]) for s in active) + args.gen
            for pos in range(horizon):
                for i, s in enumerate(slots):
                    if s is None:
                        tok[i, 0] = 0
                    elif pos < len(s["prompt"]):
                        tok[i, 0] = s["prompt"][pos]
                    # else: keep model-generated token
                nxt, cache = serve(params, cache,
                                   jnp.asarray(tok), jnp.int32(pos))
                steps += 1
                nxt_np = np.asarray(nxt)[..., 0] if cfg.n_codebooks else \
                    np.asarray(nxt)
                for i, s in enumerate(slots):
                    if s is None:
                        continue
                    if tracer is not None:
                        if s["i"] == 0:
                            tracer.prefill(s["rid"], i, 1)
                        else:
                            tracer.decode(
                                s["rid"], i, pos,
                                phase="prompt" if pos < len(s["prompt"])
                                else "decode")
                        s["i"] += 1
                    if pos >= len(s["prompt"]) - 1:
                        s["out"].append(int(nxt_np[i, 0]))
                        tok[i, 0] = nxt_np[i, 0]
                    if len(s["out"]) >= args.gen:
                        completed[s["rid"]] = s["out"]
                        if tracer is not None:
                            tracer.retire(s["rid"], i)
                        slots[i] = None
            # wave done; loop refills from queue

        dt = time.perf_counter() - t0
        print(f"served {len(completed)}/{args.requests} requests, "
              f"{steps} decode steps, {dt:.2f}s "
              f"({dt/max(steps,1)*1e3:.1f} ms/step batched x{args.slots})")
        for rid in sorted(completed)[:4]:
            print(f"  req{rid}: {completed[rid][:10]}...")
        if tracer is not None:
            log = tracer.finish()
            with open(args.capture, "w") as f:
                f.write(log.dumps() + "\n")
            print(f"captured trace {log.name}: {log.op_count()} ops "
                  f"-> {args.capture}")


if __name__ == "__main__":
    main()
