import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# ^ must precede jax init (same rule as dryrun.py).

"""Perf hillclimbing harness (EXPERIMENTS.md §Perf).

Runs named variants of a dry-run cell — each variant is a hypothesis about
the dominant roofline term — and prints before/after deltas.  Variants are
registered per cell below; results land in experiments/perf/.

  python -m repro.launch.perf --cell llama3.2-1b/train_4k
  python -m repro.launch.perf --cell smollm-135m/train_4k --mesh single
"""
import argparse
import json
import time

from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh

# Each variant: (name, hypothesis, kwargs-for-build_cell)
VARIANTS = {
    # ------------------------------------------------------------------
    # Cell A: worst roofline fraction — smollm (9 heads can't shard the
    # 16-way model axis => attention replicated across model columns).
    # ------------------------------------------------------------------
    "smollm-135m/train_4k": [
        ("baseline", "paper-faithful baseline (remat=full, TP rules)",
         dict(remat="full")),
        ("pure_dp", "135M params fit one chip: map batch over ALL axes "
         "(pod,data,model) — kills attention replication; costs a full-"
         "param all-reduce",
         dict(remat="full",
              overrides={"batch": ("pod", "data", "model"), "seq": None})),
        ("pure_dp_dtr", "pure DP + DTR remat policy (save attn/ffn outs): "
         "recompute only cheap pointwise, memory now abundant",
         dict(remat="dtr",
              overrides={"batch": ("pod", "data", "model"), "seq": None})),
        ("pure_dp_bf16sm", "pure DP + bf16 softmax: halve attention "
         "logit traffic (dominant HBM consumer)",
         dict(remat="dtr", extra_cfg=dict(softmax_f32=False),
              overrides={"batch": ("pod", "data", "model"), "seq": None})),
        ("pure_dp_flash", "pure DP + Pallas flash attention (analytic "
         "HBM model: softmax stays in VMEM; kernel validated in "
         "interpret mode)",
         dict(remat="dtr", flash_analytic=True,
              overrides={"batch": ("pod", "data", "model"), "seq": None})),
    ],
    # ------------------------------------------------------------------
    # Cell B: most collective-bound — deepseek-v3 (FSDP gathers of 671B
    # params x grad-accum microbatches + MoE all-to-all).
    # ------------------------------------------------------------------
    "deepseek-v3-671b/train_4k": [
        ("baseline", "paper-faithful baseline (ga=8, FSDP, remat=full)",
         dict(remat="full")),
        ("ga4", "halve grad-accum: FSDP params gathered 4x instead of 8x "
         "per step (2x less gather traffic; ~2x activation memory)",
         dict(remat="full", grad_accum=4)),
        ("ga4_dtr", "ga=4 + DTR remat policy: planner keeps attn/ffn "
         "outputs (memory headroom from ga exploited to cut recompute)",
         dict(remat="dtr", grad_accum=4)),
        ("ga2_dtr", "push further: ga=2 (needs the DTR policy's memory "
         "discipline to fit)",
         dict(remat="dtr", grad_accum=2)),
    ],
    # ------------------------------------------------------------------
    # Cell D (extra, beyond the required three): collective-bound MoE
    # *inference* — mixtral prefill_32k.
    # ------------------------------------------------------------------
    "mixtral-8x7b/prefill_32k": [
        ("baseline", "sweep defaults (FSDP on, seq sharding)",
         dict(remat="none")),
        ("no_fsdp", "inference weights are read-only: FSDP buys nothing "
         "and costs per-layer gathers; 47B bf16 / 16-way TP = 5.9 GiB "
         "per chip fits without it",
         dict(remat="none", fsdp=False)),
        ("no_fsdp_flash", "+ Pallas flash attention (analytic HBM model)",
         dict(remat="none", fsdp=False, flash_analytic=True)),
    ],
    # ------------------------------------------------------------------
    # Cell C: most representative of the paper's technique — llama3.2-1b
    # train (remat policy directly trades the compute term against the
    # memory term; also memory-dominated via attention softmax traffic).
    # ------------------------------------------------------------------
    "llama3.2-1b/train_4k": [
        ("baseline", "paper-faithful baseline (remat=full)",
         dict(remat="full")),
        ("dtr_policy", "DTR-planned policy (save attn_out+ffn_out): "
         "cuts the rematerialized forward (compute term) at the cost of "
         "saved residuals (memory term) — the paper's tradeoff, planned",
         dict(remat="dtr")),
        ("no_remat", "remat off entirely (upper bound on memory term)",
         dict(remat="none")),
        ("bf16_softmax", "bf16 attention logits: halves the dominant HBM "
         "traffic (softmax round trips)",
         dict(remat="dtr", extra_cfg=dict(softmax_f32=False))),
        ("bf16_no_sp", "bf16 softmax + drop sequence sharding: removes "
         "per-block seq<->heads all-to-alls (collective term) at the cost "
         "of bigger saved activations",
         dict(remat="dtr", extra_cfg=dict(softmax_f32=False),
              overrides={"seq": None})),
        ("flash_no_sp", "Pallas flash attention (analytic VMEM model) + "
         "no seq sharding: memory term without softmax round trips",
         dict(remat="dtr", flash_analytic=True, overrides={"seq": None})),
        ("flash_dp_hybrid", "flash + batch over (pod,data) and heads over "
         "model for the 32-head attention (llama shards cleanly, unlike "
         "smollm)", dict(remat="dtr", flash_analytic=True)),
    ],
}


def run_cell(cell: str, multi_pod: bool, out_dir: str):
    arch, shape = cell.split("/")
    mesh = make_production_mesh(multi_pod=multi_pod)
    os.makedirs(out_dir, exist_ok=True)
    results = []
    base = None
    for name, hypothesis, kw in VARIANTS[cell]:
        t0 = time.time()
        try:
            res = build_cell(arch, shape, mesh, **kw)
            r = res["roofline"]
            row = dict(variant=name, hypothesis=hypothesis,
                       compute_ms=r["compute_s"] * 1e3,
                       memory_ms=r["memory_s"] * 1e3,
                       collective_ms=r["collective_s"] * 1e3,
                       dominant=r["dominant"],
                       step_ms=r["step_time_s"] * 1e3,
                       roofline=r["roofline_frac"],
                       mem_gib=res["memory"]["peak_bytes_per_device"] / 2**30,
                       wall_s=time.time() - t0)
            tag = f"{arch}_{shape}_{name}"
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1, allow_nan=False)
        except Exception as e:
            row = dict(variant=name, hypothesis=hypothesis, error=repr(e))
        results.append(row)
        if name == "baseline" and "error" not in row:
            base = row
        _print_row(row, base)
    return results


def _print_row(row, base):
    if "error" in row:
        print(f"{row['variant']:16s} FAILED: {row['error'][:120]}")
        return
    d = ""
    if base is not None and base is not row:
        d = f"  step {row['step_ms']/base['step_ms']-1:+.1%} vs baseline"
    print(f"{row['variant']:16s} comp={row['compute_ms']:8.1f}ms "
          f"mem={row['memory_ms']:8.1f}ms coll={row['collective_ms']:8.1f}ms "
          f"dom={row['dominant']:10s} step={row['step_ms']:8.1f}ms "
          f"roofline={row['roofline']*100:5.1f}% "
          f"hbm={row['mem_gib']:5.1f}GiB{d}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(VARIANTS))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    print(f"== {args.cell} ({args.mesh}-pod) ==")
    run_cell(args.cell, args.mesh == "multi", args.out)


if __name__ == "__main__":
    main()
