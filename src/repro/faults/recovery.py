"""Recovery-ladder configuration for graceful degradation under pressure.

The DTR runtime's failure modes are cliffs: a failed allocation raises
``OOMError`` and a remat livelock runs straight into the ``ThrashError``
compute limit.  With a :class:`RecoveryConfig` attached the runtime
instead escalates through a ladder of increasingly drastic — but always
deterministic — degradations before giving up:

1. **prefetch reclaim** (always on, pre-existing): cancel in-flight
   prefetch-back reservations holding speculative device bytes;
2. **pool compaction**: in contiguous-pool mode, slide resident blocks
   down to coalesce free space (a moving allocator's defrag pass) — this
   can rescue window-OOMs where free bytes exist but no contiguous span;
3. **forced offload**: bypass the two-choice ``wants_offload`` key and
   move the cheapest-to-transfer evictable storage to the host tier
   regardless of its recompute price, freeing device blocks without
   losing contents;
4. **heuristic escalation**: switch the eviction heuristic mid-run to
   the next entry of ``escalation_chain`` and retry (also the thrash
   guard's lever — see below).

Every rung taken is recorded as a structured degradation event in
``DTRRuntime.events`` (and surfaced in ``RunResult``), so sweeps can
distinguish a clean run from a degraded-but-surviving one.

The **thrash guard** watches a sliding window of executed ops: when less
than ``1/thrash_ratio`` of a window's charged compute was first-execution
progress (the signature of a remat livelock), it escalates the heuristic
instead of letting the run slam into the ``ThrashError`` cliff.

None of this fires on a runtime constructed without a config (the
default), so fault-free replays stay bit-exact with the pre-ladder
engine.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RecoveryConfig:
    """Which rungs of the ladder are armed, and the thrash-guard shape."""

    compaction: bool = True
    forced_offload: bool = True
    escalation: bool = True
    #: heuristics tried, in order, by ladder rung 4 and the thrash guard.
    #: Entries equal to the current heuristic (or, under the hybrid
    #: offload policy, entries that are not cost-aware) are skipped.
    escalation_chain: tuple[str, ...] = ("h_dtr_local", "h_lru", "h_size")
    #: on an injected allocation fault, evict down to ``alloc_headroom *
    #: need`` extra free bytes before retrying (how real caching
    #: allocators respond to a failed cudaMalloc: free more than asked).
    alloc_headroom: float = 1.0
    thrash_guard: bool = True
    #: sliding-window length, in executed ops
    thrash_window_ops: int = 256
    #: trip when window charged compute exceeds ``thrash_ratio`` x the
    #: window's first-execution (forward-progress) compute
    thrash_ratio: float = 20.0
