"""Deterministic fault injection for the DTR runtime (``repro.faults``).

Production memory systems fail in ways the happy-path simulator never
exercises: transient allocator failures (the device allocator's own
fragmentation, invisible to our byte model), flaky or contended PCIe
links, co-tenants stealing device memory mid-run, and cost models that
misestimate individual operators.  This module injects all four as a
**seeded, replayable schedule** so the runtime's recovery ladder can be
tested differentially:

* every decision is drawn from ``Random(f"{seed}:{kind}:{n}")`` where
  ``n`` is a per-kind event counter — a *pure function of (seed, kind,
  occurrence index)*, independent of query interleaving, so the scan and
  index engines (whose metadata access patterns differ) draw identical
  faults, and two runs of the same schedule are bit-identical;
* fault *sites* are keyed to streams that are themselves bit-exact across
  engines: allocation admissions, channel transfers, operator ids, and
  the executed-op counter — never to heuristic evaluation counts.

Fault classes (all independently rated; see :class:`FaultConfig`):

``alloc``     an allocation attempt that would succeed fails transiently
              (the runtime runs its recovery ladder and retries);
``transfer``  an H2D/D2H channel transfer faults — the engine retries
              with capped exponential backoff, each failed attempt
              occupying the channel for its full duration;
``spike``     a transfer's duration is multiplied (congestion);
``prefetch``  an async prefetch-back is lost — the access falls back to
              a synchronous fetch charged to the stall metric;
``cost``      per-operator lognormal misestimation: the *charged* cost of
              op ``i`` is ``cost_i * exp(noise * g_i)`` while heuristics
              keep scoring the unperturbed estimate (the cost model is
              wrong, the hardware is not);
``budget``    a square-wave co-tenant: for ``budget_duty`` of every
              ``budget_period`` executed ops (after the first period) the
              effective device budget shrinks by ``budget_shrink``.
"""
from __future__ import annotations

from dataclasses import dataclass
from random import Random


@dataclass(frozen=True)
class FaultConfig:
    """Rates and shapes for the fault classes; all default to *off*.

    A config with every class off is ``enabled == False`` and attaching
    it is bit-exact with no schedule at all (the runtime never consults
    a disabled class, so no counters advance).
    """

    seed: int = 0
    #: probability an allocation attempt fails transiently
    alloc_rate: float = 0.0
    #: probability a channel transfer attempt faults (retried with backoff)
    transfer_rate: float = 0.0
    #: probability a transfer's duration is multiplied by ``spike_mult``
    spike_rate: float = 0.0
    spike_mult: float = 8.0
    #: probability an issued prefetch-back is lost (sync-fetch fallback)
    prefetch_rate: float = 0.0
    #: lognormal sigma of per-op charged-cost misestimation
    cost_noise: float = 0.0
    #: budget squeeze: shrink fraction, period (executed ops), duty cycle
    budget_shrink: float = 0.0
    budget_period: int = 0
    budget_duty: float = 0.25
    #: transfer retry shape: failed attempts before the forced success is
    #: capped, and attempt ``k`` waits ``min(backoff_base * 2**k,
    #: backoff_cap)`` clean-durations before retrying.
    max_transfer_retries: int = 4
    backoff_base: float = 0.5
    backoff_cap: float = 8.0

    def __post_init__(self):
        assert 0.0 <= self.alloc_rate <= 1.0
        assert 0.0 <= self.transfer_rate <= 1.0
        assert 0.0 <= self.prefetch_rate <= 1.0
        assert self.max_transfer_retries >= 0
        assert 0.0 <= self.budget_shrink < 1.0

    @property
    def enabled(self) -> bool:
        return (self.alloc_rate > 0 or self.transfer_rate > 0
                or self.spike_rate > 0 or self.prefetch_rate > 0
                or self.cost_noise > 0
                or (self.budget_shrink > 0 and self.budget_period > 0))

    @property
    def squeezes(self) -> bool:
        return self.budget_shrink > 0 and self.budget_period > 0


class FaultSchedule:
    """Stateful per-run instantiation of a :class:`FaultConfig`.

    One schedule belongs to exactly one runtime run (counters are per-run
    state); build a fresh one per ``simulate`` call, exactly like
    ``OffloadEngine`` wraps ``OffloadConfig``.
    """

    def __init__(self, cfg: FaultConfig) -> None:
        assert cfg.enabled, "FaultSchedule requires an enabled FaultConfig"
        self.cfg = cfg
        self._n: dict[str, int] = {}
        self._cost_cache: dict[int, float] = {}
        self._squeeze_seen: set[int] = set()
        #: faults actually fired this run (used to classify a failed run
        #: as "unlucky" rather than "infeasible")
        self.injected = 0

    # -- deterministic draws --------------------------------------------
    def _draw(self, kind: str) -> Random:
        n = self._n.get(kind, 0)
        self._n[kind] = n + 1
        return Random(f"{self.cfg.seed}:{kind}:{n}")

    def counters(self) -> dict[str, int]:
        """Per-kind draw counts (telemetry / determinism assertions)."""
        return dict(self._n)

    # -- allocation ------------------------------------------------------
    def alloc_fault(self) -> bool:
        """One admission attempt: does it fail transiently?"""
        if self.cfg.alloc_rate <= 0:
            return False
        hit = self._draw("alloc").random() < self.cfg.alloc_rate
        if hit:
            self.injected += 1
        return hit

    # -- transfers -------------------------------------------------------
    def transfer_plan(self, channel: str, nbytes: float,
                      clean: float) -> tuple[float, int, float]:
        """Plan one transfer on ``channel`` ("h2d" | "d2h").

        Returns ``(extra, retries, mult)``: ``mult`` is the latency-spike
        duration multiplier (1.0 normally), ``retries`` the number of
        failed attempts before success, and ``extra`` the total extra
        channel occupancy those failures cost — each failed attempt burns
        the full (possibly spiked) duration plus a capped exponential
        backoff wait, exactly like a driver-level retry loop.  ``clean``
        is the fault-free duration of the transfer.
        """
        cfg = self.cfg
        mult = 1.0
        if cfg.spike_rate > 0:
            if self._draw(f"spike:{channel}").random() < cfg.spike_rate:
                mult = cfg.spike_mult
                self.injected += 1
        retries = 0
        extra = 0.0
        if cfg.transfer_rate > 0:
            dur = clean * mult
            while retries < cfg.max_transfer_retries:
                if self._draw(f"xfer:{channel}").random() >= cfg.transfer_rate:
                    break
                backoff = min(cfg.backoff_base * (2.0 ** retries),
                              cfg.backoff_cap)
                extra += dur + backoff * dur
                retries += 1
                self.injected += 1
            # Past the cap the (retries+1)-th attempt is forced to succeed:
            # links recover; the cap bounds the worst case, it does not
            # turn a flaky channel into a dead one.
        return extra, retries, mult

    def prefetch_lost(self) -> bool:
        """Is this issued prefetch-back lost in flight?"""
        if self.cfg.prefetch_rate <= 0:
            return False
        hit = self._draw("prefetch").random() < self.cfg.prefetch_rate
        if hit:
            self.injected += 1
        return hit

    # -- cost-model misestimation ---------------------------------------
    def cost_factor(self, op_id: int) -> float:
        """Charged-cost multiplier for operator ``op_id``.

        Keyed by *operator identity*, not execution count: a misestimated
        op is misestimated consistently, on first execution and on every
        rematerialization — which is what makes heuristic keys (built
        from the unperturbed estimates) genuinely wrong rather than
        merely noisy.
        """
        if self.cfg.cost_noise <= 0:
            return 1.0
        f = self._cost_cache.get(op_id)
        if f is None:
            import math
            g = Random(f"{self.cfg.seed}:cost:{op_id}").gauss(0.0, 1.0)
            f = math.exp(self.cfg.cost_noise * g)
            self._cost_cache[op_id] = f
            # One injection per misestimated operator (not per execution):
            # a run killed under active noise is "unlucky", not infeasible.
            self.injected += 1
        return f

    # -- budget squeeze --------------------------------------------------
    def budget_factor(self, op_index: int) -> float:
        """Effective-budget multiplier at executed-op index ``op_index``.

        A square wave: after a fault-free first period, the leading
        ``budget_duty`` fraction of every period runs at
        ``1 - budget_shrink``.  Pure function of the executed-op counter,
        so both engines squeeze at identical points.
        """
        cfg = self.cfg
        if not cfg.squeezes:
            return 1.0
        if op_index < cfg.budget_period:
            return 1.0
        duty_ops = max(1, int(cfg.budget_period * cfg.budget_duty))
        if (op_index % cfg.budget_period) < duty_ops:
            # One injection per squeeze window (not per query): a run
            # killed inside a squeeze is "unlucky", not infeasible.
            window = op_index // cfg.budget_period
            if window not in self._squeeze_seen:
                self._squeeze_seen.add(window)
                self.injected += 1
            return 1.0 - cfg.budget_shrink
        return 1.0
