"""Fault injection + graceful degradation (``repro.faults``).

Two pieces, composable with every engine configuration:

* :class:`FaultConfig` / :class:`FaultSchedule` — a seeded, fully
  replayable chaos schedule injecting allocation failures, H2D/D2H
  transfer faults and latency spikes, lost prefetches, per-op cost-model
  misestimation, and square-wave budget squeezes (a simulated co-tenant).
  Every draw is a pure function of ``(seed, fault kind, occurrence
  index)``, so the scan and index engines inject identical faults and
  golden differential tests can pin exact victim + recovery sequences.
* :class:`RecoveryConfig` — arms the runtime's degradation ladder
  (prefetch reclaim → pool compaction → forced offload → heuristic
  escalation) and the sliding-window thrash guard that switches
  heuristics mid-run instead of hitting the ``ThrashError`` cliff.

Wire-through: ``simulate(..., faults=FaultConfig(...),
recovery=RecoveryConfig(...))`` and ``run_trace(..., faults=...,
recovery=...)``; attaching faults auto-arms a default ladder.  With no
faults and no recovery attached (the default everywhere) the runtime is
bit-exact with the pre-faults engine.  ``benchmarks/perf_faults.py``
sweeps survival and degraded overhead over the golden corpus and gates
the differential invariants in CI.
"""
from .recovery import RecoveryConfig
from .schedule import FaultConfig, FaultSchedule

__all__ = ["FaultConfig", "FaultSchedule", "RecoveryConfig"]
