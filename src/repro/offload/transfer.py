"""Transfer-cost model for the host offload tier.

Costs are expressed in the runtime's simulated-clock units (the same unit
operator costs use), so offload decisions compare recompute cost against
transfer cost directly.  A transfer of ``n`` bytes on a channel with
bandwidth ``B`` (bytes per cost unit) and fixed latency ``L`` takes
``L + n / B`` units, and channels serialize: a transfer issued while the
channel is busy starts when the previous one completes (simulated-clock
contention).  H2D (fetch / prefetch-back) and D2H (offload copy-out) are
independent channels, as on real accelerators.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class OffloadConfig:
    """Knobs for the host tier; ``host_budget`` in bytes, rates in bytes
    per simulated cost unit.  ``host_budget == 0`` disables the tier
    entirely (the runtime is constructed without an engine, so behaviour
    is bit-exact with pre-offload engines).

    ``policy``:
      * ``"hybrid"``  — two-choice eviction: per victim, offload iff the
        round-trip transfer cost per byte undercuts the heuristic's
        recompute cost per byte (and the host has room), else evict.
      * ``"offload"`` — always offload when the host has room (evict only
        when it is full); victims are ranked by transfer cost alone.
    """

    host_budget: float = 0.0
    h2d_bandwidth: float = 1.0
    d2h_bandwidth: float = 1.0
    latency: float = 0.0
    policy: str = "hybrid"            # 'hybrid' | 'offload'
    prefetch: bool = True
    #: issue a prefetch once the predicted reuse is within this multiple of
    #: the transfer duration (2.0 = start when the copy could just finish
    #: twice over — slack for predictor error).
    prefetch_lead: float = 2.0

    def __post_init__(self):
        assert self.policy in ("hybrid", "offload"), self.policy
        assert self.h2d_bandwidth > 0 and self.d2h_bandwidth > 0

    @property
    def enabled(self) -> bool:
        return self.host_budget > 0


class Channel:
    """One direction of the PCIe-like link; serializes its transfers."""

    __slots__ = ("bandwidth", "latency", "busy_until", "transfers", "bytes")

    def __init__(self, bandwidth: float, latency: float) -> None:
        self.bandwidth = float(bandwidth)
        self.latency = float(latency)
        self.busy_until = 0.0
        self.transfers = 0
        self.bytes = 0.0

    def duration(self, nbytes: float) -> float:
        return self.latency + nbytes / self.bandwidth

    def transfer(self, now: float, nbytes: float, extra: float = 0.0,
                 mult: float = 1.0) -> float:
        """Schedule ``nbytes`` at simulated time ``now``; returns the
        completion time (>= now + duration when the channel is busy).

        ``mult`` scales the duration (an injected latency spike) and
        ``extra`` adds flat channel occupancy (failed attempts + backoff
        waits of an injected-fault retry loop, ``repro.faults``); the
        defaults make the fault-free path bit-exact with the two-argument
        form."""
        start = now if now > self.busy_until else self.busy_until
        done = start + self.duration(nbytes) * mult + extra
        self.busy_until = done
        self.transfers += 1
        self.bytes += nbytes
        return done


class TransferModel:
    """H2D + D2H channel pair built from an :class:`OffloadConfig`."""

    def __init__(self, cfg: OffloadConfig) -> None:
        self.cfg = cfg
        self.h2d = Channel(cfg.h2d_bandwidth, cfg.latency)
        self.d2h = Channel(cfg.d2h_bandwidth, cfg.latency)

    def roundtrip(self, nbytes: float) -> float:
        """Static D2H + H2D cost estimate for ``nbytes`` — the transfer
        side of the two-choice comparison.  Deliberately contention-free:
        the estimate must be a pure function of size so index keys built
        on it stay valid between discrete events."""
        return (2.0 * self.cfg.latency
                + nbytes / self.cfg.d2h_bandwidth
                + nbytes / self.cfg.h2d_bandwidth)
