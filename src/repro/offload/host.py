"""Capacity-bounded host memory tier.

A byte counter in the spirit of the device-side counter mode: the host
pool holds offloaded storages' bytes (contents preserved) until they are
fetched back, dropped on death/banish, or the run ends.  Fragmentation is
deliberately not modeled host-side — host allocators are paging-backed,
so contiguity is not the binding constraint it is on device.
"""
from __future__ import annotations


class HostTier:
    """Byte-accounted host pool: sid -> resident byte count."""

    __slots__ = ("capacity", "used", "peak", "_resident")

    def __init__(self, capacity: float) -> None:
        self.capacity = float(capacity)
        self.used = 0.0
        self.peak = 0.0
        self._resident: dict[int, float] = {}

    def __contains__(self, sid: int) -> bool:
        return sid in self._resident

    def __len__(self) -> int:
        return len(self._resident)

    def can_fit(self, nbytes: float) -> bool:
        return self.used + nbytes <= self.capacity

    def put(self, sid: int, nbytes: float) -> None:
        assert sid not in self._resident, f"sid {sid} already host-resident"
        assert self.can_fit(nbytes), "host tier overcommitted"
        self._resident[sid] = float(nbytes)
        self.used += nbytes
        if self.used > self.peak:
            self.peak = self.used

    def take(self, sid: int) -> float:
        """Remove ``sid`` from the tier; returns its byte count."""
        nbytes = self._resident.pop(sid)
        self.used -= nbytes
        return nbytes
