"""Hybrid rematerialize-or-offload memory tier (``repro.offload``).

DTR frees device memory only by dropping recomputable bytes; this package
adds the second lever — moving bytes to a capacity-bounded **host tier**
over modeled H2D/D2H channels — and makes eviction a two-choice
``min(recompute cost, round-trip transfer cost)`` decision, with async
prefetch-back driven by a reuse-distance predictor.

Entry points:

* :class:`OffloadConfig` — knobs (host budget, bandwidths, latency,
  policy, prefetch); ``host_budget=0`` disables the tier bit-exactly.
* :class:`OffloadEngine` — mechanism attached to a ``DTRRuntime``.
* :func:`wrap_heuristic` — lifts a base heuristic into the two-choice
  :class:`HybridHeuristic` (or :class:`TransferHeuristic` for the
  offload-only policy), keeping the eviction index's separable contract.
* :func:`reuse_oracle` — exact reuse gaps from a captured trace, the
  validation reference for the EWMA predictor.

``repro.core.simulator.simulate(..., offload=OffloadConfig(...))`` and
``repro.trace.replay.run_trace(..., offload=...)`` wire it through.
"""
from .engine import (HybridHeuristic, OffloadEngine, TransferHeuristic,
                     wrap_heuristic)
from .host import HostTier
from .predictor import ReusePredictor, reuse_oracle, trace_access_stream
from .transfer import Channel, OffloadConfig, TransferModel

__all__ = [
    "Channel", "HostTier", "HybridHeuristic", "OffloadConfig",
    "OffloadEngine", "ReusePredictor", "TransferHeuristic", "TransferModel",
    "reuse_oracle", "trace_access_stream", "wrap_heuristic",
]
