"""Reuse-distance prediction for prefetch-back scheduling.

``ReusePredictor`` keeps an exponentially-weighted moving average of each
storage's inter-access gap in simulated time — a stack-distance-style
estimate built from the access stream the runtime already produces (every
operator execution touches its input storages).  The predicted next use of
an offloaded storage is ``last_access + ewma_gap``; the prefetch pump
issues the H2D copy-back once that lands within the transfer lead time.

``reuse_oracle`` computes the *exact* forward reuse gaps from a captured
trace (`repro.trace` logs record the full operator stream, so replay makes
the future knowable) — the validation reference for the predictor: on
periodic access patterns the EWMA converges to the oracle gap exactly,
and on captured traces every prediction must fall inside the oracle's
observed [min, max] gap for that storage.
"""
from __future__ import annotations


class ReusePredictor:
    """EWMA of per-storage access intervals over the simulated clock."""

    __slots__ = ("alpha", "_last", "_gap")

    def __init__(self, alpha: float = 0.5) -> None:
        self.alpha = float(alpha)
        self._last: dict[int, float] = {}   # sid -> last access time
        self._gap: dict[int, float] = {}    # sid -> EWMA inter-access gap

    def observe(self, sid: int, now: float) -> None:
        prev = self._last.get(sid)
        self._last[sid] = now
        if prev is None or now <= prev:
            # First sighting, or a same-instant re-touch (several inputs of
            # one op can share a storage): no gap information.
            return
        gap = now - prev
        old = self._gap.get(sid)
        self._gap[sid] = gap if old is None else (
            old + self.alpha * (gap - old))

    def predict_next(self, sid: int, now: float):
        """Predicted next-access time, or None without gap history.

        An overdue prediction (already in the past) clamps to ``now`` —
        the access is imminent as far as the predictor knows."""
        gap = self._gap.get(sid)
        if gap is None:
            return None
        t = self._last.get(sid, now) + gap
        return t if t > now else now


def trace_access_stream(log):
    """(op_index, storage) access events of a trace, in execution order.

    Storages are identified by their root tensor name (aliases collapse
    onto the storage they view).  An op "accesses" the storages of its
    input tensors — the same stream the runtime's staleness updates see.
    """
    from ..core.graph import Alias, Call, Constant, Mutate
    root: dict[str, str] = {}
    events: list[tuple[int, str]] = []
    opi = 0
    for ins in log.instrs:
        if isinstance(ins, Constant):
            root[ins.t] = ins.t
        elif isinstance(ins, Alias):
            root[ins.t_out] = (root.get(ins.t_in, ins.t_in)
                               if ins.t_in is not None else ins.t_out)
        elif isinstance(ins, Call):
            for u in ins.inputs:
                events.append((opi, root.get(u, u)))
            opi += 1
        elif isinstance(ins, Mutate):
            for u in ins.inputs:
                events.append((opi, root.get(u, u)))
            for t in ins.mutated:
                root[t] = t     # copy-on-write: fresh storage, same name
            opi += 1
    return events


def reuse_oracle(log):
    """Exact per-storage reuse gaps (in op-index distance) from a trace.

    Returns ``{storage: [gap, ...]}`` — successive differences of the op
    indices at which each storage is used as an input.  This is the
    ground truth the EWMA predictor approximates; see
    ``tests/test_offload.py`` for the validation harness.
    """
    last: dict[str, int] = {}
    gaps: dict[str, list[int]] = {}
    for opi, key in trace_access_stream(log):
        prev = last.get(key)
        if prev is not None and opi > prev:
            gaps.setdefault(key, []).append(opi - prev)
        last[key] = opi
    return gaps
