"""Offload engine: the mechanism behind rematerialize-or-offload eviction.

The engine owns the host tier, the transfer channels, the reuse predictor,
and the per-storage offload records.  The runtime drives it:

  * ``wants_offload``  — the two-choice policy decision at victim time;
  * ``on_offload``     — bookkeeping when a victim's bytes move to host
    (D2H scheduled on the simulated clock, device block already freed);
  * ``begin_fetch`` / ``finish_fetch`` — synchronous fetch-back on access
    (the miss path), stalling the clock until the H2D copy lands;
  * ``pump``           — after each operator, issue prefetch-backs for
    offloaded storages whose predicted reuse is imminent, reserving device
    space without evicting (free-space-only, so prefetch can never cause
    an eviction cascade);
  * ``cancel_one_prefetch`` — under allocation pressure, reclaim an
    in-flight reservation before declaring OOM.

Heuristic composition lives here too: ``wrap_heuristic`` lifts a base
(cost-aware) heuristic into the two-choice ``HybridHeuristic`` whose
score is ``min(recompute score, transfer score)``, or replaces it with
the pure ``TransferHeuristic`` for the offload-only policy.  Both keep
the separable key()/staleness contract, so victim selection stays on the
sublinear eviction index and bit-exact against the linear scan.
"""
from __future__ import annotations

from ..core.heuristics import Heuristic
from .host import HostTier
from .predictor import ReusePredictor
from .transfer import OffloadConfig, TransferModel


class _OffRec:
    """Per-offloaded-storage state while its bytes live on host."""

    __slots__ = ("nbytes", "d2h_done", "defined_tids", "ready_at")

    def __init__(self, nbytes: float, d2h_done: float,
                 defined_tids: tuple[int, ...]) -> None:
        self.nbytes = nbytes
        self.d2h_done = d2h_done          # host copy complete at this time
        self.defined_tids = defined_tids  # views defined at offload time
        self.ready_at = None              # prefetch arrival; None = idle


class OffloadEngine:
    """Host tier + transfer channels + prefetcher, attached to one runtime."""

    def __init__(self, cfg: OffloadConfig) -> None:
        assert cfg.enabled, "OffloadEngine requires host_budget > 0"
        self.cfg = cfg
        self.host = HostTier(cfg.host_budget)
        self.model = TransferModel(cfg)
        self.predictor = ReusePredictor()
        self._recs: dict[int, _OffRec] = {}
        self._base: Heuristic | None = None   # set by wrap_heuristic

    # -- scoring ---------------------------------------------------------
    def roundtrip_cost(self, nbytes: float) -> float:
        return self.model.roundtrip(nbytes)

    def transfer_key(self, s) -> float:
        """Round-trip transfer cost per byte — the offload key family.

        Constant per storage (sizes are immutable), so offload keys never
        go stale: the eviction index computes each once at membership."""
        return self.roundtrip_cost(s.size) / s.size

    # -- two-choice policy ----------------------------------------------
    def wants_offload(self, rt, s) -> bool:
        """Two-choice decision; also the *simultaneous exhaustion* rule.

        A full host tier (``can_fit`` False) deterministically demotes
        every would-be offload to a plain eviction — so device pressure
        with both tiers exhausted falls back to pure DTR and, only when
        no evictable storage remains anywhere, a controlled ``OOMError``.
        There is no evict-from-host path: host contents are dropped only
        on death/banish, never to admit another offload.
        """
        if s.size <= 0 or not self.host.can_fit(s.size):
            return False
        if self.cfg.policy == "offload":
            return True
        # Hybrid: offload iff transfer cost per byte undercuts the base
        # heuristic's recompute cost per byte.  Both sides share the
        # staleness denominator, so comparing keys equals comparing
        # scores — the decision is staleness-free and identical for the
        # scan and index engines (cached e*/ẽ* values are shared).
        return self.transfer_key(s) < self._base.key(rt, s)

    # -- fault injection (repro.faults) ----------------------------------
    def _faulted(self, rt, channel: str, ch, nbytes: float):
        """Plan one possibly-faulted transfer: (extra, mult) for the
        channel, with injected retries/spikes recorded as runtime events.
        Fault-free (no schedule attached) this is exactly (0.0, 1.0)."""
        faults = getattr(rt, "faults", None)
        if faults is None:
            return 0.0, 1.0
        extra, retries, mult = faults.transfer_plan(
            channel, nbytes, ch.duration(nbytes))
        if mult != 1.0:
            rt._event("transfer_spike", channel=channel, mult=mult)
        if retries:
            rt._degrade("transfer_retry", channel=channel,
                        retries=retries, extra=extra)
        return extra, mult

    # -- offload ---------------------------------------------------------
    def on_offload(self, rt, s, defined_tids: tuple[int, ...]) -> None:
        extra, mult = self._faulted(rt, "d2h", self.model.d2h, s.size)
        done = self.model.d2h.transfer(rt.clock, s.size, extra, mult)
        self.host.put(s.sid, s.size)
        self._recs[s.sid] = _OffRec(s.size, done, defined_tids)

    def holds(self, sid: int) -> bool:
        return sid in self._recs

    # -- fetch (sync miss path) ------------------------------------------
    def begin_fetch(self, rt, s) -> float:
        """Schedule the synchronous H2D copy-back; returns the stall.

        Injected channel faults retry with capped exponential backoff
        inside the transfer itself (the whole loop is one synchronous
        wait), so every failed attempt lands on the stall metric."""
        rec = self._recs[s.sid]
        start = rt.clock if rt.clock > rec.d2h_done else rec.d2h_done
        extra, mult = self._faulted(rt, "h2d", self.model.h2d, rec.nbytes)
        done = self.model.h2d.transfer(start, rec.nbytes, extra, mult)
        return done - rt.clock

    def finish_fetch(self, rt, s) -> tuple[int, ...]:
        """Host copy consumed: free host bytes, return the saved views."""
        rec = self._recs.pop(s.sid)
        self.host.take(s.sid)
        return rec.defined_tids

    # -- prefetch ---------------------------------------------------------
    def note_access(self, sid: int, now: float) -> None:
        self.predictor.observe(sid, now)

    def pump(self, rt) -> None:
        """Issue prefetch-backs for offloaded storages predicted to be
        reused within the transfer lead time.  Deterministic: offloaded
        sids are visited in sorted order, and reservations use free space
        only (a full device never triggers evictions from here)."""
        if not self.cfg.prefetch or not self._recs:
            return
        now = rt.clock
        lead = self.cfg.prefetch_lead
        for sid in sorted(self._recs):
            rec = self._recs[sid]
            if rec.ready_at is not None:
                continue
            s = rt.storages[sid]
            if s.dead or s.banished:
                continue
            nxt = self.predictor.predict_next(sid, now)
            if nxt is None:
                continue
            if nxt - now > lead * self.model.h2d.duration(rec.nbytes):
                continue
            faults = getattr(rt, "faults", None)
            if faults is not None and faults.prefetch_lost():
                # The prefetch is lost in flight: never issued, no device
                # reservation, no channel time.  The eventual access takes
                # the synchronous-fetch miss path, charged to the stall
                # metric — the prefetch-failure fallback.
                rt._event("prefetch_lost", sid=sid)
                continue
            if not self._reserve(rt, s):
                continue
            start = now if now > rec.d2h_done else rec.d2h_done
            extra, mult = self._faulted(rt, "h2d", self.model.h2d,
                                        rec.nbytes)
            rec.ready_at = self.model.h2d.transfer(start, rec.nbytes,
                                                   extra, mult)
            rt.prefetch_issued += 1

    def _reserve(self, rt, s) -> bool:
        """Claim device space for a prefetch without evicting."""
        alloc = rt.allocator
        if alloc is not None and alloc.contiguous:
            if not alloc.pool.alloc(s.sid, s.size):
                return False
        else:
            if rt.memory + s.size > rt.effective_budget():
                return False
            if alloc is not None:
                alloc.place(s)
        rt.memory += s.size
        if rt.memory > rt.peak_memory:
            rt.peak_memory = rt.memory
        return True

    def in_flight(self, sid: int) -> bool:
        rec = self._recs.get(sid)
        return rec is not None and rec.ready_at is not None

    def cancel_one_prefetch(self, rt) -> bool:
        """Reclaim one prefetch reservation under allocation pressure.

        The channel time already spent stays spent (wasted bus time, as
        on hardware); the storage reverts to plain offloaded state."""
        for sid in sorted(self._recs):
            rec = self._recs[sid]
            if rec.ready_at is None:
                continue
            rec.ready_at = None
            rt.memory -= rec.nbytes
            if rt.allocator is not None:
                rt.allocator.free(rt.storages[sid])
            rt.prefetch_cancelled += 1
            return True
        return False

    # -- drop (death / banish) -------------------------------------------
    def drop(self, rt, s) -> None:
        """Discard the host copy of ``s`` (died or banished)."""
        rec = self._recs.pop(s.sid)
        self.host.take(s.sid)
        if rec.ready_at is not None:
            # An in-flight prefetch dies with it: release the reservation.
            rt.memory -= rec.nbytes
            if rt.allocator is not None:
                rt.allocator.free(s)
            rt.prefetch_cancelled += 1
        # Plain write on purpose: "offloaded" is not in StorageRec._WATCHED
        # (offload membership moves with "resident", which the runtime
        # flips around every transfer), so this never pings the index —
        # but going through __setattr__ keeps that true by construction if
        # the watched set ever grows.  The drop callers (_kill /
        # _try_banish) mark the sid dirty themselves where needed.
        s.offloaded = False


# ---------------------------------------------------------------------------
# Heuristic composition
# ---------------------------------------------------------------------------

class HybridHeuristic(Heuristic):
    """Two-choice score: ``min(base recompute score, transfer score)``.

    Both sides divide by the same staleness, so the min is equivalent to
    taking the min of the per-byte *keys* — which is exactly the decision
    ``wants_offload`` makes.  The wrapper stays separable: the base key
    changes on the base heuristic's discrete events, and the offload key
    is constant per storage, so the eviction index keeps both as
    side-by-side key families (``hybrid = True`` flips that machinery on)
    and verifies candidates with this score — bit-exact with the scan.
    """

    hybrid = True
    separable = True

    def __init__(self, base: Heuristic, engine: OffloadEngine) -> None:
        if not getattr(base, "cost_aware", False):
            raise ValueError(
                f"hybrid policy needs a cost-aware base heuristic to price "
                f"recomputation; {base.name} is not (use policy='offload')")
        self.base = base
        self.engine = engine
        self.name = f"hybrid:{base.name}"
        self.needs_uf = base.needs_uf
        self.uses_staleness = base.uses_staleness

    def bind(self, rt) -> None:
        if hasattr(self.base, "bind"):
            self.base.bind(rt)

    def offload_key(self, s) -> float:
        return self.engine.transfer_key(s)

    def base_key(self, rt, s) -> float:
        return self.base.key(rt, s)

    def score(self, rt, s) -> float:
        b = self.base.score(rt, s)
        o = self.engine.transfer_key(s)
        if self.uses_staleness:
            o = o / rt.staleness(s)
        return b if b <= o else o

    def key(self, rt, s) -> float:
        b = self.base.key(rt, s)
        o = self.engine.transfer_key(s)
        return b if b <= o else o


class TransferHeuristic(Heuristic):
    """Offload-only policy: rank victims by transfer cost alone.

    ``score = roundtrip(size)/size / staleness`` — evict-to-host the
    stalest, cheapest-to-move bytes.  Keys are constant per storage, so
    the standard staleness-aware band machinery applies unchanged.
    """

    separable = True
    uses_staleness = True

    def __init__(self, engine: OffloadEngine) -> None:
        self.engine = engine
        self.name = "transfer"

    def score(self, rt, s) -> float:
        return self.engine.transfer_key(s) / rt.staleness(s)

    def key(self, rt, s) -> float:
        return self.engine.transfer_key(s)


def wrap_heuristic(base: Heuristic, engine: OffloadEngine) -> Heuristic:
    """Compose ``base`` with the engine per the configured policy."""
    if engine.cfg.policy == "offload":
        h = TransferHeuristic(engine)
        engine._base = base
        return h
    h = HybridHeuristic(base, engine)
    engine._base = base
    return h
