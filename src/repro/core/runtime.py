"""The DTR runtime engine (Figure 1 + Appendix C of the paper).

Storage-centric model: tensors are views of storages; operators are pure;
metadata per storage = size, (cached) local compute cost, last access time,
locks (pending remats), refs (external liveness).  On allocation pressure the
runtime evicts the resident storage minimizing the active heuristic's score,
and rematerializes evicted tensors on access by (recursively) replaying parent
operators.  Supports the paper's deallocation policies: ``ignore``, ``eager``
(evict on refcount zero), and ``banish`` (permanent free + pinning children).

The engine is *simulated-time*: the clock advances by operator cost on each
(re)execution, which reproduces the paper's compute-overhead accounting while
staying deterministic (Appendix E.3 recommends exactly this).  It is also the
execution engine for the *eager* executor (``repro.eager``), which attaches
real JAX buffers to storages via the ``materialize_fn`` / ``free_fn`` hooks.

Victim selection runs through the incremental eviction index by default
(``index=True``; see ``repro.core.evict_index``): a live evictable set plus
verified lazy heaps deliver the same victim as the exhaustive linear scan —
bit-exactly, tie-breaks included — in sublinear time, and cached
``e*``/``ẽ*`` neighborhood costs are invalidated per evicted component
instead of globally.  ``index=False`` selects the linear-scan oracle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from ..faults.recovery import RecoveryConfig
from ..faults.schedule import FaultSchedule
from .evict_index import EvictIndex, ScopedInvalidator
from .unionfind import CostUnionFind


class OOMError(RuntimeError):
    """Raised when an allocation cannot be satisfied by any eviction."""


class ThrashError(RuntimeError):
    """Raised when rematerialization compute exceeds the thrash limit.

    The paper's prototype could hang in deeply recursive rematerializations
    (App. E.3); the simulator aborts instead once total compute passes
    ``compute_limit`` so budget sweeps terminate."""


class BanishedError(RuntimeError):
    """Raised when a banished (permanently freed) tensor is accessed."""


@dataclass
class Operator:
    op_id: int
    name: str
    cost: float
    input_tids: tuple[int, ...]
    output_tids: tuple[int, ...] = ()


@dataclass
class TensorRec:
    tid: int
    name: str
    op: Optional[Operator]          # parent op; None for constants
    sid: int
    is_alias: bool
    defined: bool = True            # materialized & view metadata valid
    refs: int = 1                   # external references


# Storage fields whose writes can change candidate membership or a heap
# key/staleness bound; writes to them notify the attached eviction index.
_WATCHED = frozenset(("resident", "locks", "pinned", "banished", "constant",
                      "last_access", "local_cost"))


@dataclass
class StorageRec:
    sid: int
    size: int
    root_tid: int
    tensor_tids: list[int] = field(default_factory=list)
    resident: bool = True
    locks: int = 0
    pinned: bool = False            # constant or banish-pinned: unevictable
    banished: bool = False
    constant: bool = False
    offloaded: bool = False         # bytes live on the host tier (contents
    #                                 preserved; fetched back on access —
    #                                 NOT an evicted-set member: offloaded
    #                                 storages never join evicted components
    #                                 or e*/ẽ* walks, they transfer back)
    dead: bool = False              # no refs + every child dead/banished:
    #                                 never rematerialized again (pruned
    #                                 from evicted components and e* walks)
    dead_cost: float = 0.0          # aggregated cost of dead subgraphs
    #                                 attached to this (live) storage: e*
    #                                 walks charge it in O(1) instead of
    #                                 traversing the dead cone
    last_access: float = 0.0
    local_cost: float = 0.0         # cached cost(S) = sum of view op costs
    deps: set[int] = field(default_factory=set)       # parent storages
    children: set[int] = field(default_factory=set)   # dependent storages
    uf: int = -1                    # union-find handle (h_eq heuristics)
    uf_joined: bool = False         # local_cost currently counted in uf sum
    refs: int = 0                   # cached sum of view refs

    # Eviction-index backref (class attr so dataclass __init__ writes are
    # silent; EvictIndex.register() sets it per instance).
    _index = None

    def __setattr__(self, name, value):
        if (name in _WATCHED and self._index is not None
                and getattr(self, name, None) != value):
            object.__setattr__(self, name, value)
            self._index.on_storage_event(self, name)
        else:
            object.__setattr__(self, name, value)

    def evictable(self) -> bool:
        return (self.resident and not self.pinned and not self.banished
                and self.locks == 0 and not self.constant)


class DTRRuntime:
    """Greedy online rematerialization engine, parameterized by heuristic."""

    def __init__(
        self,
        budget: float,
        heuristic,
        dealloc: str = "eager",            # 'ignore' | 'eager' | 'banish'
        ignore_small_frac: float = 0.0,     # E.2: skip tensors < frac*mean size
        sample_sqrt: bool = False,          # E.2: search sqrt(n) random sample
        seed: int = 0,
        materialize_fn: Optional[Callable] = None,  # eager-mode hooks
        free_fn: Optional[Callable] = None,
        compute_limit: float = float("inf"),
        allocator=None,                     # repro.alloc.PoolAllocator | None
        index: bool = True,                 # incremental eviction index
        offload=None,                       # repro.offload.OffloadEngine | None
        offload_fn: Optional[Callable] = None,  # eager hook: bytes -> host
        fetch_fn: Optional[Callable] = None,    # eager hook: bytes -> device
        faults=None,                        # repro.faults FaultConfig|Schedule
        recovery: Optional[RecoveryConfig] = None,  # degradation ladder
        sanitize=False,                     # repro.check shadow sanitizer:
        #                                     True = audit every op, int N =
        #                                     audit every N ops (transition
        #                                     hooks always on when enabled)
    ) -> None:
        assert dealloc in ("ignore", "eager", "banish")
        self.budget = float(budget)
        self.heuristic = heuristic
        self.dealloc = dealloc
        self.ignore_small_frac = ignore_small_frac
        self.sample_sqrt = sample_sqrt
        import random as _random
        self._rng = _random.Random(seed)
        self.materialize_fn = materialize_fn
        self.free_fn = free_fn
        self.compute_limit = float(compute_limit)
        # Optional host offload tier (repro.offload).  None => pure DTR:
        # every code path below is bit-exact with pre-offload engines.
        self.offload = offload
        self.offload_fn = offload_fn
        self.fetch_fn = fetch_fn
        # Fault injection (repro.faults).  Accepts a FaultConfig (wrapped
        # into a per-run schedule here) or a ready FaultSchedule; a config
        # with every class off collapses to None, and None everywhere means
        # not a single fault code path runs — bit-exact with the pre-faults
        # engine by construction.
        if faults is not None and not isinstance(faults, FaultSchedule):
            faults = FaultSchedule(faults) if faults.enabled else None
        self.faults = faults
        # Graceful degradation: attaching faults arms a default ladder
        # (injected pressure with no recovery would just be a crash
        # generator); an explicit RecoveryConfig also works fault-free.
        if recovery is None and faults is not None:
            recovery = RecoveryConfig()
        self.recovery = recovery
        #: structured degradation/fault events, surfaced in RunResult
        self.events: list[dict] = []
        self.degradations = 0           # ladder actions taken (not injections)
        self._budget_factor = 1.0       # current squeeze multiplier
        self._remat_counts: dict[int, int] = {}  # sid -> times rematerialized
        self._escalated = 0             # consumed prefix of escalation_chain
        self._thrash_disabled = False   # guard exhausted its chain
        self._w_ops = 0                 # thrash-guard sliding window
        self._w_total = 0.0
        self._w_base = 0.0
        # Shadow sanitizer (repro.check): a pure observer — it reads state
        # through non-mutating, non-counting paths, so a sanitized run is
        # bit-exact with an unsanitized one (tested in tests/test_check.py).
        if sanitize:
            from ..check.sanitizer import attach as _sanitizer_attach
            self.sanitizer = _sanitizer_attach(self, sanitize)
        else:
            self.sanitizer = None

        self.tensors: dict[int, TensorRec] = {}
        self.storages: dict[int, StorageRec] = {}
        self.ops: dict[int, Operator] = {}
        self._next_tid = 0
        self._next_sid = 0
        self._next_oid = 0

        self.clock = 0.0
        self.memory = 0.0
        self.peak_memory = 0.0
        self.total_compute = 0.0        # includes rematerializations
        self.base_compute = 0.0         # first executions only
        self.stall_time = 0.0           # clock spent waiting on transfers
        self.ops_executed = 0           # op (re)plays, unit counting for Thm 3.1
        self.remat_ops = 0
        self.evictions = 0
        self.offloads = 0               # victims moved to host, not dropped
        self.fetches = 0                # synchronous fetch-backs (misses)
        self.prefetch_hits = 0          # accesses served by a prefetch-back
        self.prefetch_issued = 0
        self.prefetch_cancelled = 0
        self.meta_accesses = 0          # Appendix D.3 accounting
        self.victim_picks = 0           # victim selections (flush events)
        self._pending_banish: set[int] = set()
        # Scoped caches for neighborhood costs: entries are dropped by the
        # ScopedInvalidator when (and only when) their evicted component
        # changes — no global version nuke (App. C.5 overhead fix).
        self._estar_cache: dict[int, tuple[float, int]] = {}  # sid->(cost, n)
        self._eq_cache: dict[int, float] = {}
        # ẽ* adjacency snapshots: sid -> union-find handles of its evicted
        # neighbors at last full walk.  Survives component-sum-only events,
        # so an invalidated eq key rebuilds from the incrementally-
        # maintained per-root sums without re-walking the neighborhood.
        self._eq_adj: dict[int, tuple[int, ...]] = {}

        self.uf = CostUnionFind() if getattr(heuristic, "needs_uf", False) else None
        # Evicted-component bookkeeping for amortized-exact splits: member
        # sids and detached-phantom counts per component root.  When half a
        # component is phantoms, its true partition is re-derived
        # (``_uf_rebuild``) — bounding the over-merge drift of the paper's
        # splitting approximation to 2x instead of letting ẽ* balloon
        # without bound on eager-release workloads.
        self._uf_members: dict[int, list[int]] = {}
        self._uf_phantoms: dict[int, int] = {}
        if hasattr(heuristic, "bind"):
            heuristic.bind(self)

        # Incremental victim-selection index.  The linear scan stays as the
        # reference oracle (index=False) and as the automatic fallback for
        # non-separable heuristics (h_rand consumes RNG state per score) and
        # the E.2 sampling approximations, whose sampled candidate pools the
        # heap cannot reproduce bit-exactly.
        self.index: Optional[EvictIndex] = None
        self._invalidator = ScopedInvalidator(self)
        if (index and getattr(heuristic, "separable", False)
                and not sample_sqrt and ignore_small_frac == 0):
            self.index = EvictIndex(self)

        # Optional fragmentation-aware backend: storages map onto contiguous
        # blocks of a simulated address space, and eviction under pressure
        # selects a contiguous window (repro.alloc).  None => byte counter.
        self.allocator = allocator
        if allocator is not None:
            allocator.attach(self)

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def constant(self, size: int, name: str = "const") -> int:
        tid, sid = self._next_tid, self._next_sid
        self._next_tid += 1
        self._next_sid += 1
        t = TensorRec(tid, name, None, sid, is_alias=False)
        s = StorageRec(sid, int(size), tid, [tid], constant=True, pinned=True,
                       last_access=self.clock, refs=1)
        if self.uf is not None:
            s.uf = self.uf.make(0.0)
        self.tensors[tid] = t
        self.storages[sid] = s
        if self.index is not None:
            self.index.register(s)
        self._alloc_storages([s])
        return tid

    def call(
        self,
        op_name: str,
        cost: float,
        input_tids: Sequence[int],
        out_sizes: Sequence[int],
        aliases: Optional[Sequence[Optional[int]]] = None,
        out_names: Optional[Sequence[str]] = None,
    ) -> list[int]:
        """Execute a new pure operator; returns output tensor ids."""
        aliases = list(aliases) if aliases is not None else [None] * len(out_sizes)
        out_names = list(out_names) if out_names else [
            f"{op_name}.{i}" for i in range(len(out_sizes))]
        oid = self._next_oid
        self._next_oid += 1
        op = Operator(oid, op_name, float(cost), tuple(input_tids))
        self.ops[oid] = op

        # Create output tensor/storage records (not yet resident).
        out_tids: list[int] = []
        new_storages: list[StorageRec] = []
        for size, al, nm in zip(out_sizes, aliases, out_names):
            tid = self._next_tid
            self._next_tid += 1
            if al is not None:
                sid = self.tensors[al].sid
                t = TensorRec(tid, nm, op, sid, is_alias=True, defined=False)
                s = self.storages[sid]
                s.tensor_tids.append(tid)
                was_dead = s.dead
                s.local_cost += op.cost
                s.refs += 1
                if self.uf is not None and s.uf_joined:
                    # The component sum tracks member costs incrementally:
                    # the view's cost joins it now, so the later
                    # split_approx subtraction balances.  (Checked before
                    # the revive below — dead evicted members stay joined,
                    # and _revive would skip the re-join for them.)
                    self.uf.add_cost(s.uf, op.cost)
                if was_dead:
                    # A new external view revives a pruned storage: it
                    # rejoins the evicted components with its grown cost.
                    self._revive(s)
                elif s.offloaded:
                    # Offloaded storages sit in no evicted component: only
                    # their own cached key holds the pre-view cost.
                    if self.index is not None:
                        self.index.mark_dirty(s.sid)
                elif not s.resident and not s.banished:
                    # Cached closures summing this evicted storage hold the
                    # pre-view cost: drop them (scoped to its component).
                    self._invalidator.on_cost_change(s)
            else:
                sid = self._next_sid
                self._next_sid += 1
                t = TensorRec(tid, nm, op, sid, is_alias=False, defined=False)
                s = StorageRec(sid, int(size), tid, [tid], resident=False,
                               last_access=self.clock, local_cost=op.cost,
                               refs=1)
                if self.uf is not None:
                    s.uf = self.uf.make(0.0)
                self.storages[sid] = s
                new_storages.append(s)
            self.tensors[tid] = t
            out_tids.append(tid)
        op.output_tids = tuple(out_tids)

        # Wire storage-level dependency edges.
        out_sids = {self.tensors[t].sid for t in out_tids}
        in_sids = {self.tensors[u].sid for u in input_tids}
        for osid in out_sids:
            for isid in in_sids:
                if isid != osid:
                    self.storages[osid].deps.add(isid)
                    self.storages[isid].children.add(osid)

        # New storages are evicted-like until first materialization:
        # neighborhood closures can already reach them, so join them to the
        # evicted components and invalidate adjacent cached costs.
        for s in new_storages:
            if self.index is not None:
                self.index.register(s)
            self._invalidator.on_evict(s)

        # Inputs must be materialized, then perform.  Lock inputs across the
        # whole sequence so rematerializing input B cannot evict input A.
        lock_sids = [self.tensors[u].sid for u in input_tids]
        for sid in lock_sids:
            self.storages[sid].locks += 1
        try:
            self._ensure_defined(list(input_tids))
            self._perform(op, first=True)
        finally:
            for sid in lock_sids:
                self.storages[sid].locks -= 1
        return out_tids

    def get(self, tid: int) -> None:
        """Access a tensor: rematerialize if needed, update staleness."""
        self._ensure_defined([tid])
        s = self.storages[self.tensors[tid].sid]
        s.last_access = self.clock

    def addref(self, tid: int) -> None:
        t = self.tensors[tid]
        t.refs += 1
        s = self.storages[t.sid]
        s.refs += 1
        if s.dead:
            self._revive(s)

    def release(self, tid: int) -> None:
        """External reference dropped (RELEASE in the log)."""
        t = self.tensors[tid]
        t.refs -= 1
        s = self.storages[t.sid]
        s.refs -= 1
        if s.refs <= 0:
            # Dead-subgraph pruning happens *before* the eager evict below,
            # so a storage dying at release never joins evicted components.
            self._maybe_die(s)
        if s.refs > 0 or s.banished:
            return
        if self.dealloc == "ignore":
            return
        if self.dealloc == "eager":
            if s.evictable():
                self._evict(s)
        elif self.dealloc == "banish":
            self._try_banish(s)

    def size_of(self, tid: int) -> int:
        t = self.tensors[tid]
        return 0 if t.is_alias else self.storages[t.sid].size

    def finalize(self) -> None:
        """Output condition: all externally-referenced tensors resident+locked."""
        for t in list(self.tensors.values()):
            if t.refs > 0 and not self.storages[t.sid].banished:
                self._ensure_defined([t.tid])
                self.storages[t.sid].locks += 1
        if self.sanitizer is not None:
            self.sanitizer.audit()

    # -- introspection (benchmarks / adversary) -------------------------
    def resident_tids(self) -> set[int]:
        return {t.tid for t in self.tensors.values()
                if t.defined and self.storages[t.sid].resident}

    def slowdown(self) -> float:
        return self.total_compute / max(self.base_compute, 1e-12)

    def overhead(self) -> float:
        """Compute + transfer-stall overhead over the baseline compute.

        Equals ``slowdown()`` without an offload tier (stalls only come
        from fetch-backs); with one, it is the honest end-to-end cost the
        offload benchmarks compare across policies."""
        return ((self.total_compute + self.stall_time)
                / max(self.base_compute, 1e-12))

    def fragmentation(self):
        """Allocator telemetry (``repro.alloc.FragStats``), None in counter mode."""
        return self.allocator.stats() if self.allocator is not None else None

    # ------------------------------------------------------------------
    # Materialization
    # ------------------------------------------------------------------
    def _ensure_defined(self, tids: list[int]) -> None:
        """Iteratively rematerialize every tensor in ``tids``.

        Lock discipline (*lazy locking*): a frame rematerializing tensor t
        first rebuilds op(t)'s undefined inputs WITHOUT holding locks, then —
        once every input is simultaneously defined — locks them all, performs
        op(t), and unlocks.  The paper's pseudocode locks parents on entry
        before recursing, which pins every resident input along a deep
        rematerialization chain at once (the App. E.3 UNet failure mode);
        lazy locking keeps the pinned set to the current op's inputs only,
        preserving the O(1)-extra-memory behaviour of Lemma A.3 for gradient
        chains as well.  A visit-count guard falls back to incremental
        locking (monotone progress, more pinning) if inputs keep getting
        re-evicted — so termination is guaranteed either way.
        """
        for root in tids:
            if self.tensors[root].defined:
                continue
            # Frame: [tid, visits, locked_sids].
            stack: list[list] = [[root, 0, []]]
            try:
                while stack:
                    frame = stack[-1]
                    tid = frame[0]
                    t = self.tensors[tid]
                    if t.defined:
                        stack.pop()
                        for sid in frame[2]:
                            self.storages[sid].locks -= 1
                        continue
                    s = self.storages[t.sid]
                    if s.banished:
                        raise BanishedError(
                            f"access to banished tensor {t.name}")
                    if s.offloaded:
                        # Contents live on host: fetch them back (stalling
                        # on the transfer, or collecting a prefetch) and
                        # restore the views defined at offload time.  Views
                        # created/evicted since then fall through to the
                        # normal remat path below, now with the storage
                        # resident.
                        self._fetch_in(s)
                        continue
                    op = t.op
                    if op is None:
                        raise BanishedError(f"constant {t.name} unavailable")
                    frame[1] += 1
                    if frame[1] > 8:
                        # Livelock guard: siblings keep evicting each other —
                        # lock defined inputs now so progress is monotone.
                        for u in op.input_tids:
                            sid = self.tensors[u].sid
                            if (self.tensors[u].defined
                                    and sid not in frame[2]):
                                self.storages[sid].locks += 1
                                frame[2].append(sid)
                    undef = [u for u in op.input_tids
                             if not self.tensors[u].defined]
                    if undef:
                        for u in undef:
                            stack.append([u, 0, []])
                        continue
                    # All inputs defined *now*: lock, perform, unlock, pop.
                    lk = [self.tensors[u].sid for u in op.input_tids]
                    for sid in lk:
                        self.storages[sid].locks += 1
                    try:
                        self._perform(op, first=False)
                    finally:
                        for sid in lk:
                            self.storages[sid].locks -= 1
                    stack.pop()
                    for sid in frame[2]:
                        self.storages[sid].locks -= 1
            except BaseException:
                for fr in stack:
                    for sid in fr[2]:
                        self.storages[sid].locks -= 1
                raise

    def _perform(self, op: Operator, first: bool) -> None:
        """(Re)execute ``op``: allocate outputs, charge cost, define views."""
        # Lock inputs during allocation.
        in_sids = [self.tensors[u].sid for u in op.input_tids]
        for sid in in_sids:
            self.storages[sid].locks += 1
        try:
            # Inputs are accessed by this op: update staleness metadata.
            for sid in in_sids:
                self.storages[sid].last_access = self.clock
            if self.offload is not None:
                for sid in in_sids:
                    self.offload.note_access(sid, self.clock)
            out_storages: list[StorageRec] = []
            for tid in op.output_tids:
                t = self.tensors[tid]
                s = self.storages[t.sid]
                if s.banished:
                    continue
                # Offloaded output storages are skipped: their contents are
                # intact on host, so this replay must not re-place them
                # (their undefined views are restored by a later fetch).
                if not t.is_alias and not s.resident and not s.offloaded:
                    out_storages.append(s)
            self._alloc_storages(out_storages,
                                 exclude={s.sid for s in out_storages})
            for s in out_storages:
                s.resident = True
                # The storage leaves the evicted set (first materialization
                # included): closures that summed it are stale.
                self._invalidator.on_unevict(s)
                if not first:
                    self._on_remat(s)
            # Define output views computed by this op (aliases included).
            for tid in op.output_tids:
                t = self.tensors[tid]
                s = self.storages[t.sid]
                if s.banished or not s.resident:
                    # Not resident: either an alias of an evicted storage, or
                    # a doubly-computed output evicted mid-allocation (the
                    # paper's "ephemeral" case) — leave for a later remat.
                    continue
                t.defined = True
                s.last_access = self.clock
            # Charged cost: with a fault schedule attached, the op's true
            # hardware cost carries a consistent per-operator misestimation
            # factor — heuristics keep scoring the unperturbed estimate
            # (their cost model is wrong, not the clock).
            cost = op.cost
            if self.faults is not None:
                cost = op.cost * self.faults.cost_factor(op.op_id)
            self.clock += cost
            self.total_compute += cost
            self.ops_executed += 1
            if self.faults is not None and self.faults.cfg.squeezes:
                f = self.faults.budget_factor(self.ops_executed)
                if f != self._budget_factor:
                    self._budget_factor = f
                    self._event("budget_shrink" if f < 1.0
                                else "budget_restore", factor=f)
            if self.total_compute > self.compute_limit:
                raise ThrashError(
                    f"compute {self.total_compute:.3g} exceeded thrash "
                    f"limit {self.compute_limit:.3g}"
                    + self._memory_diagnostics())
            if first:
                self.base_compute += cost
            else:
                self.remat_ops += 1
            self._thrash_check()
            if self.materialize_fn is not None:
                self.materialize_fn(op, first)
            # Banish retry: a remat may unblock pending banishes.
            if self._pending_banish:
                for sid in list(self._pending_banish):
                    s = self.storages[sid]
                    if s.refs <= 0 and not s.banished:
                        self._try_banish(s)
            if self.offload is not None:
                self.offload.pump(self)
            if self.sanitizer is not None:
                self.sanitizer.on_op()
        finally:
            for sid in in_sids:
                self.storages[sid].locks -= 1

    # ------------------------------------------------------------------
    # Allocation / eviction
    # ------------------------------------------------------------------
    def _alloc_storages(self, storages: list[StorageRec],
                        exclude: set[int] = frozenset()) -> None:
        """Admit ``storages`` (not yet resident) into device memory.

        Byte-counter mode (no allocator, or the allocator's fragmentation-free
        compatibility mode) aggregates the sizes and runs the classic
        globally-cheapest eviction loop — decisions are identical with or
        without a pool attached.  Contiguous mode places each storage into a
        contiguous block, evicting a minimal-cost contiguous window on a
        failed fit.
        """
        if self.allocator is not None and self.allocator.contiguous:
            placed: list[StorageRec] = []
            try:
                for s in storages:
                    self.allocator.allocate(self, s, exclude)
                    placed.append(s)
            except BaseException:
                # Roll back siblings placed before the failure: they are not
                # yet resident, so nothing else will ever free their blocks.
                for s in placed:
                    self.allocator.free(s)
                    self.memory -= s.size
                raise
            self.peak_memory = max(self.peak_memory, self.memory)
            return
        need = sum(s.size for s in storages)
        self._alloc(need, exclude)
        if self.allocator is not None:
            for s in storages:
                self.allocator.place(s)

    def _alloc(self, need: float, exclude: set[int] = frozenset()) -> None:
        if need <= 0:
            self.peak_memory = max(self.peak_memory, self.memory)
            return
        if self.faults is not None and self.faults.alloc_fault():
            self._recover_alloc_fault(need, exclude)
        tried: set[str] = set()
        while self.memory + need > self.effective_budget():
            victim = self._pick_victim(exclude)
            if victim is not None:
                self._evict_or_offload(victim)
                continue
            # Before declaring OOM, reclaim in-flight prefetch
            # reservations (they hold device bytes speculatively)...
            if (self.offload is not None
                    and self.offload.cancel_one_prefetch(self)):
                continue
            # ...then walk the degradation ladder (no-op without a
            # RecoveryConfig).
            if self._recovery_step(exclude, tried):
                continue
            raise OOMError(
                f"cannot free {need} bytes (resident={self.memory}, "
                f"budget={self.effective_budget()})"
                + self._memory_diagnostics())
        self.memory += need
        self.peak_memory = max(self.peak_memory, self.memory)

    def _candidates(self, exclude: set[int]) -> list[StorageRec]:
        pool = [s for s in self.storages.values()
                if s.evictable() and s.sid not in exclude and s.size > 0]
        if not pool:
            return pool
        if self.ignore_small_frac > 0 and len(pool) > 8:
            mean = sum(s.size for s in pool) / len(pool)
            thr = self.ignore_small_frac * mean
            big = [s for s in pool if s.size >= thr]
            if big:
                pool = big
        if self.sample_sqrt and len(pool) > 16:
            k = max(int(len(pool) ** 0.5), 8)
            pool = self._rng.sample(pool, k)
        return pool

    def _pick_victim(self, exclude: set[int]) -> Optional[StorageRec]:
        self.victim_picks += 1
        if self.index is not None:
            return self.index.pick(exclude)
        # Reference oracle: exhaustive linear scan (kept bit-exact; the
        # index's verified heap must select the same victim).
        pool = self._candidates(exclude)
        best, best_score = None, None
        for s in pool:
            self.meta_accesses += 1  # one heuristic evaluation
            score = self.heuristic.score(self, s)
            if best_score is None or score < best_score:
                best, best_score = s, score
        return best

    def _evict(self, s: StorageRec) -> None:
        if self.sanitizer is not None:
            self.sanitizer.pre_evict(s)
        assert s.evictable(), f"evicting unevictable storage {s.sid}"
        s.resident = False
        for tid in s.tensor_tids:
            self.tensors[tid].defined = False
        self.memory -= s.size
        self.evictions += 1
        if s.dead and self.uf is None:
            # Dead-subgraph pruning: a never-again-rematerializable storage
            # must not subscribe or inflate the e* walks — its departure
            # leaves every neighbor's cached closure intact (the exact
            # walk charges its cone through ``dead_cost`` instead).
            self._invalidator.on_dead_evict(s)
        else:
            # Scoped invalidation: drop cached neighborhood costs only in
            # the components this eviction merges / the storages adjacent
            # to it.  (With a cost union-find attached, dead storages do
            # join the ẽ* equivalence classes — see ``_uf_join``.)
            self._invalidator.on_evict(s)
        if self.allocator is not None:
            self.allocator.free(s)
        if self.free_fn is not None:
            self.free_fn(s)
        if self.uf is not None:
            self._uf_join(s)

    def _on_remat(self, s: StorageRec) -> None:
        # (ScopedInvalidator.on_unevict already ran in _perform, before the
        # union-find split below mutates the component cost sums.)
        self._remat_counts[s.sid] = self._remat_counts.get(s.sid, 0) + 1
        if self.uf is not None:
            self._uf_detach(s)

    # ------------------------------------------------------------------
    # Fault injection + graceful degradation (repro.faults)
    # ------------------------------------------------------------------
    def effective_budget(self) -> float:
        """Device byte budget after any injected squeeze.

        Bit-exact with ``budget`` when no squeeze is active (the 1.0
        factor multiplies losslessly), so fault-free admission decisions
        are unchanged."""
        return self.budget * self._budget_factor

    def _event(self, kind: str, **fields) -> None:
        ev = {"kind": kind, "op": self.ops_executed, "clock": self.clock}
        ev.update(fields)
        self.events.append(ev)

    def _degrade(self, kind: str, **fields) -> None:
        """Record a recovery-ladder action (vs. a mere fault injection)."""
        self.degradations += 1
        self._event(kind, **fields)

    def _recover_alloc_fault(self, need: float, exclude: set[int]) -> None:
        """An injected transient allocation failure (byte-counter path).

        Responds like a caching allocator to a failed ``cudaMalloc``:
        free extra headroom beyond the request, then retry — and the
        retry is forced to succeed (the fault is transient by
        construction), so alloc faults alone can never kill a run.
        """
        rc = self.recovery
        self._degrade("alloc_fault", need=need)
        target = need * (1.0 + rc.alloc_headroom)
        while self.memory + target > self.effective_budget():
            victim = self._pick_victim(exclude)
            if victim is None:
                break               # best effort; admission proceeds anyway
            self._evict_or_offload(victim)

    def _recovery_step(self, exclude: set[int], tried: set[str]) -> bool:
        """One rung of the degradation ladder; True => retry the fit.

        Order: pool compaction (rescues window-OOMs where free bytes
        exist but no contiguous span) → forced offload (frees device
        blocks without losing contents) → heuristic escalation (a
        last-ditch policy change; it cannot create structurally missing
        candidates, so its real value is the thrash guard — it is kept
        as an OOM rung because each switch is one bounded retry).
        ``tried`` scopes once-per-allocation rungs; the ladder as a whole
        is bounded (compaction once, offload by host capacity, escalation
        by chain length), so the retry loop always terminates.
        """
        rc = self.recovery
        if rc is None:
            return False
        if (rc.compaction and "compact" not in tried
                and self.allocator is not None and self.allocator.contiguous):
            tried.add("compact")
            st = self.allocator.pool.stats()
            self.allocator.pool.compact()
            if self.sanitizer is not None:
                self.sanitizer.note_compaction(
                    st, self.allocator.pool.stats())
            self._degrade("compaction", free=st.free,
                          largest_free=st.largest_free)
            return True
        if rc.forced_offload and self._forced_offload(exclude):
            return True
        if rc.escalation and self._escalate_heuristic("oom"):
            return True
        return False

    def _forced_offload(self, exclude: set[int]) -> bool:
        """Ladder rung: bypass the two-choice key and move the
        cheapest-to-transfer evictable storage to the host tier.

        Unlike eviction this loses no contents (no future remat debt), so
        it is safe to force regardless of the recompute-vs-transfer
        comparison ``wants_offload`` would make.  Victim choice is
        deterministic: minimum transfer key, lowest sid first.
        """
        eng = self.offload
        if eng is None:
            return False
        best, best_k = None, 0.0
        for sid in sorted(self.storages):
            s = self.storages[sid]
            if (s.size <= 0 or sid in exclude or not s.evictable()
                    or not eng.host.can_fit(s.size)):
                continue
            k = eng.transfer_key(s)
            if best is None or k < best_k:
                best, best_k = s, k
        if best is None:
            return False
        self._degrade("forced_offload", sid=best.sid, size=best.size)
        self._offload(best)
        return True

    def _escalate_heuristic(self, reason: str) -> bool:
        """Switch to the next usable heuristic of the escalation chain.

        Skips entries matching the current (base) heuristic, entries
        needing machinery this run lacks (union-find, separability for an
        attached index), and — under the hybrid offload policy — entries
        that cannot price recomputation.  On success the eviction index
        is rebuilt from scratch over the existing storages, so victim
        selection stays bit-exact with a linear scan under the new
        heuristic.
        """
        rc = self.recovery
        if rc is None or self._escalated >= len(rc.escalation_chain):
            return False
        from .heuristics import by_name
        eng = self.offload
        if eng is not None and eng.cfg.policy == "offload":
            # Victims are ranked by transfer cost alone; swapping the base
            # recompute heuristic would change nothing.
            return False
        cur = self.heuristic
        cur_base = cur.base if getattr(cur, "hybrid", False) else cur
        while self._escalated < len(rc.escalation_chain):
            name = rc.escalation_chain[self._escalated]
            self._escalated += 1
            h = by_name(name)
            if h.name == cur_base.name:
                continue
            if h.needs_uf and self.uf is None:
                continue
            if self.index is not None and not h.separable:
                continue
            if eng is not None:
                if not h.cost_aware:
                    continue
                from ..offload.engine import wrap_heuristic
                h = wrap_heuristic(h, eng)
            if hasattr(h, "bind"):
                h.bind(self)
            old = self.heuristic.name
            self.heuristic = h
            if self.index is not None:
                self.index = EvictIndex(self)
                for s in self.storages.values():
                    self.index.register(s)
            self._degrade("heuristic_escalation", reason=reason,
                          from_=old, to=h.name)
            return True
        return False

    def _thrash_check(self) -> None:
        """Sliding-window remat-livelock detector (one check per op).

        When a full window's charged compute exceeds ``thrash_ratio``
        times its first-execution progress, the run is grinding remats —
        escalate the heuristic now instead of riding into the
        ``ThrashError`` cliff.  With the chain exhausted the guard
        disarms and the hard limit fires as before.
        """
        rc = self.recovery
        if rc is None or not rc.thrash_guard or self._thrash_disabled:
            return
        self._w_ops += 1
        if self._w_ops < rc.thrash_window_ops:
            return
        dt = self.total_compute - self._w_total
        db = self.base_compute - self._w_base
        self._w_ops = 0
        self._w_total = self.total_compute
        self._w_base = self.base_compute
        if dt <= rc.thrash_ratio * db:
            return
        if not self._escalate_heuristic("thrash_guard"):
            self._thrash_disabled = True

    def _memory_diagnostics(self, top_k: int = 5) -> str:
        """Breakdown appended to OOM/Thrash messages: where the resident
        bytes are stuck (pinned / locked / evictable) plus the top-k
        most-rematerialized storages — enough to debug a failed sweep
        cell from the error string alone."""
        live = pinned = locked = evictable = 0.0
        for s in self.storages.values():
            if not s.resident:
                continue
            live += s.size
            if s.pinned or s.constant:
                pinned += s.size
            elif s.locks > 0:
                locked += s.size
            elif s.evictable():
                evictable += s.size
        top = sorted(self._remat_counts.items(),
                     key=lambda kv: (-kv[1], kv[0]))[:top_k]
        hist = ", ".join(f"s{sid}x{n}" for sid, n in top) or "none"
        return (f" [resident={live:g}: pinned={pinned:g}, "
                f"locked={locked:g}, evictable={evictable:g}; "
                f"degradations={self.degradations}; top remats: {hist}]")

    # ------------------------------------------------------------------
    # Host offload tier (repro.offload)
    # ------------------------------------------------------------------
    def _evict_or_offload(self, s: StorageRec) -> None:
        """Free the victim's device bytes by the cheaper mechanism.

        The two-choice policy (``OffloadEngine.wants_offload``) compares
        round-trip transfer cost against the heuristic's recompute cost;
        without an engine this is exactly ``_evict``.
        """
        if self.offload is not None and self.offload.wants_offload(self, s):
            self._offload(s)
        else:
            self._evict(s)

    def _offload(self, s: StorageRec) -> None:
        """Move ``s``'s bytes to the host tier (contents preserved).

        The device block frees immediately (the D2H copy-out proceeds in
        the background on the simulated clock; a fetch-back cannot start
        before it lands).  Unlike eviction, nothing here touches the
        evicted components: an offloaded storage needs no remat, so
        neighboring e*/ẽ* closures are unchanged.
        """
        if self.sanitizer is not None:
            self.sanitizer.pre_offload(s)
        assert s.evictable(), f"offloading unevictable storage {s.sid}"
        defined = tuple(tid for tid in s.tensor_tids
                        if self.tensors[tid].defined)
        s.offloaded = True
        s.resident = False              # index membership exits here
        for tid in s.tensor_tids:
            self.tensors[tid].defined = False
        self.memory -= s.size
        self.offloads += 1
        if self.allocator is not None:
            self.allocator.free(s)
        self.offload.on_offload(self, s, defined)
        if self.offload_fn is not None:
            self.offload_fn(s, defined)

    def _fetch_in(self, s: StorageRec) -> None:
        """Bring an offloaded storage back to device (access miss path).

        A completed/in-flight prefetch already holds a device reservation:
        the access stalls only until its arrival time.  Otherwise device
        space is allocated now (evicting/offloading further victims if
        needed) and the clock stalls for the full synchronous H2D copy.
        """
        if self.sanitizer is not None:
            self.sanitizer.pre_fetch(s)
        eng = self.offload
        if eng.in_flight(s.sid):
            rec = eng._recs[s.sid]
            stall = rec.ready_at - self.clock
            self.prefetch_hits += 1
        else:
            self._alloc_storages([s], exclude={s.sid})
            stall = eng.begin_fetch(self, s)
            self.fetches += 1
        if stall > 0:
            self._stall(stall)
        defined = eng.finish_fetch(self, s)
        s.offloaded = False
        s.resident = True               # index membership re-enters here
        for tid in defined:
            self.tensors[tid].defined = True
        s.last_access = self.clock
        if self.fetch_fn is not None:
            self.fetch_fn(s, defined)

    def _stall(self, dt: float) -> None:
        """Advance the clock waiting on a transfer (no compute charged)."""
        self.clock += dt
        self.stall_time += dt
        if self.total_compute + self.stall_time > self.compute_limit:
            raise ThrashError(
                f"compute+stall {self.total_compute + self.stall_time:.3g} "
                f"exceeded thrash limit {self.compute_limit:.3g}"
                + self._memory_diagnostics())

    # ------------------------------------------------------------------
    # Evicted-component maintenance (h_dtr_eq's equivalence classes)
    # ------------------------------------------------------------------
    def _uf_join(self, s: StorageRec) -> None:
        """``s`` enters the evicted set: merge with evicted neighbor
        components, adding its own cost (App. C.2).

        Dead storages join too: the ẽ* equivalence classes count every
        evicted tensor's compute exactly once *as a member* (the exact
        walk instead prunes the dead and charges their cones through
        ``dead_cost`` — two self-consistent accountings of the same
        quantity).  Pruning the dead out of the *components* was measured
        to invert eq's economics on fan-out traces: the undirected
        approximation relies on their ballast, so they stay.
        """
        uf = self.uf
        members = self._uf_members
        phantoms = self._uf_phantoms
        uf.add_cost(s.uf, s.local_cost)
        s.uf_joined = True
        r = uf.find(s.uf)
        mem = members.pop(r, None)
        ph = phantoms.pop(r, 0)
        if mem is None:
            mem = [s.sid]
        else:
            mem.append(s.sid)
        for nsid in s.deps | s.children:
            ns = self.storages[nsid]
            if not ns.resident and not ns.banished and not ns.offloaded:
                r1 = uf.find(ns.uf)
                if r1 == r:
                    continue
                mem1 = members.pop(r1, None)
                ph += phantoms.pop(r1, 0)
                if mem1 is not None:
                    mem.extend(mem1)
                r = uf.union(r, r1)
                self.meta_accesses += 1
        members[r] = mem
        if ph:
            phantoms[r] = ph

    def _uf_detach(self, s: StorageRec) -> None:
        """``s`` leaves the evicted set (remat / death): the paper's split
        approximation — subtract its cost, move it to a fresh singleton —
        plus amortized *exact* splitting.

        The detached member lingers as a phantom inside the old component.
        On static workloads phantoms are short-lived; on eager-release
        traces they accumulate until ẽ* is pure noise (a single
        mega-component whose sum approaches total trace compute).  So each
        detach bumps the component's phantom count, and once phantoms
        outnumber live members the true partition is re-derived — ẽ*
        tracks e* within a bounded (2x-membership) slack instead of
        diverging with trace length.  (A storage that never joined — the
        created-unmaterialized "ephemeral" case — still detaches to a
        fresh singleton so a later re-eviction merges with its *current*
        neighbors.)
        """
        uf = self.uf
        r = uf.find(s.uf)
        own = s.local_cost if s.uf_joined else 0.0
        joined = s.uf_joined
        s.uf_joined = False
        s.uf = uf.split_approx(s.uf, own)
        self.meta_accesses += 1
        if not joined:
            return
        mem = self._uf_members.get(r)
        if mem is None:
            return
        ph = self._uf_phantoms.get(r, 0) + 1
        if 2 * ph >= len(mem):
            self._uf_rebuild(r)
        else:
            self._uf_phantoms[r] = ph

    def _uf_rebuild(self, root: int) -> None:
        """Re-derive the exact evicted components of a phantom-heavy one.

        Walks the live members' evicted adjacency, assigns each connected
        component a fresh root with an exactly re-summed cost, and
        re-parents every live member's handle — so adjacency snapshots
        held by eq consumers keep resolving (their values were already
        invalidated by the event that triggered the detach).  Stale
        handles of long-gone phantoms may resolve to an arbitrary
        successor component; no live snapshot can hold one (any consumer
        adjacent to a detaching storage is fully invalidated at that
        event).
        """
        uf = self.uf
        storages = self.storages
        mem = self._uf_members.pop(root)
        self._uf_phantoms.pop(root, None)
        live = [sid for sid in mem
                if storages[sid].uf_joined
                and uf.find(storages[sid].uf) == root]
        uf.accesses += len(mem)
        seen: set[int] = set()
        live_set = set(live)
        first_root = None
        for sid in live:
            if sid in seen:
                continue
            comp = [sid]
            seen.add(sid)
            stack = [sid]
            while stack:
                y = stack.pop()
                ys = storages[y]
                for nsid in sorted(ys.deps | ys.children):
                    if nsid in live_set and nsid not in seen:
                        seen.add(nsid)
                        comp.append(nsid)
                        stack.append(nsid)
            nr = uf.make(0.0)
            total = 0.0
            for y in comp:
                ys = storages[y]
                total += ys.local_cost
                uf._parent[ys.uf] = nr
            uf._cost[nr] = total
            uf.accesses += len(comp)
            self._uf_members[nr] = comp
            if first_root is None:
                first_root = nr
        if first_root is not None and uf._parent[root] == root:
            # Point the abandoned root at a successor so stale phantom
            # handles cannot resurrect the old (now meaningless) sum.
            # (Skipped when the old root is itself a live member's handle —
            # the member loop above already re-parented it.)
            uf._parent[root] = first_root
            uf._cost[root] = 0.0

    # ------------------------------------------------------------------
    # Dead-subgraph pruning
    # ------------------------------------------------------------------
    def _maybe_die(self, s: StorageRec) -> None:
        """Mark ``s`` (and transitively its ancestors) dead if unreachable.

        A storage is *dead* when no external reference can ever touch it
        again: its own refcount is zero and every child storage is dead or
        banished — so no rematerialization of a live tensor can require it
        (parents of a live storage are live by induction).  Dead storages
        are pruned from the evicted-component structure: they never join
        components, never subscribe, and never inflate e*/ẽ* — the fix for
        eager-release workloads whose e* walk cost otherwise grows with
        trace length.
        """
        storages = self.storages
        stack = [s]
        while stack:
            x = stack.pop()
            if x.dead or x.banished or x.refs > 0:
                continue
            if any(not (storages[c].dead or storages[c].banished)
                   for c in x.children):
                continue
            self._kill(x)
            for psid in x.deps:
                p = storages[psid]
                if p.refs <= 0 and not p.dead and not p.banished:
                    stack.append(p)

    def _kill(self, x: StorageRec) -> None:
        if self.sanitizer is not None:
            self.sanitizer.pre_kill(x)
        x.dead = True
        if x.offloaded:
            # A dead host copy can never be fetched again: drop it (and
            # any in-flight prefetch reservation).  The storage was never
            # an evicted-component member, so no invalidation beyond its
            # own key is needed.
            self.offload.drop(self, x)
            if self.free_fn is not None:
                self.free_fn(x)      # eager hook: discard the host copy too
            if self.index is not None:
                self.index.mark_dirty(x.sid)
        elif not x.resident and not x.banished:
            # x leaves the exact e* closures (walks prune the dead):
            # cached values that summed it are stale.  Its ẽ* component
            # membership is deliberately kept — dead members stay cost
            # ballast for the undirected equivalence classes.
            self._invalidator.on_death(x)
        elif self.index is not None:
            # Dying while resident: the transfer below zeroes x.dead_cost,
            # so x's own cached heap key (computed with the old weight) is
            # stale — drop it or the index could prune a band the scan
            # would pick from.
            self.index.mark_dirty(x.sid)
        # Attach the dead subgraph's frozen cone cost to its live frontier:
        # every live neighbor that the paper's e* walk would have counted
        # the cone through carries it as ``dead_cost``, charged in O(1)
        # when the neighbor is scored or walked — the cone itself is never
        # traversed or subscribed through again.  Death cascades
        # child-first, so a dying parent forwards the cone weight its own
        # ``dead_cost`` already accumulated.  (A cone shared by several
        # live parents is charged at each of them — a deliberate
        # over-approximation; the pre-pruning walk deduplicated across one
        # closure, but per-parent attachment keeps the charge local and
        # event-free.)  Pinned/constant neighbors are skipped: they are
        # never victims and never walked, so weight parked there would
        # vanish from the score system — exactly as the old walks could
        # never reach a cone hanging only off pinned storages.
        transfer = x.local_cost + x.dead_cost
        x.dead_cost = 0.0
        if transfer <= 0.0:
            return
        for nsid in sorted(x.deps | x.children):
            host = self.storages[nsid]
            if host.dead or host.banished or host.pinned or host.constant:
                continue
            host.dead_cost += transfer
            if host.offloaded:
                # Offloaded host: no closure ever sums it; only its own
                # key carries the new weight.
                if self.index is not None:
                    self.index.mark_dirty(host.sid)
            elif not host.resident:
                # Cached e* closures that summed ``host`` hold its old
                # effective cost; adjacency is unchanged (sum-only).  The
                # ẽ* component sums are untouched: the cone's members
                # carry their own cost there.
                self._invalidator.on_cost_change(host)
            elif self.index is not None:
                # Resident host: only its own key/score carries the weight.
                self.index.mark_dirty(host.sid)

    def _revive(self, s: StorageRec) -> None:
        """A dead storage regained a reference (addref / new view).

        Undo the pruning: the storage (and every dead ancestor — they all
        have a live descendant again) rejoins the evicted components.

        Known drift, accepted: the cone weight ``_kill`` already donated
        to the live frontier is not clawed back, so a revived storage is
        briefly double-counted (once live, once inside its neighbors'
        ``dead_cost``).  A well-formed log cannot reach this path — a
        handle with zero references cannot be addref'd or viewed — so the
        drift only affects hand-driven runtimes, and only as a transient
        over-protection of the revived storage's neighbors.
        """
        storages = self.storages
        stack = [s]
        while stack:
            x = stack.pop()
            if not x.dead:
                continue
            x.dead = False
            if not x.resident and not x.banished and not x.offloaded:
                self._invalidator.on_evict(x)
                if self.uf is not None and not x.uf_joined:
                    self._uf_join(x)
            stack.extend(storages[p] for p in x.deps if storages[p].dead)

    def _try_banish(self, s: StorageRec) -> None:
        # Banishable iff no *live* evicted dependents (children all
        # resident, banished, or dead); otherwise retried after
        # rematerializations.  Dead evicted children never rematerialize,
        # so they must not block the banish forever.
        for csid in s.children:
            c = self.storages[csid]
            # Offloaded children need no remat (they fetch back), so they
            # never block a banish.
            if (not c.resident and not c.banished and not c.dead
                    and not c.offloaded):
                self._pending_banish.add(s.sid)
                return
        self._pending_banish.discard(s.sid)
        if self.sanitizer is not None:
            self.sanitizer.pre_banish(s)
        if s.offloaded:
            # Banish drops the host copy too: permanent free means the
            # bytes are gone from every tier.
            self.offload.drop(self, s)
            if self.free_fn is not None:
                self.free_fn(s)
        if s.resident:
            self.memory -= s.size
            for tid in s.tensor_tids:
                self.tensors[tid].defined = False
            if self.allocator is not None:
                self.allocator.free(s)
            if self.free_fn is not None:
                self.free_fn(s)
        s.resident = False
        s.banished = True
        # Banished storages leave the evicted closures permanently; drop the
        # cached costs of their component's consumers (no-op if s was
        # resident: nothing cached ever summed it).
        self._invalidator.on_unevict(s)
        # Children become non-rematerializable => pin them.
        for csid in s.children:
            c = self.storages[csid]
            if not c.banished:
                c.pinned = True
        # A banished child counts as dead for its parents' liveness rule.
        for psid in s.deps:
            p = self.storages[psid]
            if p.refs <= 0 and not p.dead and not p.banished:
                self._maybe_die(p)

    # ------------------------------------------------------------------
    # Metadata used by heuristics
    # ------------------------------------------------------------------
    def staleness(self, s: StorageRec) -> float:
        return max(self.clock - s.last_access, 1e-9)

    def evicted_neighborhood_cost(self, s: StorageRec) -> float:
        """Exact  Σ_{T ∈ e*(S)} cost(T)  with scoped caching (App. C.5).

        Cache entries live until the ScopedInvalidator drops them: while
        computing, the walk subscribes ``s`` to the evicted component of
        every storage it sums, so an evict/remat elsewhere leaves this
        entry intact.

        The walk visits *live* evicted storages only.  Dead subgraphs
        (eager-released tensors whose whole descendant cone is
        unreferenced) are never traversed: their aggregate cost is charged
        in O(1) through the ``dead_cost`` attached to each walked storage
        — same sum as walking the cone, none of the per-member visits or
        subscriptions, so walk cost is bounded by the live evicted set
        instead of growing with trace length.
        """
        hit = self._estar_cache.get(s.sid)
        if hit is not None:
            return hit[0]
        subscribe = self._invalidator.subscribe
        total = 0.0
        seen: set[int] = set()
        # Evicted ancestors: closure over evicted deps.
        stack = [d for d in s.deps if self._is_evicted(d)]
        while stack:
            x = stack.pop()
            if x in seen:
                continue
            seen.add(x)
            self.meta_accesses += 1
            xs = self.storages[x]
            total += xs.local_cost + xs.dead_cost
            subscribe(x, s.sid)
            stack.extend(d for d in xs.deps if self._is_evicted(d) and d not in seen)
        # Evicted descendants: closure over evicted children.
        stack = [c for c in s.children if self._is_evicted(c)]
        while stack:
            x = stack.pop()
            if x in seen:
                continue
            seen.add(x)
            self.meta_accesses += 1
            xs = self.storages[x]
            total += xs.local_cost + xs.dead_cost
            subscribe(x, s.sid)
            stack.extend(c for c in xs.children
                         if self._is_evicted(c) and c not in seen)
        self._estar_cache[s.sid] = (total, len(seen))
        return total

    def evicted_ancestor_cost(self, s: StorageRec) -> float:
        """Σ cost over evicted ancestors only (MSPS, Peng et al. 2020).

        Uncached (matching the original accounting), but the walk still
        subscribes so the eviction index knows when a cached *score* built
        on this value goes stale.
        """
        subscribe = self._invalidator.subscribe
        total = 0.0
        seen: set[int] = set()
        stack = [d for d in s.deps if self._is_evicted(d)]
        while stack:
            x = stack.pop()
            if x in seen:
                continue
            seen.add(x)
            self.meta_accesses += 1
            xs = self.storages[x]
            total += xs.local_cost
            subscribe(x, s.sid)
            stack.extend(d for d in xs.deps if self._is_evicted(d) and d not in seen)
        return total

    def eq_neighborhood_cost(self, s: StorageRec) -> float:
        """ẽ*(S) via union-find components of evicted neighbors (App. C.2).

        Two-tier scoped caching:

        * the **value** (``_eq_cache``) is dropped whenever any adjacent
          component's sum changes — merges, splits, and member cost growth
          alike (the ScopedInvalidator pops the subscriptions registered
          here);
        * the **adjacency snapshot** (``_eq_adj``) — the union-find handles
          of S's evicted neighbors, in sorted-sid order — survives
          component-*sum*-only events and is dropped only when a neighbor
          actually enters or leaves the evicted set.  While it holds, a
          key rebuild resolves each remembered handle to its current root
          and reads the incrementally-maintained root sum: no neighborhood
          re-walk, no re-subscription.  The sorted order makes the float
          summation a pure function of current state, so scan and index
          engines (whose evaluation times differ) compute bit-identical
          values.
        """
        assert self.uf is not None
        hit = self._eq_cache.get(s.sid)
        if hit is not None:
            return hit
        uf = self.uf
        snap = self._eq_adj.get(s.sid)
        if snap is not None:
            roots: set[int] = set()
            total = 0.0
            for h in snap:
                r = uf.find(h)
                if r not in roots:
                    roots.add(r)
                    total += uf.root_sum(r)
            self.meta_accesses += 1
            self._eq_cache[s.sid] = total
            return total
        subscribe = self._invalidator.subscribe
        roots = set()
        total = 0.0
        handles: list[int] = []
        # Dead neighbors count here (unlike the exact walk): they are
        # members of the equivalence classes, so their component is part
        # of ẽ* by construction.
        for nsid in sorted(s.deps | s.children):
            ns = self.storages[nsid]
            if not ns.resident and not ns.banished and not ns.offloaded:
                r = uf.find(ns.uf)
                self.meta_accesses += 1
                subscribe(nsid, s.sid)
                handles.append(ns.uf)
                if r not in roots:
                    roots.add(r)
                    total += uf.root_sum(r)
        self._eq_adj[s.sid] = tuple(handles)
        self._eq_cache[s.sid] = total
        return total

    def _is_evicted(self, sid: int) -> bool:
        s = self.storages[sid]
        return (not s.resident and not s.banished and not s.dead
                and not s.offloaded)
