"""Static checkpointing baselines for the Fig. 3 comparison.

All baselines plan offline for a *linear chain* of N unit ops (the setting
where optimal static planning is tractable without an ILP solver, which is
unavailable offline in this container — noted in EXPERIMENTS.md):

  * ``chen_sqrt``   — Chen et al. (2016) √N segmentation: recompute each
                      segment once during backward.
  * ``chen_greedy`` — Chen's greedy variant: checkpoints every ``b`` bytes.
  * ``revolve``     — Griewank & Walther binomial checkpointing (optimal for
                      the one-shot adjoint regime, O(N log N) ops at
                      O(log N) memory).
  * ``optimal_dp``  — exact DP over (chain length, checkpoint slots), the
                      Checkmate-equivalent optimum for chains.

Each returns total forward-op executions (the backward ops themselves are the
same N for every planner, so comparisons report *extra recomputation*).
"""
from __future__ import annotations

import math
from functools import lru_cache


def chen_sqrt(n: int) -> tuple[int, int]:
    """(total_fwd_ops, peak_memory_tensors) for √N segmentation.

    Forward pass stores one checkpoint every k=⌈√N⌉ ops; backward
    recomputes each segment once from its checkpoint.
    """
    k = max(int(math.ceil(math.sqrt(n))), 1)
    n_ckpt = (n + k - 1) // k
    # Forward: n ops.  Backward: each segment replayed once (≤ k-1 ops each).
    recompute = sum(max(min(k, n - i * k) - 1, 0) for i in range(n_ckpt))
    peak = n_ckpt + k + 2  # checkpoints + live segment + grad pair
    return n + recompute, peak


def chen_greedy(n: int, budget: int) -> tuple[int, int]:
    """Greedy: place a checkpoint every ⌈n/(budget-2)⌉ ops to fit budget."""
    slots = max(budget - 2, 1)
    k = max((n + slots - 1) // slots, 1)
    n_ckpt = (n + k - 1) // k
    recompute = sum(max(min(k, n - i * k) - 1, 0) for i in range(n_ckpt))
    return n + recompute, n_ckpt + k + 2


@lru_cache(maxsize=None)
def _revolve_cost(n: int, s: int) -> int:
    """Minimal forward re-evaluations to reverse a chain of length n with s
    checkpoint slots (Griewank's binomial schedule, computed by DP)."""
    if n <= 1:
        return 0
    if s <= 0:
        return math.inf  # cannot reverse without any checkpoint
    if s == 1:
        # Recompute from the start for every step: n-1 + n-2 + ... + 1
        return n * (n - 1) // 2
    best = math.inf
    for k in range(1, n):
        # Place a checkpoint after k ops: k fwd ops to reach it, then reverse
        # the tail with s-1 slots, then the head with s slots.
        c = k + _revolve_cost(n - k, s - 1) + _revolve_cost(k, s)
        if c < best:
            best = c
    return best


def revolve(n: int, budget: int) -> tuple[int, int]:
    """(total_fwd_ops, peak) for binomial checkpointing with ``budget`` slots."""
    s = max(budget - 2, 1)
    extra = _revolve_cost(n, s)
    return n + int(extra), budget


def optimal_dp(n: int, budget: int) -> tuple[int, int]:
    """Exact optimum for a unit chain = REVOLVE's DP (provably optimal for
    the one-shot reversal of a homogeneous chain)."""
    return revolve(n, budget)


BASELINES = {
    "chen_sqrt": lambda n, b: chen_sqrt(n),
    "chen_greedy": chen_greedy,
    "revolve": revolve,
    "optimal_dp": optimal_dp,
}


def chain_solvers():
    """Bridge to the trace-level planners of ``repro.static.solvers``.

    The closed-form baselines above plan on *homogeneous unit chains*
    (every op costs 1, every tensor weighs 1 slot).  The ``repro.static``
    solvers generalize them to heterogeneous chains extracted from real
    traces (per-item byte sizes, per-segment recompute costs) and return
    executable ``Plan``s rather than op counts.  Returns the ``{name:
    solver(chain, budget) -> Plan}`` registry; imported lazily so the
    core package stays dependency-free of the static subsystem.
    """
    from ..static.solvers import SOLVERS
    return dict(SOLVERS)
