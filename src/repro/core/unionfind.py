"""Union-find with per-component cost sums and the paper's split approximation.

This is the data structure behind the ``h_DTR^eq`` heuristic (Sec. 4.1 /
Appendix C.2 of the DTR paper): evicted storages form *evicted components*
(connected components of the undirected dependency graph restricted to evicted
storages). Each component tracks the running sum of its members' compute
costs.  Union-find supports near-constant merging; splitting (needed when a
storage is rematerialized) is approximated per the paper: subtract the
storage's own cost from its component sum and move it to a fresh singleton —
leaving "phantom connections" behind, which is exactly the approximation the
paper evaluates.

The per-root sums are maintained *incrementally*: every ``union`` adds the
absorbed root's sum into the surviving root, ``add_cost`` adjusts a
component in place (alias registration on an evicted storage grows its
member cost), and ``split_approx`` subtracts the detached member — so a
component's current sum is always one ``find`` away.  ``h_dtr_eq`` key
recomputation reads these cached root sums directly (``root_sum``) instead
of re-walking a storage's neighborhood per key (see
``DTRRuntime.eq_neighborhood_cost``).
"""
from __future__ import annotations


class CostUnionFind:
    """Union-find over integer handles with a cost accumulator per root.

    ``accesses`` counts element visits (parent-chain hops + cost reads) so the
    runtime can reproduce the metadata-overhead accounting of Appendix D.3.
    ``unions`` / ``splits`` count structural events (telemetry only).
    """

    __slots__ = ("_parent", "_rank", "_cost", "accesses", "unions", "splits")

    def __init__(self) -> None:
        self._parent: list[int] = []
        self._rank: list[int] = []
        self._cost: list[float] = []
        self.accesses = 0
        self.unions = 0
        self.splits = 0

    def make(self, cost: float = 0.0) -> int:
        """Create a fresh singleton set; returns its handle."""
        h = len(self._parent)
        self._parent.append(h)
        self._rank.append(0)
        self._cost.append(float(cost))
        return h

    def find(self, x: int) -> int:
        # Path halving; count hops as metadata accesses.
        p = self._parent
        while p[x] != x:
            self.accesses += 1
            p[x] = p[p[x]]
            x = p[x]
        self.accesses += 1
        return x

    def union(self, a: int, b: int) -> int:
        """Merge the sets of ``a`` and ``b``; cost sums add. Returns new root."""
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        if self._rank[ra] < self._rank[rb]:
            ra, rb = rb, ra
        self._parent[rb] = ra
        self._cost[ra] += self._cost[rb]
        if self._rank[ra] == self._rank[rb]:
            self._rank[ra] += 1
        self.accesses += 1
        self.unions += 1
        return ra

    def cost(self, x: int) -> float:
        """Cost sum of x's component."""
        r = self.find(x)
        self.accesses += 1
        return self._cost[r]

    def root_sum(self, r: int) -> float:
        """Incrementally-maintained cost sum of root ``r`` (no find).

        Callers that already resolved the root (e.g. the ``h_dtr_eq``
        fast-path key rebuild, which dedupes roots across a cached
        adjacency snapshot) read the component sum in O(1); the read is
        charged as one metadata access.
        """
        self.accesses += 1
        return self._cost[r]

    def add_cost(self, x: int, delta: float) -> None:
        r = self.find(x)
        self._cost[r] += delta
        self.accesses += 1

    def split_approx(self, x: int, own_cost: float) -> int:
        """The paper's splitting approximation.

        On rematerialization of storage with handle ``x`` — and equally on
        *death* of an evicted storage (dead-subgraph pruning): subtract its
        own cost from the (old) component sum, then assign it a brand-new
        empty component.  Returns the new handle (callers must re-point the
        storage at it).  No edges are actually removed — "phantom
        dependencies" may persist, per Appendix C.2.
        """
        r = self.find(x)
        self._cost[r] -= own_cost
        # Guard tiny negative drift from float accumulation.
        if self._cost[r] < 0.0:
            self._cost[r] = 0.0
        self.accesses += 1
        self.splits += 1
        return self.make(0.0)

    def same(self, a: int, b: int) -> bool:
        return self.find(a) == self.find(b)
