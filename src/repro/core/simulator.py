"""Budget-sweep simulation driver (the experiment harness behind Fig. 2/3).

Replays a log at a list of memory budgets (absolute bytes or fractions of the
unconstrained peak) for each heuristic, recording compute slowdown, eviction /
remat counts, and metadata accesses; detects OOM (budget below feasibility)
and thrashing (slowdown >= threshold).

``alloc_mode`` selects the memory model: ``"counter"`` (default) is the
paper's fungible byte counter; ``"pool"`` maps storages onto a simulated
address space requiring contiguous fits with window eviction
(``repro.alloc``); ``"pool_nofrag"`` keeps counter semantics bit-for-bit but
tracks block placement for fragmentation telemetry.

``alloc_mode="pool+host"`` stacks the hybrid offload tier
(``repro.offload``) on the contiguous pool: pass ``offload=OffloadConfig(...)``
with a positive ``host_budget`` and victims are either evicted (recompute
later) or offloaded to a capacity-bounded host tier over modeled transfer
channels, whichever is cheaper, with async prefetch-back.  ``offload`` also
composes with the other alloc modes; ``pool+host`` merely makes the pairing
explicit and refuses to run without an enabled config.

``index`` toggles the incremental eviction index
(``repro.core.evict_index``); ``index=False`` runs the linear-scan oracle.
Both produce identical eviction decisions (only ``meta_accesses`` may
differ); large sweeps additionally parallelize across processes with
``sweep_parallel``.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from dataclasses import dataclass, field

from .graph import Log, replay
from .heuristics import Heuristic, by_name
from .runtime import DTRRuntime, OOMError, ThrashError

ALLOC_MODES = ("counter", "pool", "pool_nofrag", "pool+host")


@dataclass
class RunResult:
    budget: float
    ok: bool
    slowdown: float = 0.0
    compute: float = 0.0
    base_compute: float = 0.0
    evictions: int = 0
    remat_ops: int = 0
    ops_executed: int = 0
    meta_accesses: int = 0
    peak_memory: float = 0.0
    error: str = ""
    # Fragmentation telemetry (pool-backed runs; zeros in counter mode).
    largest_free: float = 0.0
    frag_ratio: float = 0.0
    failed_fits: int = 0
    evict_windows: int = 0
    # Offload-tier telemetry (repro.offload; zeros without a host tier).
    stall_time: float = 0.0
    offloads: int = 0
    fetches: int = 0
    prefetch_hits: int = 0
    prefetch_cancelled: int = 0
    host_peak: float = 0.0
    # (compute + transfer stalls) / base_compute; slowdown counts compute only.
    overhead: float = 0.0
    # Failure classification (repro.faults): "" for clean runs, else
    # "oom" | "thrash" (infeasible) | "fault" (injected faults fired and
    # the run still died — unlucky, not necessarily infeasible) |
    # "worker" (the sweep worker process died, no runtime to read).
    error_kind: str = ""
    # Graceful-degradation telemetry: ladder actions taken, and the full
    # structured event stream (fault injections + recoveries).
    degradations: int = 0
    events: list = field(default_factory=list)


def make_allocator(alloc_mode: str | None, placement: str = "best_fit"):
    """Build the allocator backend for ``alloc_mode`` (None/'counter' => None)."""
    if alloc_mode in (None, "counter"):
        return None
    from ..alloc import PoolAllocator
    if alloc_mode in ("pool", "pool+host"):
        return PoolAllocator(placement=placement, contiguous=True)
    if alloc_mode == "pool_nofrag":
        return PoolAllocator(placement=placement, contiguous=False)
    raise ValueError(f"unknown alloc_mode {alloc_mode!r}; "
                     f"expected one of {ALLOC_MODES}")


def _frag_fields(rt: DTRRuntime) -> dict:
    frag = rt.fragmentation()
    if frag is None:
        return {}
    return dict(largest_free=frag.largest_free, frag_ratio=frag.frag_ratio,
                failed_fits=frag.failed_fits,
                evict_windows=frag.evict_windows)


def classify_error(rt: DTRRuntime, exc: BaseException) -> str:
    """Structured error kind for a failed run.

    ``"fault"`` when injected faults actually fired before the death —
    the cell may be feasible on a luckier schedule; ``"oom"``/``"thrash"``
    otherwise (genuinely infeasible at this budget)."""
    if rt.faults is not None and rt.faults.injected > 0:
        return "fault"
    return "oom" if isinstance(exc, OOMError) else "thrash"


def result_from_runtime(rt: DTRRuntime, budget: float, ok: bool,
                        error: str = "", error_kind: str = "") -> RunResult:
    """Assemble a RunResult from a finished (or aborted) runtime.

    Single source of truth for the field mapping — ``simulate`` and the
    trace subsystem's ``run_trace`` both build their results here, so the
    two report paths cannot drift.  Failed runs are no longer a cliff:
    they carry their partial progress (ops executed, compute so far, and
    a *finite* slowdown/overhead over the work actually done) plus the
    structured ``error_kind``, so sweeps can distinguish infeasible cells
    from unlucky ones and measure how far a dying run got.
    """
    eng = rt.offload
    return RunResult(
        budget=budget, ok=ok, error=error, error_kind=error_kind,
        slowdown=rt.slowdown(), overhead=rt.overhead(),
        degradations=rt.degradations, events=list(rt.events),
        compute=rt.total_compute, base_compute=rt.base_compute,
        evictions=rt.evictions, remat_ops=rt.remat_ops,
        ops_executed=rt.ops_executed,
        meta_accesses=rt.meta_accesses + (rt.uf.accesses if rt.uf else 0),
        peak_memory=rt.peak_memory,
        stall_time=rt.stall_time, offloads=rt.offloads, fetches=rt.fetches,
        prefetch_hits=rt.prefetch_hits,
        prefetch_cancelled=rt.prefetch_cancelled,
        host_peak=eng.host.peak if eng is not None else 0.0,
        **_frag_fields(rt))


@dataclass
class SweepResult:
    log_name: str
    heuristic: str
    baseline_peak: float
    runs: list[RunResult] = field(default_factory=list)
    alloc_mode: str = "counter"

    def last_ok_before_thrash(self, thresh: float = 2.0) -> float | None:
        """Smallest budget fraction with slowdown < thresh (paper's dashed line)."""
        ok = [r for r in self.runs if r.ok and r.slowdown < thresh]
        return min((r.budget for r in ok), default=None)


def measure_baseline(log: Log) -> tuple[float, float]:
    """(peak_memory, total_cost) of an unconstrained run."""
    rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_lru"),
                    dealloc="eager")
    replay(log, rt)
    return rt.peak_memory, rt.total_compute


def resolve_budget(fraction: float, peak: float, pinned: float,
                   budget_mode: str = "peak") -> float:
    """Map a budget fraction to absolute bytes.

    ``"peak"``: fraction of the unconstrained peak (the paper's Fig. 2 axis).
    ``"activation"``: ``pinned + fraction * (peak - pinned)`` — scans the
    evictable (activation/KV) range, which is the meaningful knob for
    captured serving traces whose pinned weights dominate peak.
    """
    if budget_mode == "peak":
        return fraction * peak
    if budget_mode == "activation":
        return pinned + fraction * max(peak - pinned, 0.0)
    raise ValueError(f"unknown budget_mode {budget_mode!r}")


def simulate(
    log: Log,
    heuristic: Heuristic | str,
    budget: float,
    dealloc: str = "eager",
    ignore_small_frac: float = 0.0,
    sample_sqrt: bool = False,
    seed: int = 0,
    thrash_factor: float = 50.0,
    alloc_mode: str | None = None,
    placement: str = "best_fit",
    index: bool = True,
    offload=None,
    faults=None,
    recovery=None,
    sanitize=False,
) -> RunResult:
    h = by_name(heuristic, seed) if isinstance(heuristic, str) else heuristic
    engine = None
    if offload is not None and offload.enabled:
        from ..offload import OffloadEngine, wrap_heuristic
        engine = OffloadEngine(offload)
        h = wrap_heuristic(h, engine)
    if alloc_mode == "pool+host" and engine is None:
        raise ValueError("alloc_mode='pool+host' requires an enabled "
                         "OffloadConfig (host_budget > 0)")
    rt = DTRRuntime(budget=budget, heuristic=h, dealloc=dealloc,
                    ignore_small_frac=ignore_small_frac,
                    sample_sqrt=sample_sqrt, seed=seed,
                    compute_limit=thrash_factor * log.baseline_cost(),
                    allocator=make_allocator(alloc_mode, placement),
                    index=index, offload=engine,
                    faults=faults, recovery=recovery, sanitize=sanitize)
    try:
        replay(log, rt)
    except (OOMError, ThrashError) as e:
        return result_from_runtime(rt, budget, ok=False, error=str(e),
                                   error_kind=classify_error(rt, e))
    return result_from_runtime(rt, budget, ok=True)


def sweep(
    log: Log,
    heuristic: str,
    fractions: list[float],
    dealloc: str = "eager",
    seed: int = 0,
    alloc_mode: str | None = None,
    placement: str = "best_fit",
    index: bool = True,
    budget_mode: str = "peak",
    thrash_factor: float = 50.0,
    offload=None,
    faults=None,
    recovery=None,
) -> SweepResult:
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    out = SweepResult(log_name=log.name, heuristic=heuristic,
                      baseline_peak=peak, alloc_mode=alloc_mode or "counter")
    for f in fractions:
        # Fresh heuristic per run (h_rand carries RNG state; h_eq carries UF).
        out.runs.append(
            simulate(log, by_name(heuristic, seed),
                     budget=resolve_budget(f, peak, pinned, budget_mode),
                     dealloc=dealloc, seed=seed, alloc_mode=alloc_mode,
                     placement=placement, index=index,
                     thrash_factor=thrash_factor, offload=offload,
                     faults=faults, recovery=recovery))
        out.runs[-1].budget = f  # report as fraction
    return out


# ---------------------------------------------------------------------------
# Process-parallel sweep driver
# ---------------------------------------------------------------------------

#: Per-process parsed-log cache for sweep workers, keyed by the spill
#: file path.  Each worker parses a given log once and reuses it for every
#: grid cell it draws — instead of shipping (and re-parsing) the log's full
#: JSON-lines text inside every task payload.
_LOG_CACHE: dict[tuple[str, str], Log] = {}


def _cached_log(path: str, name: str) -> Log:
    key = (path, name)
    log = _LOG_CACHE.get(key)
    if log is None:
        with open(path, "r", encoding="utf-8") as f:
            log = Log.loads(f.read(), name=name)
        _LOG_CACHE[key] = log
    return log


def _simulate_task(payload: tuple) -> RunResult:
    """Worker: one (log, heuristic, fraction) cell.  Logs are referenced by
    spill-file path (see ``_cached_log``), so payloads stay tiny and pickle
    cheaply and deterministically on every start method."""
    (path, name, heuristic, budget, frac, dealloc, seed, alloc_mode,
     placement, index, thrash_factor, offload, faults, recovery) = payload
    log = _cached_log(path, name)
    r = simulate(log, by_name(heuristic, seed), budget=budget,
                 dealloc=dealloc, seed=seed, alloc_mode=alloc_mode,
                 placement=placement, index=index,
                 thrash_factor=thrash_factor, offload=offload,
                 faults=faults, recovery=recovery)
    r.budget = frac  # report as fraction
    return r


def _failed_cell(payload: tuple, msg: str) -> RunResult:
    """Placeholder result for a cell whose worker died (no runtime state
    to read — only the cell's identity survives)."""
    return RunResult(budget=payload[4], ok=False, error=msg,
                     error_kind="worker")


def _run_pool(payloads: list[tuple], workers: int) -> list:
    """Dispatch cells to a process pool, surviving worker deaths.

    Each cell is its own future, so one poisoned cell cannot take the
    whole grid down.  When a worker dies, every future still in flight
    raises ``BrokenProcessPool`` — innocent casualties included — so each
    such cell is retried once in an isolated single-worker pool; a cell
    that kills *that* pool too is recorded as a failed ``RunResult``
    (``error_kind="worker"``) and the rest of the sweep completes.
    """
    from concurrent.futures import ProcessPoolExecutor
    from concurrent.futures.process import BrokenProcessPool

    results: list = [None] * len(payloads)
    broken: list[int] = []
    with ProcessPoolExecutor(max_workers=workers) as pool:
        futs = [pool.submit(_simulate_task, p) for p in payloads]
        for i, fut in enumerate(futs):
            try:
                results[i] = fut.result()
            except BrokenProcessPool:
                broken.append(i)
    for i in broken:
        try:
            with ProcessPoolExecutor(max_workers=1) as solo:
                results[i] = solo.submit(_simulate_task,
                                         payloads[i]).result()
        except BrokenProcessPool:
            results[i] = _failed_cell(payloads[i], "sweep worker died")
    return results


def sweep_parallel(
    logs: list[Log] | Log,
    heuristics: list[str] | str,
    fractions: list[float],
    dealloc: str = "eager",
    seed: int = 0,
    alloc_mode: str | None = None,
    placement: str = "best_fit",
    index: bool = True,
    processes: int | None = None,
    budget_mode: str = "peak",
    thrash_factor: float = 50.0,
    offload=None,
    faults=None,
    recovery=None,
) -> list[SweepResult]:
    """Sweep the budgets × heuristics × models grid across processes.

    Every grid cell is an independent ``simulate`` call, so the grid is
    embarrassingly parallel; cells are dispatched to a process pool (one
    future per cell) and regrouped into one ``SweepResult`` per (model,
    heuristic) pair, in grid order — results are identical to nested
    serial ``sweep`` calls.  ``processes=0`` (or a single-cell grid)
    forces the serial path; pool bring-up failure (restricted
    environments) falls back to serial, and a worker dying mid-sweep
    fails only its own cell (``error_kind="worker"``) — see ``_run_pool``.
    """
    logs = [logs] if isinstance(logs, Log) else list(logs)
    heuristics = ([heuristics] if isinstance(heuristics, str)
                  else list(heuristics))
    # Keyed positionally, not by log.name: duplicate names must not collide.
    baselines = [measure_baseline(log)[0] for log in logs]
    pinned = [log.pinned_bytes() for log in logs]
    grid = [(i, h) for i in range(len(logs)) for h in heuristics]

    # Spill each log to a temp file once; payloads carry the path, workers
    # parse on first touch and cache per process (``_cached_log``).
    tmpdir = tempfile.mkdtemp(prefix="repro-sweep-")
    try:
        paths = []
        for i, log in enumerate(logs):
            path = os.path.join(tmpdir, f"log{i}.jsonl")
            with open(path, "w", encoding="utf-8") as f:
                f.write(log.dumps())
            paths.append(path)
        payloads = [
            (paths[i], logs[i].name, h,
             resolve_budget(f, baselines[i], pinned[i], budget_mode), f,
             dealloc, seed, alloc_mode, placement, index, thrash_factor,
             offload, faults, recovery)
            for i, h in grid for f in fractions]

        runs: list[RunResult] | None = None
        if processes != 0 and len(payloads) > 1:
            try:
                from concurrent.futures import ProcessPoolExecutor  # noqa: F401
            except ImportError:
                pass
            else:
                try:
                    workers = processes or min(len(payloads),
                                               os.cpu_count() or 1)
                    runs = _run_pool(payloads, workers)
                except (OSError, PermissionError):
                    # Pool bring-up failure (restricted environments):
                    # redo the whole grid serially — cells are
                    # deterministic, so results match an undisturbed
                    # parallel run.  (Worker deaths are handled inside
                    # _run_pool, per cell.)
                    runs = None
        if runs is None:
            runs = [_simulate_task(p) for p in payloads]
    finally:
        for key in [k for k in _LOG_CACHE if k[0].startswith(tmpdir)]:
            del _LOG_CACHE[key]
        shutil.rmtree(tmpdir, ignore_errors=True)

    out: list[SweepResult] = []
    it = iter(runs)
    for i, h in grid:
        sw = SweepResult(log_name=logs[i].name, heuristic=h,
                         baseline_peak=baselines[i],
                         alloc_mode=alloc_mode or "counter")
        for _ in fractions:
            sw.runs.append(next(it))
        out.append(sw)
    return out
