"""Budget-sweep simulation driver (the experiment harness behind Fig. 2/3).

Replays a log at a list of memory budgets (absolute bytes or fractions of the
unconstrained peak) for each heuristic, recording compute slowdown, eviction /
remat counts, and metadata accesses; detects OOM (budget below feasibility)
and thrashing (slowdown >= threshold).

``alloc_mode`` selects the memory model: ``"counter"`` (default) is the
paper's fungible byte counter; ``"pool"`` maps storages onto a simulated
address space requiring contiguous fits with window eviction
(``repro.alloc``); ``"pool_nofrag"`` keeps counter semantics bit-for-bit but
tracks block placement for fragmentation telemetry.

``index`` toggles the incremental eviction index
(``repro.core.evict_index``); ``index=False`` runs the linear-scan oracle.
Both produce identical eviction decisions (only ``meta_accesses`` may
differ); large sweeps additionally parallelize across processes with
``sweep_parallel``.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

from .graph import Log, replay
from .heuristics import Heuristic, by_name
from .runtime import DTRRuntime, OOMError, ThrashError

ALLOC_MODES = ("counter", "pool", "pool_nofrag")


@dataclass
class RunResult:
    budget: float
    ok: bool
    slowdown: float = float("inf")
    compute: float = 0.0
    base_compute: float = 0.0
    evictions: int = 0
    remat_ops: int = 0
    ops_executed: int = 0
    meta_accesses: int = 0
    peak_memory: float = 0.0
    error: str = ""
    # Fragmentation telemetry (pool-backed runs; zeros in counter mode).
    largest_free: float = 0.0
    frag_ratio: float = 0.0
    failed_fits: int = 0
    evict_windows: int = 0


def make_allocator(alloc_mode: str | None, placement: str = "best_fit"):
    """Build the allocator backend for ``alloc_mode`` (None/'counter' => None)."""
    if alloc_mode in (None, "counter"):
        return None
    from ..alloc import PoolAllocator
    if alloc_mode == "pool":
        return PoolAllocator(placement=placement, contiguous=True)
    if alloc_mode == "pool_nofrag":
        return PoolAllocator(placement=placement, contiguous=False)
    raise ValueError(f"unknown alloc_mode {alloc_mode!r}; "
                     f"expected one of {ALLOC_MODES}")


def _frag_fields(rt: DTRRuntime) -> dict:
    frag = rt.fragmentation()
    if frag is None:
        return {}
    return dict(largest_free=frag.largest_free, frag_ratio=frag.frag_ratio,
                failed_fits=frag.failed_fits,
                evict_windows=frag.evict_windows)


def result_from_runtime(rt: DTRRuntime, budget: float, ok: bool,
                        error: str = "") -> RunResult:
    """Assemble a RunResult from a finished (or aborted) runtime.

    Single source of truth for the field mapping — ``simulate`` and the
    trace subsystem's ``run_trace`` both build their results here, so the
    two report paths cannot drift.
    """
    return RunResult(
        budget=budget, ok=ok, error=error,
        slowdown=rt.slowdown() if ok else float("inf"),
        compute=rt.total_compute, base_compute=rt.base_compute,
        evictions=rt.evictions, remat_ops=rt.remat_ops,
        ops_executed=rt.ops_executed,
        meta_accesses=rt.meta_accesses + (rt.uf.accesses if rt.uf else 0),
        peak_memory=rt.peak_memory, **_frag_fields(rt))


@dataclass
class SweepResult:
    log_name: str
    heuristic: str
    baseline_peak: float
    runs: list[RunResult] = field(default_factory=list)
    alloc_mode: str = "counter"

    def last_ok_before_thrash(self, thresh: float = 2.0) -> float | None:
        """Smallest budget fraction with slowdown < thresh (paper's dashed line)."""
        ok = [r for r in self.runs if r.ok and r.slowdown < thresh]
        return min((r.budget for r in ok), default=None)


def measure_baseline(log: Log) -> tuple[float, float]:
    """(peak_memory, total_cost) of an unconstrained run."""
    rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_lru"),
                    dealloc="eager")
    replay(log, rt)
    return rt.peak_memory, rt.total_compute


def resolve_budget(fraction: float, peak: float, pinned: float,
                   budget_mode: str = "peak") -> float:
    """Map a budget fraction to absolute bytes.

    ``"peak"``: fraction of the unconstrained peak (the paper's Fig. 2 axis).
    ``"activation"``: ``pinned + fraction * (peak - pinned)`` — scans the
    evictable (activation/KV) range, which is the meaningful knob for
    captured serving traces whose pinned weights dominate peak.
    """
    if budget_mode == "peak":
        return fraction * peak
    if budget_mode == "activation":
        return pinned + fraction * max(peak - pinned, 0.0)
    raise ValueError(f"unknown budget_mode {budget_mode!r}")


def simulate(
    log: Log,
    heuristic: Heuristic | str,
    budget: float,
    dealloc: str = "eager",
    ignore_small_frac: float = 0.0,
    sample_sqrt: bool = False,
    seed: int = 0,
    thrash_factor: float = 50.0,
    alloc_mode: str | None = None,
    placement: str = "best_fit",
    index: bool = True,
) -> RunResult:
    h = by_name(heuristic, seed) if isinstance(heuristic, str) else heuristic
    rt = DTRRuntime(budget=budget, heuristic=h, dealloc=dealloc,
                    ignore_small_frac=ignore_small_frac,
                    sample_sqrt=sample_sqrt, seed=seed,
                    compute_limit=thrash_factor * log.baseline_cost(),
                    allocator=make_allocator(alloc_mode, placement),
                    index=index)
    try:
        replay(log, rt)
    except (OOMError, ThrashError) as e:
        return result_from_runtime(rt, budget, ok=False, error=str(e))
    return result_from_runtime(rt, budget, ok=True)


def sweep(
    log: Log,
    heuristic: str,
    fractions: list[float],
    dealloc: str = "eager",
    seed: int = 0,
    alloc_mode: str | None = None,
    placement: str = "best_fit",
    index: bool = True,
    budget_mode: str = "peak",
    thrash_factor: float = 50.0,
) -> SweepResult:
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    out = SweepResult(log_name=log.name, heuristic=heuristic,
                      baseline_peak=peak, alloc_mode=alloc_mode or "counter")
    for f in fractions:
        # Fresh heuristic per run (h_rand carries RNG state; h_eq carries UF).
        out.runs.append(
            simulate(log, by_name(heuristic, seed),
                     budget=resolve_budget(f, peak, pinned, budget_mode),
                     dealloc=dealloc, seed=seed, alloc_mode=alloc_mode,
                     placement=placement, index=index,
                     thrash_factor=thrash_factor))
        out.runs[-1].budget = f  # report as fraction
    return out


# ---------------------------------------------------------------------------
# Process-parallel sweep driver
# ---------------------------------------------------------------------------

def _simulate_task(payload: tuple) -> RunResult:
    """Worker: one (log, heuristic, fraction) cell.  Logs travel as their
    JSON-lines serialization so the payload pickles cheaply and
    deterministically on every start method."""
    (text, name, heuristic, budget, frac, dealloc, seed, alloc_mode,
     placement, index, thrash_factor) = payload
    log = Log.loads(text, name=name)
    r = simulate(log, by_name(heuristic, seed), budget=budget,
                 dealloc=dealloc, seed=seed, alloc_mode=alloc_mode,
                 placement=placement, index=index,
                 thrash_factor=thrash_factor)
    r.budget = frac  # report as fraction
    return r


def sweep_parallel(
    logs: list[Log] | Log,
    heuristics: list[str] | str,
    fractions: list[float],
    dealloc: str = "eager",
    seed: int = 0,
    alloc_mode: str | None = None,
    placement: str = "best_fit",
    index: bool = True,
    processes: int | None = None,
    budget_mode: str = "peak",
    thrash_factor: float = 50.0,
) -> list[SweepResult]:
    """Sweep the budgets × heuristics × models grid across processes.

    Every grid cell is an independent ``simulate`` call, so the grid is
    embarrassingly parallel; cells are dispatched to a process pool and
    regrouped into one ``SweepResult`` per (model, heuristic) pair, in grid
    order — results are identical to nested serial ``sweep`` calls.
    ``processes=0`` (or a single-cell grid) forces the serial path; any
    pool bring-up failure (restricted environments) falls back to serial.
    """
    logs = [logs] if isinstance(logs, Log) else list(logs)
    heuristics = ([heuristics] if isinstance(heuristics, str)
                  else list(heuristics))
    # Keyed positionally, not by log.name: duplicate names must not collide.
    baselines = [measure_baseline(log)[0] for log in logs]
    pinned = [log.pinned_bytes() for log in logs]
    texts = [log.dumps() for log in logs]
    grid = [(i, h) for i in range(len(logs)) for h in heuristics]
    payloads = [
        (texts[i], logs[i].name, h,
         resolve_budget(f, baselines[i], pinned[i], budget_mode), f,
         dealloc, seed, alloc_mode, placement, index, thrash_factor)
        for i, h in grid for f in fractions]

    runs: list[RunResult] | None = None
    if processes != 0 and len(payloads) > 1:
        try:
            from concurrent.futures import ProcessPoolExecutor
            from concurrent.futures.process import BrokenProcessPool
        except ImportError:
            pass
        else:
            try:
                workers = processes or min(len(payloads),
                                           os.cpu_count() or 1)
                with ProcessPoolExecutor(max_workers=workers) as pool:
                    runs = list(pool.map(_simulate_task, payloads,
                                         chunksize=1))
            except (OSError, PermissionError, BrokenProcessPool):
                # Pool bring-up failure or a killed worker (e.g. OOM): redo
                # the whole grid serially — cells are deterministic, so
                # results match an undisturbed parallel run.
                runs = None
    if runs is None:
        runs = [_simulate_task(p) for p in payloads]

    out: list[SweepResult] = []
    it = iter(runs)
    for i, h in grid:
        sw = SweepResult(log_name=logs[i].name, heuristic=h,
                         baseline_peak=baselines[i],
                         alloc_mode=alloc_mode or "counter")
        for _ in fractions:
            sw.runs.append(next(it))
        out.append(sw)
    return out
