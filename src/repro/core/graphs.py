"""Synthetic operator-graph builders used by tests and benchmarks.

Includes the formal-bounds graphs (linear feedforward of Thm 3.1, adversarial
family of Thm 3.2), Fig.-2-style model-shaped graphs (MLP/ResNet/UNet/
Transformer/LSTM/TreeLSTM) with synthesized backward passes, and random DAGs
for property tests.  All builders emit ``core.graph.Log`` programs with
framework-style RELEASE events (computed from last use), so DTR sees the same
liveness information the PyTorch prototype received from refcounting.
"""
from __future__ import annotations

import random
from typing import Callable

from .graph import Log, LogBuilder


# ---------------------------------------------------------------------------
# Theorem graphs
# ---------------------------------------------------------------------------

def linear_network(n: int, unit_cost: float = 1.0, unit_size: int = 1,
                   costs=None, sizes=None) -> Log:
    """N-op linear feedforward net + backward, per Appendix A.1.

    Forward:  t_i = f_i(t_{i-1});  t_0 is a pinned constant input.
    Backward: t̂_N = f̂_N(t_{N-1});  t̂_i = f̂_i(t_{i-1}, t̂_{i+1});
              t̂_1 = f̂_1(t̂_2).
    Releases are emitted at last use, so e.g. t_N dies right after the
    forward pass (it feeds no backward op) — matching the paper's liveness.
    The final gradient t̂_1 is kept (output condition).

    ``costs`` / ``sizes`` (length-``n`` sequences) make the chain
    heterogeneous: layer ``i`` costs ``costs[i-1]`` and its activation /
    gradient occupy ``sizes[i-1]`` bytes (the input ``t_0`` takes
    ``sizes[0]``).  This is the ground-truth family for the differential
    solver tests in ``repro.static`` — real checkpointing trade-offs are
    driven by exactly this per-layer cost/size variation.  Defaults
    reproduce the homogeneous unit chain bit-for-bit.
    """
    costs = [unit_cost] * n if costs is None else [float(c) for c in costs]
    sizes = [unit_size] * n if sizes is None else [int(s) for s in sizes]
    assert len(costs) == n and len(sizes) == n
    b = LogBuilder(name=f"linear{n}")
    t0 = b.constant(sizes[0] if n else unit_size, name="t0")
    fwd = [t0]
    for i in range(1, n + 1):
        (ti,) = b.call([fwd[-1]], [sizes[i - 1]], costs[i - 1], f"f{i}",
                       out_names=[f"t{i}"])
        fwd.append(ti)
    grads: dict[int, str] = {}
    (gN,) = b.call([fwd[n - 1]], [sizes[n - 1]], costs[n - 1], f"g{n}",
                   out_names=[f"g{n}"])
    grads[n] = gN
    for i in range(n - 1, 1, -1):
        (gi,) = b.call([fwd[i - 1], grads[i + 1]], [sizes[i - 1]],
                       costs[i - 1], f"g{i}", out_names=[f"g{i}"])
        grads[i] = gi
    (g1,) = b.call([grads[2]], [sizes[0]], costs[0], "g1", out_names=["g1"])
    grads[1] = g1
    return b.auto_release(keep=[g1])


class AdversarialDriver:
    """Interactive adversary of Theorem 3.2.

    The graph is revealed one node at a time: t0 (a pinned constant) has B
    children; at each step the adversary inspects the runtime's resident set
    and extends a path from t0 whose tensors are *all* evicted, forcing DTR
    to rematerialize the entire path.  ``run`` returns (ops_executed, n).
    """

    def __init__(self, n: int, b: int) -> None:
        assert n > b >= 2
        self.n, self.b = n, b

    def run(self, rt) -> int:
        t0 = rt.constant(1, name="t0")
        # paths[j] = list of tids along path j (excluding t0).
        paths: list[list[int]] = []
        for j in range(self.b):
            (tj,) = rt.call(f"p{j}.0", 1.0, [t0], [1])
            paths.append([tj])
        made = self.b
        while made < self.n:
            resident = rt.resident_tids()
            # Find a path whose tensors are all evicted; B paths vs B-1
            # memory slots below t0 guarantees one exists.
            target = None
            for j, p in enumerate(paths):
                if not any(t in resident for t in p):
                    target = j
                    break
            if target is None:
                # Budget exceeds pigeonhole regime; extend the path with the
                # fewest resident tensors.
                target = min(
                    range(self.b),
                    key=lambda j: sum(t in resident for t in paths[j]))
            tail = paths[target][-1]
            (t_new,) = rt.call(f"p{target}.{len(paths[target])}", 1.0,
                               [tail], [1])
            paths[target].append(t_new)
            made += 1
        return rt.ops_executed


# ---------------------------------------------------------------------------
# Backward-pass synthesis for model graphs
# ---------------------------------------------------------------------------

class _Net:
    """Tiny graph-with-autograd builder over LogBuilder.

    ``op(name, inputs, out_size, cost)`` records a forward op; ``backward``
    synthesizes reverse-mode gradient ops (one grad op per (op, input) pair,
    plus accumulation adds at fan-in), mirroring how frameworks structure the
    backward graph.  Parameter gradients and the loss are kept live at the
    end (the simulator output condition).
    """

    def __init__(self, name: str):
        self.b = LogBuilder(name=name)
        self.params: list[str] = []
        self.fwd_ops: list[tuple[str, list[str], str, int, float]] = []
        self.sizes: dict[str, int] = {}

    def param(self, size: int, name: str | None = None) -> str:
        t = self.b.constant(size, name=name)
        self.params.append(t)
        self.sizes[t] = size
        return t

    def input(self, size: int, name: str | None = None) -> str:
        t = self.b.constant(size, name=name)
        self.sizes[t] = size
        return t

    def op(self, name: str, inputs: list[str], out_size: int,
           cost: float) -> str:
        (out,) = self.b.call(inputs, [out_size], cost, name)
        self.sizes[out] = out_size
        self.fwd_ops.append((name, list(inputs), out, out_size, cost))
        return out

    def backward(self, loss: str) -> Log:
        # Seed: d(loss) = 1.
        grads: dict[str, str] = {}
        (g,) = self.b.call([loss], [self.sizes[loss]], 1.0, "grad_seed")
        grads[loss] = g
        self.sizes[g] = self.sizes[loss]
        # Reverse topological order over recorded ops.
        for name, inputs, out, out_size, cost in reversed(self.fwd_ops):
            if out not in grads:
                continue  # branch not on the loss path
            gout = grads[out]
            for inp in inputs:
                # d(inp) += vjp(op, inp)(gout); depends on the op's inputs
                # (activations) + upstream grad, like real autograd.
                (gi,) = self.b.call(
                    inputs + [gout], [self.sizes[inp]], cost,
                    f"d_{name}/{inp}")
                self.sizes[gi] = self.sizes[inp]
                if inp in grads:
                    (acc,) = self.b.call(
                        [grads[inp], gi], [self.sizes[inp]],
                        max(self.sizes[inp] * 1e-3, 0.1), f"acc_{inp}")
                    self.sizes[acc] = self.sizes[inp]
                    grads[inp] = acc
                else:
                    grads[inp] = gi
        keep = [grads[p] for p in self.params if p in grads] + [loss]
        return self.b.auto_release(keep=keep)


# ---------------------------------------------------------------------------
# Fig. 2-style model graphs (shapes chosen to echo the paper's models)
# ---------------------------------------------------------------------------

def mlp(depth: int = 16, width: int = 64, batch: int = 32) -> Log:
    """Plain MLP: matmul + pointwise per layer (activation-dominated)."""
    net = _Net(f"mlp{depth}")
    act = batch * width
    x = net.input(act)
    h = x
    for i in range(depth):
        w = net.param(width * width // 8, name=f"w{i}")
        h = net.op(f"mm{i}", [h, w], act, cost=float(width))
        h = net.op(f"relu{i}", [h], act, cost=1.0)
    loss = net.op("loss", [h], 1, cost=1.0)
    return net.backward(loss)


def resnet(blocks: int = 16, width: int = 64, batch: int = 32) -> Log:
    """Residual chain: two convs + skip add per block (ResNet-shaped)."""
    net = _Net(f"resnet{blocks}")
    act = batch * width
    h = net.input(act)
    for i in range(blocks):
        w1 = net.param(width * 9, name=f"w{i}a")
        w2 = net.param(width * 9, name=f"w{i}b")
        a = net.op(f"conv{i}a", [h, w1], act, cost=float(width))
        a = net.op(f"relu{i}a", [a], act, cost=1.0)
        a = net.op(f"conv{i}b", [a, w2], act, cost=float(width))
        h = net.op(f"add{i}", [h, a], act, cost=1.0)
        h = net.op(f"relu{i}b", [h], act, cost=1.0)
    loss = net.op("loss", [h], 1, cost=1.0)
    return net.backward(loss)


def unet(depth: int = 5, width: int = 32, batch: int = 8) -> Log:
    """U-shaped net with long skip connections (downs feed ups)."""
    net = _Net(f"unet{depth}")
    width = width * batch
    h = net.input(width * (2 ** depth))
    skips = []
    # Down path: spatial size halves, channels double => tensor size ~const,
    # mimic by keeping sizes but rising cost.
    for i in range(depth):
        w = net.param(width * 9, name=f"dw{i}")
        h = net.op(f"down{i}", [h, w], width * (2 ** (depth - i)),
                   cost=float(width * (2 ** (depth - i))))
        skips.append(h)
        h = net.op(f"pool{i}", [h], width * (2 ** (depth - i - 1)), cost=1.0)
    for i in reversed(range(depth)):
        w = net.param(width * 9, name=f"uw{i}")
        h = net.op(f"up{i}", [h, w], width * (2 ** (depth - i)),
                   cost=float(width * (2 ** (depth - i))))
        h = net.op(f"cat{i}", [h, skips[i]], width * (2 ** (depth - i + 1)),
                   cost=1.0)
    loss = net.op("loss", [h], 1, cost=1.0)
    return net.backward(loss)


def transformer(layers: int = 8, d: int = 64, seq: int = 32,
                batch: int = 8) -> Log:
    """Decoder-block stack: qkv, attention, proj, 2-matmul MLP per layer."""
    net = _Net(f"transformer{layers}")
    size = batch * d * seq
    h = net.input(size)
    for i in range(layers):
        wqkv = net.param(3 * d * d, name=f"wqkv{i}")
        wo = net.param(d * d, name=f"wo{i}")
        w1 = net.param(4 * d * d, name=f"w1_{i}")
        w2 = net.param(4 * d * d, name=f"w2_{i}")
        ln1 = net.op(f"ln1_{i}", [h], size, cost=1.0)
        qkv = net.op(f"qkv{i}", [ln1, wqkv], 3 * size, cost=float(3 * d))
        scores = net.op(f"scores{i}", [qkv], batch * seq * seq,
                        cost=float(seq))
        probs = net.op(f"softmax{i}", [scores], batch * seq * seq, cost=2.0)
        attn = net.op(f"attnv{i}", [probs, qkv], size, cost=float(seq))
        proj = net.op(f"proj{i}", [attn, wo], size, cost=float(d))
        h = net.op(f"res1_{i}", [h, proj], size, cost=1.0)
        ln2 = net.op(f"ln2_{i}", [h], size, cost=1.0)
        m1 = net.op(f"fc1_{i}", [ln2, w1], 4 * size, cost=float(4 * d))
        ge = net.op(f"gelu{i}", [m1], 4 * size, cost=2.0)
        m2 = net.op(f"fc2_{i}", [ge, w2], size, cost=float(4 * d))
        h = net.op(f"res2_{i}", [h, m2], size, cost=1.0)
    loss = net.op("loss", [h], 1, cost=1.0)
    return net.backward(loss)


def lstm(steps: int = 32, width: int = 64, batch: int = 32) -> Log:
    """Unrolled LSTM chain (dynamic-model shaped: long temporal chain)."""
    net = _Net(f"lstm{steps}")
    act = batch * width
    wx = net.param(width * width // 2, name="wx")
    wh = net.param(width * width // 2, name="wh")
    h = net.input(act, name="h0")
    c = net.input(act, name="c0")
    for i in range(steps):
        x = net.input(act, name=f"x{i}")
        gates = net.op(f"gates{i}", [x, h, wx, wh], 4 * act,
                       cost=float(8 * width))
        c = net.op(f"cell{i}", [gates, c], act, cost=2.0)
        h = net.op(f"hid{i}", [gates, c], act, cost=2.0)
    loss = net.op("loss", [h], 1, cost=1.0)
    return net.backward(loss)


def treelstm(depth: int = 5, width: int = 64, seed: int = 0,
             batch: int = 16) -> Log:
    """TreeLSTM over a (complete) binary tree — the paper's dynamic model."""
    net = _Net(f"treelstm{depth}")
    act = batch * width
    w = net.param(width * width // 2, name="w")

    def build(d: int) -> tuple[str, str]:
        if d == 0:
            leaf = net.input(act)
            h = net.op(f"leaf_h.{leaf}", [leaf, w], act, cost=float(width))
            c = net.op(f"leaf_c.{leaf}", [leaf, w], act, cost=float(width))
            return h, c
        lh, lc = build(d - 1)
        rh, rc = build(d - 1)
        g = net.op(f"tg.{d}.{net.b._fresh}", [lh, rh, w], 4 * act,
                   cost=float(4 * width))
        c = net.op(f"tc.{d}.{net.b._fresh}", [g, lc, rc], act, cost=2.0)
        h = net.op(f"th.{d}.{net.b._fresh}", [g, c], act, cost=2.0)
        return h, c

    h, _ = build(depth)
    loss = net.op("loss", [h], 1, cost=1.0)
    return net.backward(loss)


def random_dag(n_ops: int, seed: int = 0, max_fan_in: int = 3,
               max_size: int = 8) -> Log:
    """Random connected DAG + synthesized backward (property tests)."""
    rng = random.Random(seed)
    net = _Net(f"rand{n_ops}_{seed}")
    frontier = [net.input(rng.randint(1, max_size))]
    for i in range(n_ops):
        k = rng.randint(1, min(max_fan_in, len(frontier)))
        ins = rng.sample(frontier, k)
        out = net.op(f"op{i}", ins, rng.randint(1, max_size),
                     cost=float(rng.randint(1, 4)))
        frontier.append(out)
        if len(frontier) > 12:
            frontier.pop(0)
    loss = net.op("loss", [frontier[-1]], 1, cost=1.0)
    return net.backward(loss)


MODEL_GRAPHS: dict[str, Callable[[], Log]] = {
    "mlp": mlp,
    "resnet": resnet,
    "unet": unet,
    "transformer": transformer,
    "lstm": lstm,
    "treelstm": treelstm,
}
