"""DTR core: the paper's contribution as a reusable library.

Layers:
  runtime     — greedy online eviction/rematerialization engine (App. C)
  heuristics  — h_DTR family + caching/checkpointing baselines (Sec. 4.1)
  evict_index — incremental eviction index: sublinear victim selection
  graph       — operator log format + replay (App. C.6)
  graphs      — synthetic model graphs incl. Thm 3.1/3.2 families
  simulator   — budget sweep harness (Fig. 2/3) + parallel sweep driver
  baselines   — static checkpointing planners (Fig. 3)
  planner     — trace-time DTR plan -> jax.checkpoint policy (TPU-native form)
"""
from .evict_index import EvictIndex, ScopedInvalidator
from .graph import Log, LogBuilder, replay
from .heuristics import by_name as heuristic_by_name
from .runtime import DTRRuntime, OOMError

__all__ = [
    "Log", "LogBuilder", "replay", "DTRRuntime", "OOMError",
    "EvictIndex", "ScopedInvalidator", "heuristic_by_name",
]
