"""Eviction heuristics (Sec. 4.1 + Appendix C.3/D.1 of the DTR paper).

All heuristics are score functions over storages; the runtime evicts the
minimum-score storage.  The ablation grid h'(s, m, c) of Appendix D.1 is
exposed via ``make_ablation``; the named heuristics from the paper are module
singletons/factories:

    h_dtr        (c0 + Σ_{e*} c0) / (m · s)     exact evicted neighborhood
    h_dtr_eq     (c0 + Σ_{ẽ*} c0) / (m · s)     union-find approximation
    h_dtr_local  c0 / (m · s)
    h_lru        1 / s
    h_size       1 / m                           GreedyRemat (Kumar et al.)
    h_msps       (c0 + Σ_{anc_e} c0) / m         MSPS (Peng et al.)
    h_rand       U(0, 1)
    h_estar      c0 + Σ_{e*} c0                  Thm 3.1 heuristic (h_{e*})
"""
from __future__ import annotations

import random


class Heuristic:
    name: str = "base"
    needs_uf: bool = False

    def score(self, rt, s) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<heuristic {self.name}>"


class HDTR(Heuristic):
    """Full h_DTR with exact evicted neighborhood e*."""
    name = "h_dtr"

    def score(self, rt, s) -> float:
        c = s.local_cost + rt.evicted_neighborhood_cost(s)
        return c / (s.size * rt.staleness(s))


class HDTREq(Heuristic):
    """h_DTR^eq: union-find ẽ* with the splitting approximation."""
    name = "h_dtr_eq"
    needs_uf = True

    def score(self, rt, s) -> float:
        c = s.local_cost + rt.eq_neighborhood_cost(s)
        return c / (s.size * rt.staleness(s))


class HDTRLocal(Heuristic):
    name = "h_dtr_local"

    def score(self, rt, s) -> float:
        return s.local_cost / (s.size * rt.staleness(s))


class HLRU(Heuristic):
    name = "h_lru"

    def score(self, rt, s) -> float:
        return 1.0 / rt.staleness(s)


class HSize(Heuristic):
    name = "h_size"

    def score(self, rt, s) -> float:
        return 1.0 / max(s.size, 1)


class HMSPS(Heuristic):
    """MSPS: rematerialization cost over evicted *ancestors*, per byte."""
    name = "h_msps"

    def score(self, rt, s) -> float:
        c = s.local_cost + rt.evicted_ancestor_cost(s)
        return c / max(s.size, 1)


class HRandom(Heuristic):
    name = "h_rand"

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)

    def score(self, rt, s) -> float:
        return self._rng.random()


class HEStar(Heuristic):
    """h_{e*} from Sec. 3 / App. A: projected cost per byte, no staleness.

    Under unit cost/size this reduces to |e*(t)| + 1, the heuristic of
    Theorem 3.1 (evict the tensor with the smallest evicted neighborhood).
    """
    name = "h_estar"

    def score(self, rt, s) -> float:
        return (s.local_cost + rt.evicted_neighborhood_cost(s)) / max(s.size, 1)


class HAblation(Heuristic):
    """Parameterized h'(s, m, c) of Appendix D.1.

    stale  in {True, False}
    mem    in {True, False}
    cost   in {"estar", "eq", "local", "no"}
    """

    def __init__(self, stale: bool, mem: bool, cost: str) -> None:
        assert cost in ("estar", "eq", "local", "no")
        self.stale, self.mem, self.cost = stale, mem, cost
        self.needs_uf = cost == "eq"
        self.name = (f"h_s{'1' if stale else '0'}"
                     f"m{'1' if mem else '0'}c_{cost}")

    def score(self, rt, s) -> float:
        if self.cost == "estar":
            c = s.local_cost + rt.evicted_neighborhood_cost(s)
        elif self.cost == "eq":
            c = s.local_cost + rt.eq_neighborhood_cost(s)
        elif self.cost == "local":
            c = s.local_cost
        else:
            c = 1.0
        denom = 1.0
        if self.mem:
            denom *= max(s.size, 1)
        if self.stale:
            denom *= rt.staleness(s)
        return c / denom


def make_ablation(stale: bool, mem: bool, cost: str) -> Heuristic:
    return HAblation(stale, mem, cost)


def window_cost(rt, heuristic: Heuristic, storages, cache=None) -> float:
    """Summed heuristic score of a candidate eviction window.

    Contiguity-aware eviction (``repro.alloc``) ranks contiguous windows of
    storages by this aggregate instead of scoring storages one at a time;
    ``cache`` (sid -> score) amortizes repeated scoring while sliding the
    window across the address space.  Each fresh evaluation counts as one
    metadata access, matching ``DTRRuntime._pick_victim`` accounting.
    """
    total = 0.0
    for s in storages:
        if cache is not None and s.sid in cache:
            total += cache[s.sid]
            continue
        rt.meta_accesses += 1
        sc = heuristic.score(rt, s)
        if cache is not None:
            cache[s.sid] = sc
        total += sc
    return total


def by_name(name: str, seed: int = 0) -> Heuristic:
    table = {
        "h_dtr": HDTR,
        "h_dtr_eq": HDTREq,
        "h_dtr_local": HDTRLocal,
        "h_lru": HLRU,
        "h_size": HSize,
        "h_msps": HMSPS,
        "h_estar": HEStar,
    }
    if name == "h_rand":
        return HRandom(seed)
    return table[name]()


ALL_NAMES = ["h_dtr", "h_dtr_eq", "h_dtr_local", "h_lru", "h_size",
             "h_msps", "h_rand"]
