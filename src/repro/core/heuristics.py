"""Eviction heuristics (Sec. 4.1 + Appendix C.3/D.1 of the DTR paper).

All heuristics are score functions over storages; the runtime evicts the
minimum-score storage.  The ablation grid h'(s, m, c) of Appendix D.1 is
exposed via ``make_ablation``; the named heuristics from the paper are module
singletons/factories:

    h_dtr        (c0 + Σ_{e*} c0) / (m · s)     exact evicted neighborhood
    h_dtr_eq     (c0 + Σ_{ẽ*} c0) / (m · s)     union-find approximation
    h_dtr_local  c0 / (m · s)
    h_lru        1 / s
    h_size       1 / m                           GreedyRemat (Kumar et al.)
    h_msps       (c0 + Σ_{anc_e} c0) / m         MSPS (Peng et al.)
    h_rand       U(0, 1)
    h_estar      c0 + Σ_{e*} c0                  Thm 3.1 heuristic (h_{e*})
"""
from __future__ import annotations

import random


class Heuristic:
    """Score function over storages; the runtime evicts the minimum.

    A heuristic may additionally declare a *staleness-separable*
    decomposition for the incremental eviction index
    (``repro.core.evict_index``)::

        score(S, clock) == key(S) / staleness(S)   if uses_staleness
        score(S, clock) == key(S)                  otherwise

    ``key`` must be free of the clock: it changes only on discrete events
    (evict / remat / banish / alias registration), so heap entries keyed on
    it stay valid as simulated time advances.  Heuristics that cannot offer
    this (``h_rand`` consumes RNG state per evaluation) leave ``separable``
    False and the runtime falls back to the linear scan.

    Contract for ``uses_staleness=False``: ``key`` must be the *same
    expression* as ``score`` (bit-identical floats, not merely equal
    values) — the index's key-ordered selection breaks ties by sid under
    that identity.  Staleness-aware keys may associate differently from
    their score formula (e.g. ``(c/m)/t`` vs ``c/(m*t)``); the index
    absorbs the ulp-level difference with epsilon slack on its bounds and
    always re-verifies with ``score`` itself.
    """

    name: str = "base"
    needs_uf: bool = False
    separable: bool = False         # has a key()/staleness decomposition
    uses_staleness: bool = False    # score == key / staleness
    cost_aware: bool = False        # key prices recomputation (per byte) —
    #                                 required as the base of the two-choice
    #                                 hybrid offload policy (repro.offload)

    def score(self, rt, s) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def key(self, rt, s) -> float:  # pragma: no cover - interface
        raise NotImplementedError(f"{self.name} is not separable")

    def __repr__(self) -> str:
        return f"<heuristic {self.name}>"


class HDTR(Heuristic):
    """Full h_DTR with exact evicted neighborhood e*.

    The numerator charges the storage's own compute, the aggregate cost of
    dead subgraphs attached to it (``dead_cost`` — evicted cones the e*
    walk no longer traverses member-by-member), and the live evicted
    neighborhood.
    """
    name = "h_dtr"
    separable = True
    uses_staleness = True
    cost_aware = True

    def score(self, rt, s) -> float:
        c = s.local_cost + s.dead_cost + rt.evicted_neighborhood_cost(s)
        return c / (s.size * rt.staleness(s))

    def key(self, rt, s) -> float:
        return (s.local_cost + s.dead_cost
                + rt.evicted_neighborhood_cost(s)) / s.size


class HDTREq(Heuristic):
    """h_DTR^eq: union-find ẽ* with the splitting approximation.

    ``key()`` reads the cached per-root component sums maintained
    incrementally by the union-find (via ``eq_neighborhood_cost``'s
    snapshot fast path) — no neighborhood re-walk per recomputation.
    """
    name = "h_dtr_eq"
    needs_uf = True
    separable = True
    uses_staleness = True
    cost_aware = True

    def score(self, rt, s) -> float:
        # No ``dead_cost`` term here: dead storages are *members* of the
        # equivalence classes, so their compute already sits in the
        # component sums ẽ* reads (the exact walk instead prunes them and
        # charges the attached cones).
        c = s.local_cost + rt.eq_neighborhood_cost(s)
        return c / (s.size * rt.staleness(s))

    def key(self, rt, s) -> float:
        return (s.local_cost + rt.eq_neighborhood_cost(s)) / s.size


class HDTRLocal(Heuristic):
    name = "h_dtr_local"
    separable = True
    uses_staleness = True
    cost_aware = True

    def score(self, rt, s) -> float:
        return s.local_cost / (s.size * rt.staleness(s))

    def key(self, rt, s) -> float:
        return s.local_cost / s.size


class HLRU(Heuristic):
    name = "h_lru"
    separable = True
    uses_staleness = True

    def score(self, rt, s) -> float:
        return 1.0 / rt.staleness(s)

    def key(self, rt, s) -> float:
        return 1.0


class HSize(Heuristic):
    name = "h_size"
    separable = True

    def score(self, rt, s) -> float:
        return 1.0 / max(s.size, 1)

    def key(self, rt, s) -> float:
        return 1.0 / max(s.size, 1)


class HMSPS(Heuristic):
    """MSPS: rematerialization cost over evicted *ancestors*, per byte."""
    name = "h_msps"
    separable = True
    cost_aware = True

    def score(self, rt, s) -> float:
        c = s.local_cost + rt.evicted_ancestor_cost(s)
        return c / max(s.size, 1)

    def key(self, rt, s) -> float:
        return self.score(rt, s)


class HRandom(Heuristic):
    name = "h_rand"
    # Not separable: each evaluation consumes RNG state, so the sampled
    # sequence is tied to the linear scan's evaluation order.

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)

    def score(self, rt, s) -> float:
        return self._rng.random()


class HEStar(Heuristic):
    """h_{e*} from Sec. 3 / App. A: projected cost per byte, no staleness.

    Under unit cost/size this reduces to |e*(t)| + 1, the heuristic of
    Theorem 3.1 (evict the tensor with the smallest evicted neighborhood).
    """
    name = "h_estar"
    separable = True
    cost_aware = True

    def score(self, rt, s) -> float:
        return (s.local_cost + s.dead_cost
                + rt.evicted_neighborhood_cost(s)) / max(s.size, 1)

    def key(self, rt, s) -> float:
        return self.score(rt, s)


class HAblation(Heuristic):
    """Parameterized h'(s, m, c) of Appendix D.1.

    stale  in {True, False}
    mem    in {True, False}
    cost   in {"estar", "eq", "local", "no"}
    """

    separable = True

    def __init__(self, stale: bool, mem: bool, cost: str) -> None:
        assert cost in ("estar", "eq", "local", "no")
        self.stale, self.mem, self.cost = stale, mem, cost
        self.needs_uf = cost == "eq"
        self.uses_staleness = stale
        self.cost_aware = cost != "no"
        self.name = (f"h_s{'1' if stale else '0'}"
                     f"m{'1' if mem else '0'}c_{cost}")

    def _numer(self, rt, s) -> float:
        if self.cost == "estar":
            return (s.local_cost + s.dead_cost
                    + rt.evicted_neighborhood_cost(s))
        if self.cost == "eq":
            return s.local_cost + rt.eq_neighborhood_cost(s)
        if self.cost == "local":
            return s.local_cost
        return 1.0

    def score(self, rt, s) -> float:
        c = self._numer(rt, s)
        denom = 1.0
        if self.mem:
            denom *= max(s.size, 1)
        if self.stale:
            denom *= rt.staleness(s)
        return c / denom

    def key(self, rt, s) -> float:
        c = self._numer(rt, s)
        denom = 1.0
        if self.mem:
            denom *= max(s.size, 1)
        return c / denom


def make_ablation(stale: bool, mem: bool, cost: str) -> Heuristic:
    return HAblation(stale, mem, cost)


def window_cost(rt, heuristic: Heuristic, storages, cache=None) -> float:
    """Summed heuristic score of a candidate eviction window.

    Contiguity-aware eviction (``repro.alloc``) ranks contiguous windows of
    storages by this aggregate instead of scoring storages one at a time.

    When the runtime carries an eviction index, scores come from the
    index's shared per-storage memo (``EvictIndex.cached_score``) — the
    same memo victim-selection verification reads — so the window planner
    and ``_pick_victim`` score each storage once per instant and count
    metadata accesses identically (one per fresh evaluation, zero per
    hit).  Without an index, ``cache`` (sid -> score) amortizes repeated
    scoring within one planning pass, each fresh evaluation counting one
    metadata access as in the linear-scan ``_pick_victim``.  An explicit
    ``cache`` dict is honored (and populated) in both modes.
    """
    idx = getattr(rt, "index", None)
    use_idx = idx is not None and heuristic is rt.heuristic
    total = 0.0
    for s in storages:
        if cache is not None and s.sid in cache:
            total += cache[s.sid]
            continue
        if use_idx:
            sc = idx.cached_score(s)
        else:
            rt.meta_accesses += 1
            sc = heuristic.score(rt, s)
        if cache is not None:
            cache[s.sid] = sc
        total += sc
    return total


def by_name(name: str, seed: int = 0) -> Heuristic:
    table = {
        "h_dtr": HDTR,
        "h_dtr_eq": HDTREq,
        "h_dtr_local": HDTRLocal,
        "h_lru": HLRU,
        "h_size": HSize,
        "h_msps": HMSPS,
        "h_estar": HEStar,
    }
    if name == "h_rand":
        return HRandom(seed)
    return table[name]()


ALL_NAMES = ["h_dtr", "h_dtr_eq", "h_dtr_local", "h_lru", "h_size",
             "h_msps", "h_rand"]
