"""DTR as a trace-time rematerialization planner (the TPU-native form).

JAX retraces per input shape, so the paper's *online* algorithm can run at
trace time — the "just in time" static planning the paper describes in Sec. 6
(possible exactly because DTR's greedy heuristic costs milliseconds, unlike
Checkmate's ILP).  Pipeline:

  1. ``trace_to_log``: jaxpr of (usually) a value_and_grad step → DTR op log,
     with tensor sizes from avals and an analytic FLOPs cost model (the
     deterministic cost model Appendix E.3 recommends).
  2. ``plan``: replay the log through the DTR engine under a per-device
     activation-byte budget; tensors tagged via
     ``jax.ad_checkpoint.checkpoint_name`` that were *never evicted* form the
     save-set.
  3. ``policy_from_plan``: the save-set becomes
     ``jax.checkpoint_policies.save_only_these_names(...)``, enforced by XLA
     remat — the runtime never sees the evicted activations at all.

Also provides ``block_remat``: DTR-planned segment checkpointing over scanned
layer stacks (the √N pattern of Thm 3.1 emerges as the planned block size).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Log, LogBuilder, replay
from .heuristics import by_name
from .runtime import DTRRuntime, OOMError


# ---------------------------------------------------------------------------
# Cost model over jaxpr equations
# ---------------------------------------------------------------------------

def _aval_bytes(aval) -> int:
    # Abstract tokens / effect avals lack shape/dtype (AttributeError);
    # extended dtypes without an itemsize raise TypeError.  Anything else
    # (a malformed shape, a numpy overflow) is a real bug and propagates.
    try:
        return int(np.prod(aval.shape, dtype=np.int64)
                   * jnp.dtype(aval.dtype).itemsize)
    except (AttributeError, TypeError):
        return 0


def _aval_elems(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64))
    except (AttributeError, TypeError):
        return 0


def eqn_flops(eqn) -> float:
    """Analytic FLOPs estimate for one jaxpr equation."""
    prim = eqn.primitive.name
    if prim == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dims
        lhs = eqn.invars[0].aval
        batch = 1
        for d in lb:
            batch *= lhs.shape[d]
        k = 1
        for d in lc:
            k *= lhs.shape[d]
        m = _aval_elems(lhs) // max(batch * k, 1)
        rhs = eqn.invars[1].aval
        rk = 1
        for d in rc:
            rk *= rhs.shape[d]
        n = _aval_elems(rhs) // max(batch * rk, 1)
        return 2.0 * batch * m * n * k
    if prim in ("conv_general_dilated",):
        out = eqn.outvars[0].aval
        lhs = eqn.invars[1].aval  # kernel
        return 2.0 * _aval_elems(out) * _aval_elems(lhs) / max(
            out.shape[-1] if out.shape else 1, 1)
    if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax"):
        return float(_aval_elems(eqn.invars[0].aval))
    if prim in ("exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                "sin", "cos", "pow", "integer_pow"):
        return 4.0 * _aval_elems(eqn.outvars[0].aval)
    # Metadata-only ops.
    if prim in ("reshape", "transpose", "broadcast_in_dim", "squeeze",
                "convert_element_type", "slice", "dynamic_slice",
                "dynamic_update_slice", "concatenate", "gather", "name",
                "stop_gradient", "copy", "rev", "iota", "pad",
                "scatter", "scatter-add", "select_n", "split"):
        return float(_aval_elems(eqn.outvars[0].aval)) * 0.1
    # Default: one flop per output element.
    return float(sum(_aval_elems(o.aval) for o in eqn.outvars))


# ---------------------------------------------------------------------------
# jaxpr -> DTR log
# ---------------------------------------------------------------------------

@dataclass
class TracedGraph:
    log: Log
    named: dict[str, str]            # checkpoint_name -> log tensor name
    outputs: list[str]               # log tensor names of jaxpr outputs
    total_bytes: int = 0
    total_flops: float = 0.0


def _eqn_cost(eqn, scale) -> float:
    """Cost contribution of one flattened (eqn, scale) pair.

    Opaque sub-jaxprs (nested pjit / scan inside a scanned body) carry their
    pre-summed total in the scale tuple; multiplying eqn_flops by it would be
    meaningless (and breaks on the tuple).
    """
    if isinstance(scale, tuple):
        return float(scale[1])
    return eqn_flops(eqn) * scale


def _flatten_eqns(jaxpr, depth: int = 0):
    """Yield (eqn, scale) with nested jaxprs inlined; scan bodies scaled."""
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
                    "checkpoint", "custom_lin"):
            inner = None
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if key in eqn.params:
                    inner = eqn.params[key]
                    break
            if inner is not None:
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                # Treat as opaque op (cost summed) to keep the DAG aligned
                # with data deps at this level.
                total = sum(_eqn_cost(e, s)
                            for e, s in _flatten_eqns(ij, depth + 1))
                yield eqn, ("opaque", total)
                continue
        if prim == "scan":
            length = eqn.params.get("length", 1)
            inner = eqn.params["jaxpr"]
            ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            total = sum(_eqn_cost(e, s)
                        for e, s in _flatten_eqns(ij, depth + 1)) * length
            yield eqn, ("opaque", total)
            continue
        if prim in ("while", "cond"):
            yield eqn, ("opaque", float(
                sum(_aval_elems(o.aval) for o in eqn.outvars)))
            continue
        yield eqn, 1.0


def trace_to_log(fn: Callable, *example_args, name: str = "traced",
                 unroll_scans: bool = False, unroll_limit: int = 256,
                 **example_kwargs) -> TracedGraph:
    """Trace ``fn`` and convert its jaxpr into a DTR operator log.

    ``unroll_scans=True`` inlines ``lax.scan`` bodies per iteration (up to
    ``unroll_limit`` trips) instead of treating the scan as one opaque op.
    Scanned layer stacks then appear as per-layer operator chains — without
    this, the whole stack is a single op whose inputs/outputs lock nearly the
    entire peak and DTR has nothing to evict (the ``repro.trace`` captures of
    real train steps need the unrolled form).
    """
    closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    jaxpr = closed.jaxpr
    b = LogBuilder(name=name)
    env: dict[Any, str] = {}
    named: dict[str, str] = {}
    totals = {"bytes": 0, "flops": 0.0}

    def lookup(v, env) -> str:
        # Literals become fresh constants (builder-unique names: per-scope
        # env sizes repeat across unrolled scan iterations).
        if not hasattr(v, "count") and hasattr(v, "val"):
            return b.constant(_aval_bytes(v.aval), name=b.fresh("lit"))
        return env[v]

    def emit_call(eqn, cost: float, env, op: str | None = None) -> None:
        cost = max(cost, 1.0)
        ins = [lookup(v, env) for v in eqn.invars]
        sizes = [_aval_bytes(o.aval) for o in eqn.outvars]
        prim = eqn.primitive.name
        # View-like ops share their input's storage (paper alias semantics);
        # `name` in particular must alias so that evicting the producer
        # registers against the checkpoint_name tag.  optimization_barrier
        # is identity on every operand — without the alias each scanned
        # layer's parameters would count as a fresh activation-sized copy.
        aliases = None
        if prim == "optimization_barrier" and len(ins) == len(sizes):
            aliases = list(ins)
        elif prim in ("name", "reshape", "transpose", "squeeze") and ins:
            aliases = [ins[0]] * len(sizes)
        outs = b.call(ins, sizes, cost, op or prim, aliases=aliases)
        for o, t in zip(eqn.outvars, outs):
            env[o] = t
            totals["bytes"] += _aval_bytes(o.aval)
        totals["flops"] += cost
        if prim == "name":
            named[eqn.params["name"]] = outs[0]

    # stack-output log tensor -> its per-iteration parts.  A later unrolled
    # scan consuming a stacked output as xs reads the parts directly instead
    # of slicing the monolithic storage — the fwd-residuals -> bwd-scan path
    # would otherwise make every backward step depend on the whole stacked
    # array, which locks ~the entire activation peak during remat.
    stacked: dict[str, list[str]] = {}

    def unroll_scan(eqn, env, depth: int) -> None:
        length = max(int(eqn.params.get("length", 1)), 1)
        reverse = bool(eqn.params.get("reverse", False))
        inner = eqn.params["jaxpr"]
        ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
        nc = int(eqn.params.get("num_consts", 0))
        nk = int(eqn.params.get("num_carry", 0))
        invals = [lookup(v, env) for v in eqn.invars]
        cvals, carry, xs = invals[:nc], invals[nc:nc + nk], invals[nc + nk:]
        const_env: dict[Any, str] = {}
        for v, cv in zip(ij.constvars, getattr(inner, "consts", ())):
            const_env[v] = b.constant(
                int(getattr(cv, "nbytes", _aval_bytes(v.aval))),
                name=f"scanconst{depth}_{len(const_env)}")
        n_ys = len(eqn.outvars) - nk
        ys_parts: list[list[str]] = [[] for _ in range(n_ys)]
        for it in range(length):
            benv: dict[Any, str] = dict(const_env)
            xe: list[str] = []
            for xi, xv in enumerate(xs):
                var = ij.invars[nc + nk + xi]
                parts = stacked.get(xv)
                if parts is not None and len(parts) == length:
                    xe.append(parts[length - 1 - it if reverse else it])
                    continue
                sz = _aval_bytes(var.aval)
                # A per-iteration slice is a view of the stacked operand
                # (XLA reads it in place); a fresh storage per layer would
                # double-count every scanned parameter stack as activation
                # memory.
                (t,) = b.call([xv], [sz],
                              max(0.1 * _aval_elems(var.aval), 1.0),
                              "scan_slice", aliases=[xv])
                xe.append(t)
            for var, val in zip(ij.invars, cvals + carry + xe):
                benv[var] = val
            emit(ij, benv, depth + 1)
            outs = [lookup(v, benv) for v in ij.outvars]
            carry = outs[:nk]
            for yi in range(n_ys):
                ys_parts[yi].append(outs[nk + yi])
        if reverse:
            ys_parts = [list(reversed(p)) for p in ys_parts]
        for var, val in zip(eqn.outvars[:nk], carry):
            env[var] = val
        for yi, var in enumerate(eqn.outvars[nk:]):
            sz = _aval_bytes(var.aval)
            (t,) = b.call(ys_parts[yi], [sz],
                          max(0.1 * _aval_elems(var.aval), 1.0),
                          "scan_stack")
            env[var] = t
            stacked[t] = ys_parts[yi]
            totals["bytes"] += sz

    def emit(jx, env, depth: int = 0) -> None:
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            if prim in ("pjit", "closed_call", "core_call",
                        "custom_jvp_call", "custom_vjp_call",
                        "custom_vjp_call_jaxpr", "remat", "checkpoint",
                        "custom_lin"):
                inner = None
                for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                    if key in eqn.params:
                        inner = eqn.params[key]
                        break
                if inner is not None:
                    ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                    if unroll_scans and not getattr(inner, "consts", ()):
                        # Inline the call body: sub-eqns bind directly.
                        benv: dict[Any, str] = {}
                        for var, v in zip(ij.invars, eqn.invars):
                            benv[var] = lookup(v, env)
                        emit(ij, benv, depth + 1)
                        for var, v in zip(eqn.outvars, ij.outvars):
                            env[var] = lookup(v, benv)
                        continue
                    total = sum(_eqn_cost(e, s)
                                for e, s in _flatten_eqns(ij, depth + 1))
                    emit_call(eqn, total, env)
                    continue
            if prim == "scan":
                length = eqn.params.get("length", 1)
                if unroll_scans and length <= unroll_limit:
                    unroll_scan(eqn, env, depth)
                    continue
                inner = eqn.params["jaxpr"]
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                total = sum(_eqn_cost(e, s)
                            for e, s in _flatten_eqns(ij, depth + 1)
                            ) * length
                emit_call(eqn, total, env)
                continue
            if prim in ("while", "cond"):
                emit_call(eqn, float(sum(_aval_elems(o.aval)
                                         for o in eqn.outvars)), env)
                continue
            emit_call(eqn, eqn_flops(eqn), env)

    for v, cv in zip(jaxpr.constvars, closed.consts):
        env[v] = b.constant(
            int(getattr(cv, "nbytes", _aval_bytes(v.aval))), name=str(v))
    for v in jaxpr.invars:
        env[v] = b.constant(_aval_bytes(v.aval), name=f"in_{v}")

    emit(jaxpr, env)

    outputs = [env[v] if hasattr(v, "count") or v in env else lookup(v, env)
               for v in jaxpr.outvars]
    log = b.auto_release(keep=outputs)
    return TracedGraph(log=log, named=named, outputs=outputs,
                       total_bytes=totals["bytes"],
                       total_flops=totals["flops"])


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------

@dataclass
class Plan:
    budget_bytes: float
    feasible: bool
    save_names: list[str] = field(default_factory=list)
    remat_names: list[str] = field(default_factory=list)
    est_slowdown: float = 1.0
    est_peak_bytes: float = 0.0
    evictions: int = 0

    def policy(self):
        """A jax.checkpoint policy saving exactly the planned names."""
        if not self.remat_names:
            return jax.checkpoint_policies.everything_saveable
        if not self.save_names:
            return jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint_policies.save_only_these_names(
            *self.save_names)


def plan(fn: Callable, *example_args, budget_bytes: float,
         heuristic: str = "h_dtr_eq", **example_kwargs) -> Plan:
    """Run the DTR greedy simulation over ``fn``'s graph under a budget.

    Returns the save/remat split over ``checkpoint_name``-tagged tensors.
    ``fn`` should be the *differentiated* step (e.g. value_and_grad) so the
    simulation sees the true fwd+bwd tensor lifetime structure.
    """
    tg = trace_to_log(fn, *example_args, name="plan", **example_kwargs)
    rt = DTRRuntime(budget=float(budget_bytes),
                    heuristic=by_name(heuristic), dealloc="eager")
    evicted_names: set[str] = set()

    orig_evict = rt._evict

    def traced_evict(s):
        # Only *pressure* evictions of still-live tensors are remat
        # decisions; eager evictions at refcount zero are ordinary frees.
        if s.refs > 0:
            for tid in s.tensor_tids:
                evicted_names.add(rt.tensors[tid].name)
        orig_evict(s)

    rt._evict = traced_evict
    try:
        env = replay(tg.log, rt)
    except OOMError:
        return Plan(budget_bytes=budget_bytes, feasible=False,
                    remat_names=sorted(tg.named))
    # env maps log tensor names -> tids; evicted_names recorded runtime names
    # — map through: runtime tensors were created with out_names = log names.
    save, remat = [], []
    for cname, log_t in tg.named.items():
        if log_t in evicted_names:
            remat.append(cname)
        else:
            save.append(cname)
    return Plan(budget_bytes=budget_bytes, feasible=True,
                save_names=sorted(save), remat_names=sorted(remat),
                est_slowdown=rt.slowdown(), est_peak_bytes=rt.peak_memory,
                evictions=rt.evictions)


def dtr_checkpoint(fn: Callable, *example_args, budget_bytes: float,
                   grad_fn: Callable | None = None,
                   heuristic: str = "h_dtr_eq", **example_kwargs):
    """Wrap ``fn`` in jax.checkpoint with a DTR-planned policy.

    ``grad_fn`` (default: grad of sum(fn)) is traced for planning so the
    simulation sees backward lifetimes; the returned callable is
    ``jax.checkpoint(fn, policy=planned)``.
    """
    if grad_fn is None:
        def grad_fn(*a, **k):
            return jax.grad(
                lambda *aa: jnp.sum(fn(*aa, **k)).astype(jnp.float32)
            )(*a)
    p = plan(grad_fn, *example_args, budget_bytes=budget_bytes,
             heuristic=heuristic, **example_kwargs)
    return jax.checkpoint(fn, policy=p.policy()), p


# ---------------------------------------------------------------------------
# Segment-level planning for scanned layer stacks
# ---------------------------------------------------------------------------

def plan_layer_blocks(n_layers: int, layer_act_bytes: float,
                      budget_bytes: float) -> int:
    """Pick the remat block size for a scanned stack of ``n_layers``.

    DTR's even-spacing behaviour (Lemma A.1) on a homogeneous chain puts
    checkpoints every L/B layers; with a byte budget this is
    ceil(n_layers * layer_act_bytes / budget) layers per block, clamped to
    [1, n_layers].  Block size √L falls out when the budget equals
    √L·layer_act_bytes — the Thm 3.1 regime.
    """
    if budget_bytes <= 0 or n_layers <= 1:
        return 1
    blocks = max(int(budget_bytes // max(layer_act_bytes, 1)), 1)
    size = math.ceil(n_layers / blocks)
    return max(1, min(size, n_layers))


def sqrt_block_size(n_layers: int) -> int:
    return max(1, int(round(math.sqrt(n_layers))))
