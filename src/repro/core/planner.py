"""DTR as a trace-time rematerialization planner (the TPU-native form).

JAX retraces per input shape, so the paper's *online* algorithm can run at
trace time — the "just in time" static planning the paper describes in Sec. 6
(possible exactly because DTR's greedy heuristic costs milliseconds, unlike
Checkmate's ILP).  Pipeline:

  1. ``trace_to_log``: jaxpr of (usually) a value_and_grad step → DTR op log,
     with tensor sizes from avals and an analytic FLOPs cost model (the
     deterministic cost model Appendix E.3 recommends).
  2. ``plan``: replay the log through the DTR engine under a per-device
     activation-byte budget; tensors tagged via
     ``jax.ad_checkpoint.checkpoint_name`` that were *never evicted* form the
     save-set.
  3. ``policy_from_plan``: the save-set becomes
     ``jax.checkpoint_policies.save_only_these_names(...)``, enforced by XLA
     remat — the runtime never sees the evicted activations at all.

Also provides ``block_remat``: DTR-planned segment checkpointing over scanned
layer stacks (the √N pattern of Thm 3.1 emerges as the planned block size).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Log, LogBuilder, replay
from .heuristics import by_name
from .runtime import DTRRuntime, OOMError


# ---------------------------------------------------------------------------
# Cost model over jaxpr equations
# ---------------------------------------------------------------------------

def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)
                   * jnp.dtype(aval.dtype).itemsize)
    except Exception:
        return 0


def _aval_elems(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64))
    except Exception:
        return 0


def eqn_flops(eqn) -> float:
    """Analytic FLOPs estimate for one jaxpr equation."""
    prim = eqn.primitive.name
    if prim == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dims
        lhs = eqn.invars[0].aval
        batch = 1
        for d in lb:
            batch *= lhs.shape[d]
        k = 1
        for d in lc:
            k *= lhs.shape[d]
        m = _aval_elems(lhs) // max(batch * k, 1)
        rhs = eqn.invars[1].aval
        rk = 1
        for d in rc:
            rk *= rhs.shape[d]
        n = _aval_elems(rhs) // max(batch * rk, 1)
        return 2.0 * batch * m * n * k
    if prim in ("conv_general_dilated",):
        out = eqn.outvars[0].aval
        lhs = eqn.invars[1].aval  # kernel
        return 2.0 * _aval_elems(out) * _aval_elems(lhs) / max(
            out.shape[-1] if out.shape else 1, 1)
    if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax"):
        return float(_aval_elems(eqn.invars[0].aval))
    if prim in ("exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                "sin", "cos", "pow", "integer_pow"):
        return 4.0 * _aval_elems(eqn.outvars[0].aval)
    # Metadata-only ops.
    if prim in ("reshape", "transpose", "broadcast_in_dim", "squeeze",
                "convert_element_type", "slice", "dynamic_slice",
                "dynamic_update_slice", "concatenate", "gather", "name",
                "stop_gradient", "copy", "rev", "iota", "pad",
                "scatter", "scatter-add", "select_n", "split"):
        return float(_aval_elems(eqn.outvars[0].aval)) * 0.1
    # Default: one flop per output element.
    return float(sum(_aval_elems(o.aval) for o in eqn.outvars))


# ---------------------------------------------------------------------------
# jaxpr -> DTR log
# ---------------------------------------------------------------------------

@dataclass
class TracedGraph:
    log: Log
    named: dict[str, str]            # checkpoint_name -> log tensor name
    outputs: list[str]               # log tensor names of jaxpr outputs
    total_bytes: int = 0
    total_flops: float = 0.0


def _flatten_eqns(jaxpr, depth: int = 0):
    """Yield (eqn, scale) with nested jaxprs inlined; scan bodies scaled."""
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
                    "checkpoint", "custom_lin"):
            inner = None
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if key in eqn.params:
                    inner = eqn.params[key]
                    break
            if inner is not None:
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                # Treat as opaque op (cost summed) to keep the DAG aligned
                # with data deps at this level.
                total = sum(eqn_flops(e) * s
                            for e, s in _flatten_eqns(ij, depth + 1))
                yield eqn, ("opaque", total)
                continue
        if prim == "scan":
            length = eqn.params.get("length", 1)
            inner = eqn.params["jaxpr"]
            ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            total = sum(eqn_flops(e) * s
                        for e, s in _flatten_eqns(ij, depth + 1)) * length
            yield eqn, ("opaque", total)
            continue
        if prim in ("while", "cond"):
            yield eqn, ("opaque", float(
                sum(_aval_elems(o.aval) for o in eqn.outvars)))
            continue
        yield eqn, 1.0


def trace_to_log(fn: Callable, *example_args, name: str = "traced",
                 **example_kwargs) -> TracedGraph:
    """Trace ``fn`` and convert its jaxpr into a DTR operator log."""
    closed = jax.make_jaxpr(fn)(*example_args, **example_kwargs)
    jaxpr = closed.jaxpr
    b = LogBuilder(name=name)
    env: dict[Any, str] = {}
    named: dict[str, str] = {}
    total_bytes = 0
    total_flops = 0.0

    def lookup(v) -> str:
        # Literals become fresh constants.
        if not hasattr(v, "count") and hasattr(v, "val"):
            t = b.constant(_aval_bytes(v.aval), name=f"lit{len(env)}")
            return t
        return env[v]

    for v, cv in zip(jaxpr.constvars, closed.consts):
        env[v] = b.constant(
            int(getattr(cv, "nbytes", _aval_bytes(v.aval))), name=str(v))
    for v in jaxpr.invars:
        env[v] = b.constant(_aval_bytes(v.aval), name=f"in_{v}")

    for eqn, scale in _flatten_eqns(jaxpr):
        if isinstance(scale, tuple):
            cost = max(scale[1], 1.0)
        else:
            cost = max(eqn_flops(eqn) * scale, 1.0)
        ins = [lookup(v) for v in eqn.invars]
        sizes = [_aval_bytes(o.aval) for o in eqn.outvars]
        prim = eqn.primitive.name
        # View-like ops share their input's storage (paper alias semantics);
        # `name` in particular must alias so that evicting the producer
        # registers against the checkpoint_name tag.
        aliases = None
        if prim in ("name", "reshape", "transpose", "squeeze") and ins:
            aliases = [ins[0]] * len(sizes)
        outs = b.call(ins, sizes, cost, prim, aliases=aliases)
        for o, t in zip(eqn.outvars, outs):
            env[o] = t
            total_bytes += _aval_bytes(o.aval)
        total_flops += cost
        if prim == "name":
            named[eqn.params["name"]] = outs[0]

    outputs = [env[v] if hasattr(v, "count") or v in env else lookup(v)
               for v in jaxpr.outvars]
    log = b.auto_release(keep=outputs)
    return TracedGraph(log=log, named=named, outputs=outputs,
                       total_bytes=total_bytes, total_flops=total_flops)


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------

@dataclass
class Plan:
    budget_bytes: float
    feasible: bool
    save_names: list[str] = field(default_factory=list)
    remat_names: list[str] = field(default_factory=list)
    est_slowdown: float = 1.0
    est_peak_bytes: float = 0.0
    evictions: int = 0

    def policy(self):
        """A jax.checkpoint policy saving exactly the planned names."""
        if not self.remat_names:
            return jax.checkpoint_policies.everything_saveable
        if not self.save_names:
            return jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint_policies.save_only_these_names(
            *self.save_names)


def plan(fn: Callable, *example_args, budget_bytes: float,
         heuristic: str = "h_dtr_eq", **example_kwargs) -> Plan:
    """Run the DTR greedy simulation over ``fn``'s graph under a budget.

    Returns the save/remat split over ``checkpoint_name``-tagged tensors.
    ``fn`` should be the *differentiated* step (e.g. value_and_grad) so the
    simulation sees the true fwd+bwd tensor lifetime structure.
    """
    tg = trace_to_log(fn, *example_args, name="plan", **example_kwargs)
    rt = DTRRuntime(budget=float(budget_bytes),
                    heuristic=by_name(heuristic), dealloc="eager")
    evicted_names: set[str] = set()

    orig_evict = rt._evict

    def traced_evict(s):
        # Only *pressure* evictions of still-live tensors are remat
        # decisions; eager evictions at refcount zero are ordinary frees.
        if s.refs > 0:
            for tid in s.tensor_tids:
                evicted_names.add(rt.tensors[tid].name)
        orig_evict(s)

    rt._evict = traced_evict
    try:
        env = replay(tg.log, rt)
    except OOMError:
        return Plan(budget_bytes=budget_bytes, feasible=False,
                    remat_names=sorted(tg.named))
    # env maps log tensor names -> tids; evicted_names recorded runtime names
    # — map through: runtime tensors were created with out_names = log names.
    save, remat = [], []
    for cname, log_t in tg.named.items():
        if log_t in evicted_names:
            remat.append(cname)
        else:
            save.append(cname)
    return Plan(budget_bytes=budget_bytes, feasible=True,
                save_names=sorted(save), remat_names=sorted(remat),
                est_slowdown=rt.slowdown(), est_peak_bytes=rt.peak_memory,
                evictions=rt.evictions)


def dtr_checkpoint(fn: Callable, *example_args, budget_bytes: float,
                   grad_fn: Callable | None = None,
                   heuristic: str = "h_dtr_eq", **example_kwargs):
    """Wrap ``fn`` in jax.checkpoint with a DTR-planned policy.

    ``grad_fn`` (default: grad of sum(fn)) is traced for planning so the
    simulation sees backward lifetimes; the returned callable is
    ``jax.checkpoint(fn, policy=planned)``.
    """
    if grad_fn is None:
        def grad_fn(*a, **k):
            return jax.grad(
                lambda *aa: jnp.sum(fn(*aa, **k)).astype(jnp.float32)
            )(*a)
    p = plan(grad_fn, *example_args, budget_bytes=budget_bytes,
             heuristic=heuristic, **example_kwargs)
    return jax.checkpoint(fn, policy=p.policy()), p


# ---------------------------------------------------------------------------
# Segment-level planning for scanned layer stacks
# ---------------------------------------------------------------------------

def plan_layer_blocks(n_layers: int, layer_act_bytes: float,
                      budget_bytes: float) -> int:
    """Pick the remat block size for a scanned stack of ``n_layers``.

    DTR's even-spacing behaviour (Lemma A.1) on a homogeneous chain puts
    checkpoints every L/B layers; with a byte budget this is
    ceil(n_layers * layer_act_bytes / budget) layers per block, clamped to
    [1, n_layers].  Block size √L falls out when the budget equals
    √L·layer_act_bytes — the Thm 3.1 regime.
    """
    if budget_bytes <= 0 or n_layers <= 1:
        return 1
    blocks = max(int(budget_bytes // max(layer_act_bytes, 1)), 1)
    size = math.ceil(n_layers / blocks)
    return max(1, min(size, n_layers))


def sqrt_block_size(n_layers: int) -> int:
    return max(1, int(round(math.sqrt(n_layers))))
