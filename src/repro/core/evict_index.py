"""Incremental eviction index: sublinear victim selection for the DTR runtime.

The paper's own overhead analysis (App. C.5/D.3) concedes that victim
selection dominates runtime cost: the naive engine rebuilds the candidate
list from *all* storages and re-scores every one on *every* eviction, and a
global version counter discards every cached ``e*`` neighborhood on every
evict/remat.  This module replaces both with incremental structures:

``ScopedInvalidator``
    Tracks *evicted connected components* (the same merge-on-evict /
    phantom-on-remat approximation the paper uses for ``h_DTR^eq``) in a
    lightweight epoch-based union-find, plus a per-component **subscriber
    set**: the resident storages whose cached neighborhood costs were
    computed through that component.  An evict/remat then invalidates only
    the caches in the affected component — not the whole table.  The scope
    is a sound over-approximation: phantom connections left by remats can
    widen a component (extra invalidations), never narrow it (a cached
    value is dropped whenever any storage it summed over changes state).

``EvictIndex``
    A live evictable-storage set maintained on state transitions (storage
    field writes notify the index; no per-eviction rebuild), with
    **verified lazy heaps** over the staleness-free part of the heuristic
    score.  Separable heuristics declare ``score = key(S) / staleness(S)``
    (or ``score = key(S)`` for staleness-free heuristics); ``key`` only
    changes on discrete events (evict / remat / banish / alias
    registration), so heap entries stay valid as the clock advances.
    Staleness-free heuristics use a single min-heap popped in key order.
    Staleness-aware heuristics bucket candidates into geometric key bands
    (quarter-octave: keys within a band differ by less than 2^(1/4)),
    each band a lazy min-heap over last-access times: a band whose floor
    key over its oldest member's staleness exceeds the best score so far
    is skipped whole in O(1), and inside a band the oldest-first walk
    stops as soon as the floor-key bound passes the best.  Every candidate that survives
    its bounds is *verified* — its exact score recomputed with the
    heuristic's own formula — so the selected victim is *bit-exact* with
    the linear scan's argmin, tie-breaking (lowest sid among equal scores,
    i.e. first in ``storages`` iteration order) included.

The linear scan remains in ``DTRRuntime._pick_victim`` as the reference
oracle (``index=False``), and is also the automatic fallback for
non-separable heuristics (``h_rand`` advances an RNG per evaluation) and
for the ``sample_sqrt`` / ``ignore_small_frac`` approximations, whose
sampling sequences the heap cannot reproduce.
"""
from __future__ import annotations

import bisect
import heapq
from math import frexp as _frexp, ldexp as _ldexp
from typing import Optional

# Relative slack on the early-stop bound.  ``key`` is computed with a
# different association of the same factors as ``score`` (e.g. ``(c/m)/t``
# vs ``c/(m*t)``), so the two can differ by a few ulps; the bound must not
# prune a storage whose exact score ties the current best within rounding.
_BOUND_EPS = 1e-9
_MIN_STALENESS = 1e-9  # mirrors DTRRuntime.staleness


class _EpochUF:
    """Identity-only union-find with epoch nodes (no splitting needed).

    A storage gets a *fresh* node each time it is evicted, so a
    rematerialized-then-re-evicted storage rejoins as a singleton and
    merges with the *current* components of its neighbors; its old node
    lingers as a phantom inside whatever component absorbed it, which only
    widens invalidation scopes (sound).  Bookkeeping hops are not counted
    as heuristic metadata accesses.
    """

    __slots__ = ("_parent",)

    def __init__(self) -> None:
        self._parent: list[int] = []

    def make(self) -> int:
        h = len(self._parent)
        self._parent.append(h)
        return h

    def find(self, x: int) -> int:
        p = self._parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    def union(self, a: int, b: int) -> int:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return ra
        # Union by index: keep the smaller root (deterministic, no rank).
        if ra > rb:
            ra, rb = rb, ra
        self._parent[rb] = ra
        return ra


class ScopedInvalidator:
    """Per-component dirty-sets for cached neighborhood costs.

    The runtime calls :meth:`subscribe` while walking a closure ("the value
    cached for ``consumer`` summed over evicted storage ``dep``") and the
    event hooks on state transitions.  Invalidation distinguishes two
    classes per affected consumer:

    * **full** — the consumer's *adjacency* changed (a neighbor entered or
      left the evicted set): both the cached value and the ẽ* adjacency
      snapshot (``rt._eq_adj``) are dropped, forcing a neighborhood
      re-walk (which re-subscribes);
    * **sum-only** — only an adjacent component's *sum* changed (a merge,
      split, or member cost growth elsewhere in the component): the value
      is dropped but the snapshot survives, so the eq key rebuilds from
      the union-find's incrementally-maintained per-root sums in O(roots)
      — and the consumer stays subscribed to the (possibly merged)
      component.

    Exact e* closures (``_estar_cache``) cannot be rebuilt from component
    sums, so both classes drop them; their consumers re-subscribe on the
    next walk.  Dead storages (``StorageRec.dead``) are pruned: they never
    receive epoch nodes, never merge, and their eviction fires no
    neighborhood invalidation at all (:meth:`on_dead_evict`).
    """

    def __init__(self, rt) -> None:
        self.rt = rt
        self._uf = _EpochUF()
        self._node: dict[int, int] = {}       # sid -> current epoch node
        self._subs: dict[int, set[int]] = {}  # root -> subscriber sids
        self.invalidations = 0                # telemetry: entries dropped
        self.subscribes = 0                   # telemetry: registrations

    # -- closure bookkeeping -------------------------------------------
    def _node_of(self, sid: int) -> int:
        n = self._node.get(sid)
        if n is None:
            n = self._uf.make()
            self._node[sid] = n
        return n

    def subscribe(self, dep_sid: int, consumer_sid: int) -> None:
        self.subscribes += 1
        root = self._uf.find(self._node_of(dep_sid))
        subs = self._subs.get(root)
        if subs is None:
            self._subs[root] = {consumer_sid}
        else:
            subs.add(consumer_sid)

    # -- state-transition hooks ----------------------------------------
    def on_evict(self, s) -> None:
        """``s`` left residency (evicted, or created not-yet-materialized).

        Gives ``s`` a fresh epoch node, merges it with the components of
        its evicted neighbors, and invalidates (a) the subscribers of every
        merged component — their closures can now extend through ``s``
        (sum-only for eq consumers: their adjacency is unchanged) — and
        (b) the resident neighbors of ``s``, whose closures gain ``s``
        itself (full: their adjacency grew).
        """
        rt = self.rt
        node = self._uf.make()
        self._node[s.sid] = node
        full: set[int] = {s.sid}
        moved: set[int] = set()
        for nsid in s.deps | s.children:
            ns = rt.storages.get(nsid)
            if ns is None or ns.banished or (ns.dead and rt.uf is None):
                # Without a cost union-find, dead storages are fully
                # pruned; with one they are ẽ* component members whose
                # epoch components must keep mirroring the cost ones.
                continue
            if ns.resident or ns.offloaded:
                # Offloaded neighbors behave like resident ones here: they
                # are outside the evicted components (no remat needed) but
                # their own keys can sum over ``s`` once it is evicted.
                full.add(nsid)
            else:
                r = self._uf.find(self._node_of(nsid))
                sub = self._subs.pop(r, None)
                if sub:
                    moved |= sub
                node = self._uf.union(node, r)
        self._invalidate_full(full)
        self._invalidate_sum(moved - full)
        # Consumers whose adjacency snapshot survived stay subscribed to
        # the merged component (their remembered handles keep resolving to
        # its root); the rest re-subscribe on their next walk.
        adj = rt._eq_adj
        keep = {c for c in moved if c in adj}
        if keep:
            root = self._uf.find(node)
            cur = self._subs.get(root)
            if cur is None:
                self._subs[root] = keep
            else:
                cur |= keep

    def on_unevict(self, s) -> None:
        """``s`` left the evicted set (rematerialized or banished).

        Every cached value that summed over ``s``'s component is stale.
        Subscribers *adjacent* to ``s`` lose it from their neighborhood —
        adjacency changed, so their ẽ* snapshots are dropped too (the
        component-split case the snapshot cannot express).  The remaining
        subscribers see only the component sum shrink (split_approx):
        sum-only, snapshots and subscriptions intact.
        """
        rt = self.rt
        node = self._node.get(s.sid)
        subs = self._subs.get(self._uf.find(node)) if node is not None \
            else None
        full: set[int] = {s.sid}
        if subs:
            for nsid in s.deps | s.children:
                if nsid in subs:
                    full.add(nsid)
            self._invalidate_sum(subs - full)
            subs -= full
            # Estar-only consumers re-subscribe on their next walk; keep
            # only live snapshot holders subscribed.
            adj = rt._eq_adj
            stale = [c for c in subs if c not in adj]
            subs.difference_update(stale)
        self._invalidate_full(full)

    #: Death of an evicted storage splits it out of its component exactly
    #: like a rematerialization (the runtime detaches its union-find handle
    #: and subtracts its cost right after this hook).
    on_death = on_unevict

    def on_dead_evict(self, s) -> None:
        """A dead storage left residency: neighbors' closures never
        included it and never will — only its own consumer entries go."""
        self._invalidate_full({s.sid})

    def on_cost_change(self, s) -> None:
        """``s.local_cost`` grew (alias registration) while ``s`` evicted:
        cached closures summing over ``s`` hold the old cost.  Adjacency is
        unchanged for every subscriber, so the drop is sum-only (the
        runtime has already added the delta to the component sum)."""
        node = self._node.get(s.sid)
        sum_only: set[int] = set()
        if node is not None:
            sum_only |= self._subs.get(self._uf.find(node), set())
        sum_only.discard(s.sid)
        self._invalidate_full({s.sid})
        self._invalidate_sum(sum_only)

    def _invalidate_full(self, sids: set[int]) -> None:
        """Adjacency changed: drop values *and* ẽ* adjacency snapshots."""
        rt = self.rt
        estar, eq, adj = rt._estar_cache, rt._eq_cache, rt._eq_adj
        idx = rt.index
        self.invalidations += len(sids)
        for sid in sids:
            estar.pop(sid, None)
            eq.pop(sid, None)
            adj.pop(sid, None)
            if idx is not None:
                idx.mark_dirty(sid)

    def _invalidate_sum(self, sids: set[int]) -> None:
        """Component sums changed, adjacency intact: drop values, keep the
        ẽ* snapshots (eq keys rebuild via the per-root-sum fast path)."""
        rt = self.rt
        estar, eq = rt._estar_cache, rt._eq_cache
        idx = rt.index
        self.invalidations += len(sids)
        for sid in sids:
            estar.pop(sid, None)
            eq.pop(sid, None)
            if idx is not None:
                idx.mark_dirty(sid)




class EvictIndex:
    """Live evictable set + verified lazy heaps over heuristic keys.

    Two organizations, chosen by the heuristic's declared decomposition:

    * staleness-free (``score == key``): one min-heap over ``(key, sid)``;
      selection pops in (key, sid) order and stops at the first key that
      can neither beat the best score nor win its sid tie-break.
    * staleness-aware (``score == key / staleness``): candidates live in
      geometric key *bands* (band ``b`` holds keys in
      ``[2^(b/GRAIN), 2^((b+1)/GRAIN))``), each band a min-heap over
      ``(last_access, sid)``.  For a band, the floor key over its oldest
      member's staleness lower-bounds every member's score, so selection
      probes each band once (O(1) skip for hopeless bands), walks
      most-promising bands first, and stops a band's oldest-first walk as
      soon as the floor bound passes the best verified score.

    All heap entries are lazy: membership changes, accesses, and key
    invalidations never search the heaps — stale entries are recognized
    and dropped at pop time (``_slot``/``_ver`` record the one canonical
    live entry per storage).
    """

    #: bucket id for exact-zero keys (sorts before every real exponent)
    _ZERO_BAND = -(1 << 30)
    #: bands per key octave: band b holds keys in [2^(b/GRAIN), 2^((b+1)/GRAIN))
    _GRAIN = 4

    def __init__(self, rt) -> None:
        self.rt = rt
        self.heuristic = rt.heuristic
        assert getattr(self.heuristic, "separable", False), (
            f"{self.heuristic!r} does not declare a separable decomposition")
        self.stale = bool(self.heuristic.uses_staleness)
        # Two-choice offload composition (repro.offload.HybridHeuristic):
        # the effective score is min(base recompute side, transfer side).
        # The base keys live in the structures below as usual; a second
        # *offload key family* (``_okeys``/``_obands``/``_okheap``) holds
        # the transfer keys — constant per storage, computed once at
        # membership, never invalidated.  Selection walks both families
        # (each side's band floors bound that side of the min), and every
        # surviving candidate is verified with the full hybrid score, so
        # bit-exactness against the linear scan is preserved.
        self.hybrid = bool(getattr(self.heuristic, "hybrid", False))
        self.members: set[int] = set()
        self._dirty: set[int] = set()
        # sid -> last computed key, present iff still valid.  Keys survive
        # membership flaps (lock/unlock cycles around every operator) — the
        # storage's heap entry simply goes dormant and revives — so only
        # genuine invalidation events trigger recomputation.
        self._keys: dict[int, float] = {}
        # Shared score memo: sid -> (clock, last_access, score).  Consulted
        # by pop-verification *and* ``heuristics.window_cost`` so the
        # allocator's window planner and victim selection score (and count
        # metadata accesses for) each storage identically.
        self._scores: dict[int, tuple[float, float, float]] = {}
        # Staleness-aware organization: key bands of (la, sid) heaps.
        self._bands: dict[int, list[tuple[float, int]]] = {}
        self._band_ids: list[int] = []    # sorted; bands are never removed
        self._floors: dict[int, float] = {}            # band -> floor key
        self._slot: dict[int, tuple[int, float]] = {}  # sid -> (band, la)
        # Staleness-free organization: one (key, sid, version) heap.
        self._kheap: list[tuple[float, int, int]] = []
        self._ver: dict[int, int] = {}
        # Offload key family (hybrid heuristics only).
        self._okeys: dict[int, float] = {}             # sid -> constant key
        self._obands: dict[int, list[tuple[float, int]]] = {}
        self._oband_ids: list[int] = []
        self._oslot: dict[int, tuple[int, float]] = {}
        self._okheap: list[tuple[float, int]] = []     # staleness-free side
        self._oin: set[int] = set()                    # sids with live entry
        # Telemetry.
        self.picks = 0
        self.pops = 0
        self.key_recomputes = 0

    # -- notifications --------------------------------------------------
    def register(self, s) -> None:
        """Attach a newly created storage to the index."""
        s._index = self
        self.on_storage_event(s, "resident")

    def on_storage_event(self, s, name: str) -> None:
        sid = s.sid
        if name == "last_access":
            if self.stale and sid in self.members:
                if sid in self._keys:
                    self._place(sid, self._keys[sid], s.last_access)
                if self.hybrid:
                    self._oplace(sid, self._okeys[sid], s.last_access)
            return
        if name == "local_cost":
            # The staleness-free key depends on local_cost for every
            # cost-aware heuristic.
            self.mark_dirty(sid)
            return
        # resident / locks / pinned / banished / constant: membership.
        now = (s.resident and not s.pinned and not s.banished
               and s.locks == 0 and not s.constant and s.size > 0)
        if now and sid not in self.members:
            self.members.add(sid)
            k = self._keys.get(sid)
            if k is None:
                self._dirty.add(sid)
            elif self.stale:
                self._place(sid, k, s.last_access)
            # staleness-free: the dormant (k, sid, ver) entry revives.
            if self.hybrid:
                ok = self._okeys.get(sid)
                if ok is None:
                    ok = self._okeys[sid] = self.heuristic.offload_key(s)
                if self.stale:
                    self._oplace(sid, ok, s.last_access)
                elif sid not in self._oin:
                    heapq.heappush(self._okheap, (ok, sid))
                    self._oin.add(sid)
        elif not now and sid in self.members:
            self.members.discard(sid)
            self._dirty.discard(sid)
            # Heap entries go dormant via the membership check on pop; the
            # key itself stays valid unless an invalidation event drops it.

    def mark_dirty(self, sid: int) -> None:
        self._scores.pop(sid, None)
        self._keys.pop(sid, None)
        if sid in self.members:
            self._dirty.add(sid)

    # -- internal placement ---------------------------------------------
    # Quarter-octave mantissa boundaries (frexp mantissas live in [0.5, 1)).
    _Q = (0.5, 2.0 ** -0.75, 2.0 ** -0.5, 2.0 ** -0.25)

    @classmethod
    def _band_of(cls, k: float) -> int:
        """Band id = GRAIN*exponent + quarter; its floor is <= k exactly
        (mantissa thresholds are the same float constants ``_floor_of``
        rescales with exact power-of-two multiplication)."""
        if k <= 0.0:
            return cls._ZERO_BAND
        m, e = _frexp(k)
        q = cls._Q
        j = 3 if m >= q[3] else 2 if m >= q[2] else 1 if m >= q[1] else 0
        return cls._GRAIN * e + j

    def _floor_of(self, b: int) -> float:
        f = self._floors.get(b)
        if f is None:
            if b == self._ZERO_BAND:
                f = 0.0
            else:
                e, j = divmod(b, self._GRAIN)
                f = _ldexp(self._Q[j], e)
            self._floors[b] = f
        return f

    def _place(self, sid: int, k: float, la: float) -> None:
        """Ensure the canonical band entry for ``sid`` is (band(k), la)."""
        b = self._band_of(k)
        if self._slot.get(sid) == (b, la):
            return
        heap = self._bands.get(b)
        if heap is None:
            heap = self._bands[b] = []
            bisect.insort(self._band_ids, b)
        heapq.heappush(heap, (la, sid))
        self._slot[sid] = (b, la)

    def _oplace(self, sid: int, k: float, la: float) -> None:
        """Offload-family twin of :meth:`_place` (hybrid heuristics)."""
        b = self._band_of(k)
        if self._oslot.get(sid) == (b, la):
            return
        heap = self._obands.get(b)
        if heap is None:
            heap = self._obands[b] = []
            bisect.insort(self._oband_ids, b)
        heapq.heappush(heap, (la, sid))
        self._oslot[sid] = (b, la)

    def _flush_dirty(self) -> None:
        rt = self.rt
        h = self.heuristic
        # Hybrid heuristics keep the recompute side in the main key family
        # (the constant transfer side lives in the offload family), so the
        # flushed key is the *base* key, not the min.
        keyfn = h.base_key if self.hybrid else h.key
        for sid in self._dirty:
            s = rt.storages[sid]
            rt.meta_accesses += 1
            self.key_recomputes += 1
            k = keyfn(rt, s)
            self._keys[sid] = k
            if self.stale:
                self._place(sid, k, s.last_access)
            else:
                v = self._ver.get(sid, 0) + 1
                self._ver[sid] = v
                heapq.heappush(self._kheap, (k, sid, v))
        self._dirty.clear()

    # -- scoring --------------------------------------------------------
    def cached_score(self, s) -> float:
        """Exact current score of ``s``, memoized for the current instant.

        A memo entry is valid only at the clock/last-access it was computed
        at; any scoped invalidation drops the entry.  Fresh computations
        count one metadata access (matching the linear scan's
        per-evaluation accounting); hits count none.
        """
        rt = self.rt
        sid = s.sid
        hit = self._scores.get(sid)
        # (mark_dirty pops the memo entry, so a surviving entry is valid
        # even while the *key* is still pending recomputation.)
        if (hit is not None and hit[0] == rt.clock
                and hit[1] == s.last_access):
            return hit[2]
        rt.meta_accesses += 1
        sc = self.heuristic.score(rt, s)
        self._scores[sid] = (rt.clock, s.last_access, sc)
        return sc

    # -- selection ------------------------------------------------------
    def pick(self, exclude: set[int]) -> Optional[object]:
        """Bit-exact argmin of the heuristic over the candidate set.

        Every candidate that is not excluded by an admissible lower bound
        (band floor / staleness, or its own key) is *verified* by
        recomputing its exact score with the heuristic's own formula, and
        the verified minimum — ties broken to the lowest sid, the linear
        scan's first-strictly-smaller rule over ``storages`` insertion
        order — is returned.  The ``_BOUND_EPS`` slack on every bound
        absorbs the ulp-level association difference between
        ``key/staleness`` and the score formula, so near-ties are always
        verified rather than pruned.
        """
        self._flush_dirty()
        self.picks += 1
        if self.stale:
            return self._pick_banded(exclude)
        if self.hybrid:
            return self._pick_keyed_hybrid(exclude)
        return self._pick_keyed(exclude)

    def _pick_banded(self, exclude: set[int]) -> Optional[object]:
        rt = self.rt
        storages = rt.storages
        members = self.members
        clock = rt.clock
        heappop, heappush = heapq.heappop, heapq.heappush

        best = None
        best_score = 0.0
        best_sid = -1
        thresh = float("inf")     # best_score * (1 + eps), cached
        stash: list[tuple[list, tuple[float, int]]] = []
        band_of = self._band_of

        # Key families: the recompute side, plus — for hybrid two-choice
        # heuristics — the offload side.  Each family's band floors bound
        # its own side of the min-score; a storage's hybrid argmin is
        # always discoverable through its *winning* side's family, and
        # every surviving candidate is verified with the full hybrid
        # score, so pruning a storage in the losing family is sound.
        fams = [(self._bands, self._band_ids, self._keys, self._slot)]
        if self.hybrid:
            fams.append((self._obands, self._oband_ids, self._okeys,
                         self._oslot))

        def valid_top(fam: int, b: int, heap: list):
            """Validated (la, sid) top of band ``b``; discards stale entries."""
            keys, slot = fams[fam][2], fams[fam][3]
            while heap:
                la, sid = heap[0]
                if sid in members:
                    k = keys.get(sid)
                    if (k is not None and band_of(k) == b
                            and la == storages[sid].last_access):
                        return la, sid, k
                heappop(heap)
                if slot.get(sid) == (b, la):
                    del slot[sid]            # re-add must place afresh
            return None

        # Probe every band's current lower bound (floor key over its oldest
        # member's staleness) and process most-promising first, so the
        # first walked band sets a near-optimal threshold and the rest are
        # usually skipped whole by their already-known bound.
        order: list[tuple[float, int, int]] = []
        for fam, (bands, band_ids, _k, _s) in enumerate(fams):
            for b in band_ids:
                heap = bands[b]
                if not heap:
                    continue
                top = valid_top(fam, b, heap)
                if top is None:
                    continue
                st = clock - top[0]
                if st < _MIN_STALENESS:
                    st = _MIN_STALENESS
                order.append((self._floor_of(b) / st, fam, b))
        order.sort()

        for initial_bound, fam, b in order:
            if initial_bound > thresh:
                break                        # later bands only start worse
            heap = fams[fam][0][b]
            k_floor = self._floor_of(b)
            while heap:
                top = valid_top(fam, b, heap)
                if top is None:
                    break
                la, sid, k = top
                st = clock - la              # oldest remaining in band
                if st < _MIN_STALENESS:
                    st = _MIN_STALENESS
                if k_floor / st > thresh:
                    break                    # rest of band is fresher still
                stash.append((heap, heappop(heap)))
                if sid in exclude or k / st > thresh:
                    continue                 # unselectable / provably worse
                self.pops += 1
                s = storages[sid]
                sc = self.cached_score(s)
                if (best is None or sc < best_score
                        or (sc == best_score and sid < best_sid)):
                    best, best_score, best_sid = s, sc, sid
                    thresh = best_score * (1.0 + _BOUND_EPS) + 1e-300
        for heap, entry in stash:
            heappush(heap, entry)
        return best

    def _pick_keyed(self, exclude: set[int]) -> Optional[object]:
        rt = self.rt
        storages = rt.storages
        members = self.members
        ver = self._ver
        kheap = self._kheap
        heappop, heappush = heapq.heappop, heapq.heappush

        best = None
        best_score = 0.0
        best_sid = -1
        popped: list[tuple[float, int, int]] = []

        while kheap:
            k, sid, v = kheap[0]
            if v != ver.get(sid):
                heappop(kheap)               # superseded by a newer push
                continue
            if sid not in members:
                # Dormant (locked/evicted) storage: consuming its only live
                # entry, so drop the key — membership re-add re-pushes.
                heappop(kheap)
                self._keys.pop(sid, None)
                continue
            # For staleness-free heuristics ``key`` is the same expression
            # as ``score`` (bit-identical), and equal keys pop in ascending
            # sid order — so a larger-or-equal key can neither beat the
            # best nor win its sid tie-break.
            if best is not None and k >= best_score:
                break
            popped.append(heappop(kheap))
            if sid in exclude:
                continue
            self.pops += 1
            s = storages[sid]
            sc = self.cached_score(s)
            if (best is None or sc < best_score
                    or (sc == best_score and sid < best_sid)):
                best, best_score, best_sid = s, sc, sid
        for entry in popped:
            heappush(kheap, entry)
        return best

    def _pick_keyed_hybrid(self, exclude: set[int]) -> Optional[object]:
        """Merged two-heap walk for staleness-free hybrid heuristics.

        Every member has one live entry per family (base key in
        ``_kheap``, constant offload key in ``_okheap``), and for
        staleness-free heuristics each entry's key *is* that side's score
        bit-exactly — so the hybrid score of any unseen candidate is
        bounded below by the smaller of the two validated heap tops.
        Entries pop in ascending (key, sid) order across both heaps; the
        walk breaks only once the merged top key strictly exceeds the best
        verified score (continuing through ties so the lowest sid among
        equal scores wins, as in the scan).
        """
        rt = self.rt
        storages = rt.storages
        members = self.members
        ver = self._ver
        kheap = self._kheap
        oheap = self._okheap
        oin = self._oin
        heappop, heappush = heapq.heappop, heapq.heappush

        best = None
        best_score = 0.0
        best_sid = -1
        rpopped: list[tuple[float, int, int]] = []
        opopped: list[tuple[float, int]] = []

        def rtop():
            while kheap:
                k, sid, v = kheap[0]
                if v != ver.get(sid):
                    heappop(kheap)           # superseded by a newer push
                    continue
                if sid not in members:
                    heappop(kheap)           # dormant: drop, re-add re-pushes
                    self._keys.pop(sid, None)
                    continue
                return k, sid
            return None

        def otop():
            while oheap:
                k, sid = oheap[0]
                if sid in members and sid in oin:
                    return k, sid
                heappop(oheap)               # dormant: membership re-pushes
                oin.discard(sid)
            return None

        while True:
            rt_top = rtop()
            o_top = otop()
            if rt_top is None and o_top is None:
                break
            use_r = o_top is None or (rt_top is not None and rt_top <= o_top)
            k, sid = rt_top if use_r else o_top
            if best is not None and k > best_score:
                break
            if use_r:
                rpopped.append(heappop(kheap))
            else:
                opopped.append(heappop(oheap))
            if sid in exclude:
                continue
            self.pops += 1
            s = storages[sid]
            sc = self.cached_score(s)
            if (best is None or sc < best_score
                    or (sc == best_score and sid < best_sid)):
                best, best_score, best_sid = s, sc, sid
        for entry in rpopped:
            heappush(kheap, entry)
        for entry in opopped:
            heappush(oheap, entry)
        return best
