"""Roofline-aware DTR budget autotuning (beyond-paper).

The paper treats the memory budget as given.  On TPU the budget is itself a
decision variable: saving more activations cuts the compute term (less
recompute) but raises the memory term (more HBM traffic + footprint).
Because the DTR planner costs milliseconds per budget (unlike ILP), we can
afford to sweep budgets at trace time and pick the plan minimizing the
estimated step time = max(compute, memory, collective) — "roofline-aware
DTR".

Two estimation modes:
  * ``estimate="sim"`` (fast, no compile): terms from the DTR simulation's
    own compute/byte accounting over the traced graph.
  * ``estimate="compile"`` (exact, slow): lower+compile each candidate and
    read the loop-aware HLO analyzer (launch/perf.py uses this manually).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

from ..analysis.roofline import HBM_BW, PEAK_FLOPS
from . import planner
from .simulator import measure_baseline


@dataclass
class TunedPlan:
    budget_frac: float
    plan: planner.Plan
    est_compute_s: float
    est_memory_s: float
    est_step_s: float


def autotune(grad_fn: Callable, *example_args,
             fracs: Sequence[float] = (0.9, 0.7, 0.5, 0.35, 0.25),
             chips: int = 1, heuristic: str = "h_dtr_eq") -> TunedPlan:
    """Sweep activation budgets; return the roofline-optimal DTR plan.

    ``grad_fn`` is the differentiated step (sees fwd+bwd lifetimes).  The
    sim-mode estimator charges: compute = (base + remat) flops / peak;
    memory = bytes-of-live-writes / HBM bw (both per the traced graph's
    analytic cost model, scaled per chip).
    """
    tg = planner.trace_to_log(grad_fn, *example_args, name="autotune")
    peak, base_cost = measure_baseline(tg.log)
    best: TunedPlan | None = None
    for f in fracs:
        p = planner.plan(grad_fn, *example_args, budget_bytes=f * peak,
                         heuristic=heuristic)
        if not p.feasible:
            continue
        flops = tg.total_flops * p.est_slowdown
        comp = flops / (PEAK_FLOPS * chips)
        memo = (tg.total_bytes * p.est_slowdown) / (HBM_BW * chips)
        cand = TunedPlan(budget_frac=f, plan=p, est_compute_s=comp,
                         est_memory_s=memo, est_step_s=max(comp, memo))
        if best is None or cand.est_step_s < best.est_step_s:
            best = cand
    if best is None:
        raise ValueError("no feasible budget in the sweep")
    return best
