"""Operator-DAG / event-log representation (Appendix C.6 of the DTR paper).

A *log* is a sequence of abstract instructions mirroring what the paper's
instrumented PyTorch emits:

  CONSTANT(t)                      — t is a pinned constant (followed by MEMORY)
  MEMORY(t, size)                  — size of t's storage (0 if alias)
  ALIAS(t_o, t_i)                  — t_o views t_i's storage (t_i None => owns)
  CALL(inputs, outputs, cost, op)  — pure operator call
  MUTATE(inputs, mutated, cost, op)— in-place op (rewritten copy-on-write)
  COPY(t_o, t_i)                   — new Python ref to same view
  COPYFROM(t_o, t_i)               — x = y over existing tensors
  RELEASE(t)                       — external refcount decrement

Logs can be built programmatically (``LogBuilder``), synthesized from model
shapes (``graphs.py``), extracted from jaxprs (``planner.py``), captured from
real serve/train workloads (``repro.trace``), or serialized to/from JSON
lines.  ``replay`` drives a DTR runtime from a log.

Serialization is versioned: ``dumps`` emits a ``LogHeader`` line carrying the
schema version, the log name, and log-level metadata (capture source, model
config, slot width, ...); ``loads`` accepts headerless version-1 streams for
backward compatibility.  Every instruction optionally carries ``meta`` — a
tuple of ``(key, value)`` pairs (hashable, JSON-round-trippable) used by the
trace subsystem to tag per-request/slot/phase boundaries in captured serving
traces.  Metadata never influences replay decisions.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterable, Sequence

SCHEMA_VERSION = 2

MetaT = tuple  # tuple[(str, str | int | float), ...]


def as_meta(m) -> MetaT:
    """Normalize a dict/iterable of pairs into the canonical meta tuple."""
    if not m:
        return ()
    items = m.items() if isinstance(m, dict) else m
    return tuple((str(k), v) for k, v in items)


# ---------------------------------------------------------------------------
# Instructions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Constant:
    t: str
    meta: MetaT = ()


@dataclass(frozen=True)
class Memory:
    t: str
    size: int
    meta: MetaT = ()


@dataclass(frozen=True)
class Alias:
    t_out: str
    t_in: str | None  # None => t_out's parent op created its storage
    meta: MetaT = ()


@dataclass(frozen=True)
class Call:
    inputs: tuple[str, ...]
    outputs: tuple[str, ...]
    cost: float
    op: str
    meta: MetaT = ()


@dataclass(frozen=True)
class Mutate:
    inputs: tuple[str, ...]
    mutated: tuple[str, ...]  # subset of inputs
    cost: float
    op: str
    meta: MetaT = ()


@dataclass(frozen=True)
class Copy:
    t_out: str
    t_in: str
    meta: MetaT = ()


@dataclass(frozen=True)
class CopyFrom:
    t_out: str
    t_in: str
    meta: MetaT = ()


@dataclass(frozen=True)
class Release:
    t: str
    meta: MetaT = ()


Instr = Constant | Memory | Alias | Call | Mutate | Copy | CopyFrom | Release


# ---------------------------------------------------------------------------
# Log container + builder
# ---------------------------------------------------------------------------

@dataclass
class Log:
    instrs: list[Instr] = field(default_factory=list)
    name: str = "log"
    version: int = SCHEMA_VERSION
    meta: dict = field(default_factory=dict)   # log-level capture metadata

    def __iter__(self):
        return iter(self.instrs)

    def __len__(self) -> int:
        return len(self.instrs)

    # -- serialization ------------------------------------------------------
    def dumps(self) -> str:
        header = {"kind": "LogHeader", "version": SCHEMA_VERSION,
                  "name": self.name}
        if self.meta:
            header["meta"] = self.meta
        out = [json.dumps(header, allow_nan=False)]
        for ins in self.instrs:
            d = {"kind": type(ins).__name__}
            for k in ins.__dataclass_fields__:
                v = getattr(ins, k)
                if k == "meta":
                    if v:
                        d[k] = [list(p) for p in v]
                    continue
                d[k] = v
            out.append(json.dumps(d, allow_nan=False))
        return "\n".join(out)

    @staticmethod
    def loads(text: str, name: str | None = None) -> "Log":
        kinds = {c.__name__: c for c in
                 (Constant, Memory, Alias, Call, Mutate, Copy, CopyFrom,
                  Release)}
        instrs: list[Instr] = []
        version = 1
        log_name = name
        log_meta: dict = {}
        for lineno, line in enumerate(text.splitlines(), start=1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"malformed log line {lineno}: {e}") from e
            if not isinstance(d, dict) or "kind" not in d:
                raise ValueError(
                    f"malformed log line {lineno}: not an instruction object")
            kind = d.pop("kind")
            if kind == "LogHeader":
                version = int(d.get("version", 1))
                if version > SCHEMA_VERSION:
                    raise ValueError(
                        f"log schema version {version} is newer than "
                        f"supported ({SCHEMA_VERSION})")
                if log_name is None and "name" in d:
                    log_name = d["name"]
                log_meta = d.get("meta", {}) or {}
                continue
            cls = kinds.get(kind)
            if cls is None:
                raise ValueError(
                    f"malformed log line {lineno}: unknown instruction "
                    f"kind {kind!r}")
            for k in ("inputs", "outputs", "mutated"):
                if k in d:
                    d[k] = tuple(d[k])
            if "meta" in d:
                d["meta"] = as_meta(d["meta"])
            try:
                instrs.append(cls(**d))
            except TypeError as e:
                raise ValueError(
                    f"malformed log line {lineno}: bad fields for "
                    f"{kind}: {e}") from e
        return Log(instrs, name=log_name or "log", version=version,
                   meta=log_meta)

    # -- analysis helpers ---------------------------------------------------
    def baseline_cost(self) -> float:
        """Total op cost with unlimited memory (no rematerialization)."""
        return sum(i.cost for i in self.instrs if isinstance(i, (Call, Mutate)))

    def op_count(self) -> int:
        return sum(1 for i in self.instrs if isinstance(i, (Call, Mutate)))

    def pinned_bytes(self) -> int:
        """Total bytes of CONSTANT storages — the unevictable floor.

        Constant storages are pinned, so even a RELEASE never frees them
        under the ``ignore``/``eager`` policies — once created they occupy
        memory to the end of the run (``banish`` can free them; activation-
        mode budgets are an approximation there).  Serving sweeps express
        budgets as ``pinned + fraction * (peak - pinned)`` to scan the
        meaningful (activation/KV) range.
        """
        total = 0
        for a, b in zip(self.instrs, self.instrs[1:]):
            if isinstance(a, Constant) and isinstance(b, Memory):
                total += b.size
        return total


class LogBuilder:
    """Convenience builder that tracks tensor names and emits releases.

    ``call`` emits CALL + MEMORY/ALIAS per output. ``auto_release`` computes
    last-use positions over the whole program and appends RELEASE right after
    the final consuming instruction — modelling framework refcounting (the
    liveness information DTR receives online, Appendix A.2).
    """

    def __init__(self, name: str = "log") -> None:
        self.log = Log(name=name)
        self._fresh = 0

    def fresh(self, prefix: str = "t") -> str:
        self._fresh += 1
        return f"{prefix}{self._fresh}"

    def constant(self, size: int, name: str | None = None,
                 meta=None) -> str:
        t = name or self.fresh("const")
        self.log.instrs.append(Constant(t, meta=as_meta(meta)))
        self.log.instrs.append(Memory(t, int(size)))
        return t

    def call(
        self,
        inputs: Sequence[str],
        out_sizes: Sequence[int],
        cost: float,
        op: str,
        aliases: Sequence[str | None] | None = None,
        out_names: Sequence[str] | None = None,
        meta=None,
    ) -> list[str]:
        outs = list(out_names) if out_names else [self.fresh() for _ in out_sizes]
        self.log.instrs.append(Call(tuple(inputs), tuple(outs), float(cost),
                                    op, meta=as_meta(meta)))
        aliases = aliases or [None] * len(outs)
        for t, size, al in zip(outs, out_sizes, aliases):
            self.log.instrs.append(Memory(t, 0 if al is not None else int(size)))
            self.log.instrs.append(Alias(t, al))
        return outs

    def mutate(self, inputs: Sequence[str], mutated: Sequence[str],
               cost: float, op: str, meta=None) -> None:
        self.log.instrs.append(
            Mutate(tuple(inputs), tuple(mutated), float(cost), op,
                   meta=as_meta(meta)))

    def release(self, t: str, meta=None) -> None:
        self.log.instrs.append(Release(t, meta=as_meta(meta)))

    def auto_release(self, keep: Iterable[str] = ()) -> Log:
        """Append RELEASE after last use for every tensor not in ``keep``.

        Constants are also released (banishing policies may free them).
        Tensors in ``keep`` stay externally referenced => the runtime's output
        condition will pin them at the end (gradients / loss, Appendix C.6).
        """
        keep = set(keep)
        last_use: dict[str, int] = {}
        for idx, ins in enumerate(self.log.instrs):
            if isinstance(ins, Call):
                # A Call is followed by 2*len(outputs) metadata instructions;
                # releases must land after that block.
                end = idx + 2 * len(ins.outputs)
                for t in ins.inputs:
                    last_use[t] = end
                for t in ins.outputs:
                    last_use.setdefault(t, end)
            elif isinstance(ins, Mutate):
                for t in ins.inputs:
                    last_use[t] = idx
                for t in ins.mutated:
                    last_use.setdefault(t, idx)
            elif isinstance(ins, Constant):
                last_use.setdefault(ins.t, idx + 1)  # after its MEMORY
        # Insert releases in reverse order so indices stay valid.
        inserts: list[tuple[int, Release]] = [
            (idx, Release(t)) for t, idx in last_use.items() if t not in keep
        ]
        inserts.sort(key=lambda p: p[0], reverse=True)
        for idx, rel in inserts:
            self.log.instrs.insert(idx + 1, rel)
        return self.log


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------

def parse_call_block(instrs: Sequence[Instr], i: int):
    """Parse the (MEMORY, ALIAS) metadata block following a CALL at ``i``.

    Returns ``(sizes, alias_names, j)`` where ``sizes[k]`` / ``alias_names[k]``
    describe output ``k`` (``alias_names[k] is None`` for an owning output)
    and ``j`` is the index of the first instruction after the block.  Shared
    by ``replay`` and the static-planner trace analysis (``repro.static``),
    so the two consumers cannot drift on the block layout.
    """
    ins = instrs[i]
    assert isinstance(ins, Call)
    sizes: list[int] = []
    alias_names: list[str | None] = []
    j = i + 1
    for t in ins.outputs:
        mem = instrs[j]
        ali = instrs[j + 1]
        assert isinstance(mem, Memory) and mem.t == t
        assert isinstance(ali, Alias) and ali.t_out == t
        sizes.append(mem.size)
        alias_names.append(ali.t_in)
        j += 2
    return sizes, alias_names, j


def replay(log: Log, rt) -> dict[str, int]:
    """Drive runtime ``rt`` (core.runtime.DTRRuntime) from a log.

    Returns the final mapping from log tensor names to runtime tensor ids.
    Implements the paper's mutation rewrite (copy-on-write), COPY/COPYFROM
    refcount semantics, and the output condition (all still-referenced tensors
    are materialized and locked at the end).
    """
    env: dict[str, int] = {}
    pending_mem: dict[str, tuple] = {}

    i = 0
    instrs = log.instrs
    n = len(instrs)
    while i < n:
        ins = instrs[i]
        if isinstance(ins, Constant):
            # MEMORY follows.
            mem = instrs[i + 1]
            assert isinstance(mem, Memory) and mem.t == ins.t
            env[ins.t] = rt.constant(mem.size, name=ins.t)
            i += 2
            continue
        if isinstance(ins, Call):
            # Followed by len(outputs) (MEMORY, ALIAS) pairs.
            sizes, alias_names, j = parse_call_block(instrs, i)
            aliases = [env[a] if a is not None else None for a in alias_names]
            tids = rt.call(ins.op, ins.cost, [env[x] for x in ins.inputs],
                           sizes, aliases=aliases,
                           out_names=list(ins.outputs))
            for t, tid in zip(ins.outputs, tids):
                env[t] = tid
            i = j
            continue
        if isinstance(ins, Mutate):
            # Copy-on-write rewrite: pure op from inputs -> fresh versions of
            # the mutated tensors; remap names (Appendix C.6).
            out_sizes = [rt.size_of(env[t]) for t in ins.mutated]
            tids = rt.call(ins.op + "_mut", ins.cost,
                           [env[x] for x in ins.inputs],
                           out_sizes, aliases=[None] * len(ins.mutated),
                           out_names=[t + "'" for t in ins.mutated])
            for t, tid in zip(ins.mutated, tids):
                rt.release(env[t])
                env[t] = tid
            i += 1
            continue
        if isinstance(ins, Copy):
            env[ins.t_out] = env[ins.t_in]
            rt.addref(env[ins.t_in])
            i += 1
            continue
        if isinstance(ins, CopyFrom):
            rt.release(env[ins.t_out])
            rt.addref(env[ins.t_in])
            env[ins.t_out] = env[ins.t_in]
            i += 1
            continue
        if isinstance(ins, Release):
            rt.release(env[ins.t])
            i += 1
            continue
        if isinstance(ins, (Memory, Alias)):  # stray (already consumed)
            i += 1
            continue
        raise TypeError(f"unknown instruction {ins}")

    # Output condition: everything still externally referenced must be
    # resident at the end (gradients, loss, prediction).
    rt.finalize()
    return env
