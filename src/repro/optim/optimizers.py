"""Minimal, production-shaped optimizer library (pure pytree transforms)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    name: str = "opt"


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (
            1 + jnp.cos(np.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype="float32") -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)
    sd = jnp.dtype(state_dtype)

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, sd), params)
        return OptState(jnp.zeros((), jnp.int32),
                        {"m": z, "v": jax.tree.map(jnp.copy, z)})

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = (b1 * m.astype(jnp.float32) + (1 - b1) * g32)
            v = (b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), m.astype(sd), v.astype(sd)

        out = jax.tree.map(upd, grads, state.inner["m"], state.inner["v"],
                           params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step, {"m": m, "v": v})

    return Optimizer(init, update, "adamw")


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; memory ~ O(n+m) per matrix)
# ---------------------------------------------------------------------------

def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(one, params))

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def one(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = (vr / jnp.mean(vr, axis=-1, keepdims=True)
                        )[..., None]
                u = g32 * jax.lax.rsqrt(rfac * vc[..., None, :] + eps)
                news = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                news = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype), news

        out = jax.tree.map(one, grads, state.inner, params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and ("v" in x or "vr" in x))
        updates = jax.tree.map(lambda t2: t2[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        news = jax.tree.map(lambda t2: t2[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step, news)

    return Optimizer(init, update, "adafactor")


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------

def sgdm(lr=1e-2, momentum=0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros_like(
                            p, jnp.float32), params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda mm, g: momentum * mm
                         + g.astype(jnp.float32), state.inner, grads)
        updates = jax.tree.map(lambda mm, p: (-lr_t * mm).astype(p.dtype),
                               m, params)
        return updates, OptState(step, m)

    return Optimizer(init, update, "sgdm")


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name](**kw)
