"""Optimizers (built from scratch — no optax offline): AdamW, Adafactor, SGD-m.

All optimizers are pure pytree transforms with ZeRO-1-friendly state layout:
the state tree mirrors the param tree, so sharding rules (params sharded over
``model``, optionally ``fsdp`` over ``data``) apply to the state unchanged —
which is exactly ZeRO when FSDP is on.

deepseek-v3-671b trains with Adafactor (factored second moment, no first
moment): 671B params × AdamW-f32 states cannot fit a 512-chip v5e slice;
Adafactor + bf16 params does (DESIGN.md §5).
"""
from .optimizers import (
    OptState, adafactor, adamw, apply_updates, clip_by_global_norm,
    make_optimizer, sgdm, cosine_schedule,
)

__all__ = [
    "OptState", "adafactor", "adamw", "apply_updates",
    "clip_by_global_norm", "make_optimizer", "sgdm", "cosine_schedule",
]
