"""jit'd dispatch wrappers: model-layout adapters over the Pallas kernels.

On TPU these are the fast paths; on CPU (this container) they run the kernels
in interpret mode for correctness work, and the model layer falls back to its
XLA formulation (models/layers.py) for speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .moe_gemm import moe_grouped_gemm
from .rwkv6_chunk import rwkv6_chunk
from . import ref

_ON_TPU = None


def on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.devices()[0].platform == "tpu"
    return _ON_TPU


def attention(q_bshd, k_bskd, v_bskd, *, causal: bool = True,
              window: int = 0, use_kernel: bool | None = None):
    """Model layout [B,S,H,D] adapter; returns [B,S,H,D]."""
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    q = q_bshd.swapaxes(1, 2)
    k = k_bskd.swapaxes(1, 2)
    v = v_bskd.swapaxes(1, 2)
    if use_kernel:
        out = flash_attention(q, k, v, causal=causal, window=window,
                              interpret=not on_tpu())
    else:
        out = ref.flash_reference(q, k, v, causal=causal, window=window)
    return out.swapaxes(1, 2)


def rwkv_mix(r_bshd, k_bshd, v_bshd, wlog_bshd, u_hd,
             *, use_kernel: bool | None = None):
    """[B,S,H,D] layout adapter for the chunked RWKV6 recurrence."""
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    b, s, h, d = r_bshd.shape

    def to_bh(x):
        return x.swapaxes(1, 2).reshape(b * h, s, d)

    u = jnp.broadcast_to(u_hd[None], (b, h, d)).reshape(b * h, d)
    args = (to_bh(r_bshd), to_bh(k_bshd), to_bh(v_bshd), to_bh(wlog_bshd), u)
    if use_kernel:
        out = rwkv6_chunk(*args, interpret=not on_tpu())
    else:
        out = ref.rwkv6_reference(*args)
    return out.reshape(b, h, s, d).swapaxes(1, 2)


def expert_ffn(buf_ecd, w_edf, *, use_kernel: bool | None = None):
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return moe_grouped_gemm(buf_ecd, w_edf, interpret=not on_tpu())
    return ref.moe_gemm_reference(buf_ecd, w_edf)
