"""RWKV6 chunked-recurrence Pallas TPU kernel.

The GPU reference implementation is a per-thread serial scan (CUDA wkv6
kernel); the TPU-native form is *chunkwise*: within a chunk the token
interactions are dense matmuls on the MXU with per-channel decay factors
applied in log space; the cross-chunk state [D,D] (f32) lives in VMEM scratch
and is carried across the sequential chunk grid dimension.

Grid: (B·H, S/C) with the chunk dimension 'arbitrary' (sequential).  Inputs
r,k,v: [BH, S, D]; w = log-decay (≤0) [BH, S, D]; bonus u: [BH, D] (per-head,
broadcast over batch in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import compiler_params

DEFAULT_CHUNK = 64
# The separable decay factorization exp(cumW_t)*exp(-cumW_s) is bounded only
# while |cum log-decay| stays within f32 exponent range; 64 steps of the
# fastest realistic RWKV6 decay (~e^-3.3/step) is the safe limit.  Longer
# chunks must be split (the sequence scan handles any S).
MAX_CHUNK = 64


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_scr, *,
                 chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)          # [C, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = w_ref[0].astype(jnp.float32)         # log decay <= 0
    u = u_ref[0].astype(jnp.float32)          # [D]

    cum = jnp.cumsum(lw, axis=0)              # logW_t   [C, D]
    cum_prev = cum - lw                       # logW_{t-1}
    state = state_scr[...]                    # [D, D]

    # inter-chunk: (r_t ⊙ W_{t-1}) @ S0
    inter = jax.lax.dot_general(
        r * jnp.exp(cum_prev), state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [C, D]

    # intra-chunk: A[t,s] = Σ_d (r_t W_{t-1}) (k_s / W_s), s < t  (log-safe:
    # both factors bounded by the chunk-local normalization exp(cum - cum)).
    rq = r * jnp.exp(cum_prev)
    ks = k * jnp.exp(-cum)
    att = jax.lax.dot_general(rq, ks, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [C, C]
    t_idx = jax.lax.iota(jnp.int32, chunk)
    tri = t_idx[:, None] > t_idx[None, :]
    att = jnp.where(tri, att, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=1)          # bonus, s == t
    intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    intra = intra + diag[:, None] * v

    o_ref[0] = (inter + intra).astype(o_ref.dtype)

    # state update: S1 = W_C ⊙ S0 + Σ_s (k_s W_C / W_s) v_s^T
    wtot = cum[-1]                                       # [D]
    kdec = k * jnp.exp(wtot[None, :] - cum)              # [C, D]
    state_scr[...] = state * jnp.exp(wtot)[:, None] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def rwkv6_chunk(r, k, v, w_log, u, *, chunk: int = DEFAULT_CHUNK,
                interpret: bool = False):
    """r,k,v,w_log: [BH, S, D]; u: [BH, D].  Returns [BH, S, D] (f32)."""
    bh, s, d = r.shape
    chunk = min(chunk, s)
    assert chunk <= MAX_CHUNK, (
        f"chunk {chunk} > {MAX_CHUNK}: the separable decay form overflows "
        f"f32 for long chunks; split the sequence instead")
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)

    def seq_map(b, c):
        return (b, c, 0)

    def u_map(b, c):
        return (b, 0)

    kernel = functools.partial(_rwkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, chunk, d), seq_map),
            pl.BlockSpec((1, d), u_map),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), seq_map),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=compiler_params(
            ("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w_log, u)
    return out
