"""Grouped (expert-blocked) GEMM Pallas TPU kernel for MoE dispatch buffers.

MegaBlocks' insight (block-sparse expert matmuls) re-tiled for the TPU MXU:
after sort-based dispatch packs tokens into equal-capacity expert buffers
[E, C, d], the expert FFN is a block-diagonal matmul.  The kernel walks
grid = (E, C/bc, F/bf, d/bd) with the contraction dim innermost, accumulating
in VMEM scratch — each expert's weight tile is fetched once per (bc, bf) tile
pair, giving the same data-reuse schedule as a dense GEMM per expert without
materializing a [E·C, d] × [E·d, F] dense product.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import compiler_params


def _moe_gemm_kernel(x_ref, w_ref, o_ref, acc_scr, *, k_blocks: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == k_blocks - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret"))
def moe_grouped_gemm(x, w, *, block_c: int = 128, block_f: int = 128,
                     block_d: int = 512, interpret: bool = False):
    """x: [E, C, d] expert buffers; w: [E, d, F] -> [E, C, F]."""
    e, c, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert c % block_c == 0 and f % block_f == 0 and d % block_d == 0
    k_blocks = d // block_d
    grid = (e, c // block_c, f // block_f, k_blocks)

    out = pl.pallas_call(
        functools.partial(_moe_gemm_kernel, k_blocks=k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda ei, ci, fi, ki: (ei, ci, ki)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda ei, ci, fi, ki: (ei, ki, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ei, ci, fi, ki: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=compiler_params(
            ("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out
