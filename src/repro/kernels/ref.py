"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_reference(q, k, v, *, causal: bool = True,
                    window: int = 0) -> jax.Array:
    """q: [B,Hq,Sq,D]; k/v: [B,Hkv,Skv,D] — naive softmax attention."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    if causal:
        mask = kpos <= qpos
        if window > 0:
            mask &= (qpos - kpos) < window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def rwkv6_reference(r, k, v, w_log, u) -> jax.Array:
    """Serial recurrence oracle.  r,k,v,w_log: [BH,S,D]; u: [BH,D]."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = jnp.exp(w_log.astype(jnp.float32))
    u = u.astype(jnp.float32)
    bh, s, d = r.shape

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bd,be->bde", kt, vt)
        out = jnp.einsum("bd,bde->be", rt, state + u[:, :, None] * kv)
        state = state * wt[:, :, None] + kv
        return state, out

    s0 = jnp.zeros((bh, d, d), jnp.float32)
    _, outs = jax.lax.scan(
        step, s0,
        (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         w.swapaxes(0, 1)))
    return outs.swapaxes(0, 1)


def moe_gemm_reference(x, w) -> jax.Array:
    """x: [E,C,d]; w: [E,d,F] — per-expert matmul."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
