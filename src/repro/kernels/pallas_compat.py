"""Version-compatibility shims for the Pallas TPU API surface.

The container's jax pins an older Pallas: ``pltpu.CompilerParams`` was named
``TPUCompilerParams`` before the rename, and kernels must construct whichever
exists so interpret-mode validation runs on any supported jax.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(dimension_semantics: tuple[str, ...]):
    """Build the TPU compiler-params object across the rename."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(dimension_semantics=tuple(dimension_semantics))
