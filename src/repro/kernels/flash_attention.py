"""Flash attention Pallas TPU kernel (online softmax, VMEM-tiled).

TPU-native schedule: grid = (batch·heads, Sq/bq, Skv/bk); for each query tile
the kv tiles stream through VMEM while running max / normalizer / output
accumulator live in VMEM scratch (f32).  Tile sizes default to MXU-aligned
128×128.  GQA is handled in the kv index map (query head → kv head group), so
K/V tiles are fetched once per group — the memory win that makes GQA decode
fast.  Causal and sliding-window masks are applied with iota comparisons
inside the tile; fully-masked tiles are skipped via ``pl.when`` on the block
index (saves ~half the work for causal).

Validated against ``ref.flash_reference`` in interpret mode (CPU) across
shape/dtype sweeps — see tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, kv_blocks: int, seq_q: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    def body():
        q = q_ref[0].astype(jnp.float32) * scale        # [bq, d]
        k = k_ref[0].astype(jnp.float32)                 # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # [bq, bk]
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        # Padding beyond the true sequence end.
        mask &= (k_pos < seq_kv)[None, :]
        if causal:
            offs = seq_kv - seq_q  # queries start at this kv offset
            mask &= k_pos[None, :] <= (q_pos[:, None] + offs)
            if window > 0:
                mask &= (q_pos[:, None] + offs - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        # Zero padded kv rows explicitly: p is ~0 there, but 0 x junk from
        # the padded tile region is NaN-poisonous in the PV product.
        v = jnp.where((k_pos < seq_kv)[:, None], v_ref[0], 0)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # Skip tiles strictly above the diagonal (and outside the window).
        offs = seq_kv - seq_q
        first_q = qi * block_q + offs
        last_q = first_q + block_q - 1
        live = ki * block_k <= last_q
        if window > 0:
            live &= (ki + 1) * block_k - 1 >= first_q - window + 1
        pl.when(live)(body)
    else:
        body()

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / np.sqrt(d)

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    q_blocks = pl.cdiv(sq, block_q)
    kv_blocks = pl.cdiv(skv, block_k)
    grid = (b * hq, q_blocks, kv_blocks)

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        bb = bh // hq
        h = bh % hq
        return (bb * hkv + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_blocks=kv_blocks,
        seq_q=sq, seq_kv=skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
