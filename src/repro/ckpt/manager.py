"""Checkpoint manager: atomic, retention-limited, elastic-restorable.

Format: one directory per step containing ``arrays.npz`` (flattened pytree,
keys are ``/``-joined paths) + ``manifest.json`` (step, pytree structure,
data-pipeline cursor, mesh shape at save time).  Writes go to a temp dir and
are atomically renamed — a crash mid-save never corrupts the latest
checkpoint.  Restore is **elastic**: arrays are stored as full (gathered)
logical arrays, so a job restarted on a different device count just reshards
on load (sharding is reapplied by the caller's in_shardings).

For 1000+-node scale the same layout shards per host (each host writes its
addressable shards under ``arrays.<host>.npz``); this container has one host,
so the gathered path is exercised and the per-host path is unit-tested with
host=0.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None, host: int = 0) -> str:
    """Atomically write a checkpoint for ``step``; returns its path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, f"arrays.{host}.npz"), **flat)
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": step,
            "time": time.time(),
            "treedef": str(treedef),
            "keys": sorted(flat.keys()),
            "n_hosts": 1,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, allow_nan=False)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def restore_latest(directory: str, like: Any,
                   host: int = 0) -> tuple[Optional[int], Any, dict]:
    """Restore the newest complete checkpoint into the structure of ``like``.

    Returns (step, tree, extra); (None, like, {}) when nothing to restore.
    Elastic: device count/sharding may differ from save time — caller
    re-applies shardings (device_put with in_shardings).
    """
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_")) if os.path.isdir(directory) else []
    if not steps:
        return None, like, {}
    step = steps[-1]
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"arrays.{host}.npz"))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    new_leaves = []
    for (pth, leaf) in paths:
        key = "/".join(_path_str(p) for p in pth)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(arr.astype(leaf.dtype))
    return step, jax.tree_util.tree_unflatten(treedef, new_leaves), \
        manifest.get("extra", {})


@dataclass
class CheckpointManager:
    """Retention + cadence policy around save/restore."""
    directory: str
    every_steps: int = 100
    keep: int = 3

    def maybe_save(self, step: int, tree: Any,
                   extra: Optional[dict] = None) -> Optional[str]:
        if step % self.every_steps != 0:
            return None
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def restore(self, like: Any):
        return restore_latest(self.directory, like)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
