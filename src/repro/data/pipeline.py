"""Deterministic synthetic LM data pipeline.

Produces structured pseudo-language (Zipfian unigrams + bigram transitions +
copy motifs) so small models have real signal to learn — loss decreases
measurably within a few hundred steps, unlike uniform-random tokens.

Deterministic + seekable: the stream is a pure function of (seed, step), so
resuming from a checkpoint cursor reproduces the exact batch sequence — the
fault-tolerance property large jobs need.  Prefetch: a one-slot background
thread hides generation latency behind the train step.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np


@dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    n_codebooks: int = 0
    zipf_a: float = 1.2

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step) — the seekable cursor."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        shape = (self.batch, self.seq_len)
        if self.n_codebooks:
            shape = shape + (self.n_codebooks,)
        # Zipfian unigrams (bounded to vocab).
        toks = rng.zipf(self.zipf_a, size=shape)
        toks = np.minimum(toks - 1, self.vocab - 1)
        # Deterministic bigram structure: every even position continues a
        # fixed permutation chain of its predecessor (learnable signal).
        perm_rng = np.random.default_rng(self.seed)
        perm = perm_rng.permutation(self.vocab)
        if self.n_codebooks:
            toks[:, 1::2, :] = perm[toks[:, 0::2, :][
                :, : toks[:, 1::2, :].shape[1]]]
        else:
            toks[:, 1::2] = perm[toks[:, 0::2][:, : toks[:, 1::2].shape[1]]]
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """One-slot background prefetch (overlap host datagen with device step)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            s = start_step
            while not self._stop.is_set():
                b = source.batch_at(s)
                try:
                    self._q.put((s, b), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def stop(self):
        self._stop.set()


def make_batch_specs(cfg, batch: int, seq_len: int,
                     dtype=np.int32) -> dict:
    """ShapeDtypeStruct batch stand-ins for lowering (dry-run input_specs)."""
    if cfg.n_codebooks:
        tok = jax.ShapeDtypeStruct((batch, seq_len, cfg.n_codebooks),
                                   np.dtype(dtype))
    else:
        tok = jax.ShapeDtypeStruct((batch, seq_len), np.dtype(dtype))
    specs = {"tokens": tok}
    if cfg.cross_attn_dim:
        specs["img_embed"] = jax.ShapeDtypeStruct(
            (batch, cfg.cross_attn_tokens, cfg.cross_attn_dim),
            np.dtype("bfloat16"))
    return specs
