"""Mixtral-8x7B [arXiv:2401.04088]: 8 experts top-2, sliding-window attn."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    pattern=("attn_local",), window=4096,
    moe=True, n_experts=8, top_k=2, moe_d_ff=14336,
    rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16, window=8,
                          n_experts=4, top_k=2, moe_d_ff=96,
                          dtype="float32")
