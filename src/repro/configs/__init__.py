"""Assigned architecture configs (exact dims from the assignment table).

Each module exposes ``CONFIG`` (full size) and ``smoke()`` (reduced same-
family config for CPU tests).  ``get(name)`` / ``ARCHS`` are the registry.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "recurrentgemma_2b",
    "smollm_135m",
    "llama3_2_1b",
    "qwen2_0_5b",
    "gemma3_1b",
    "llama3_2_vision_11b",
    "musicgen_large",
    "rwkv6_1_6b",
    "deepseek_v3_671b",
    "mixtral_8x7b",
]

# CLI ids (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "recurrentgemma-2b": "recurrentgemma_2b",
    "smollm-135m": "smollm_135m",
    "llama3.2-1b": "llama3_2_1b",
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma3-1b": "gemma3_1b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "musicgen-large": "musicgen_large",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mixtral-8x7b": "mixtral_8x7b",
})


def get(name: str):
    mod = importlib.import_module(
        f".{ALIASES.get(name, name)}", __package__)
    return mod.CONFIG


def get_smoke(name: str):
    mod = importlib.import_module(
        f".{ALIASES.get(name, name)}", __package__)
    return mod.smoke()
