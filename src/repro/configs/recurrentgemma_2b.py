"""RecurrentGemma-2B [arXiv:2402.19427]: Griffin — RG-LRU + local attn 1:2.

26 layers = 8 scan groups x (rec, rec, attn_local) + 2 rec tail.
Local attention window 2048.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    pattern=("rglru", "rglru", "attn_local"),
    tail=("rglru", "rglru"),
    window=2048, lru_width=2560, conv_width=4,
    rope_theta=10_000.0, tie_embeddings=True, mlp_act="gelu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=5, d_model=64, n_heads=2, n_kv_heads=1,
                          d_ff=128, vocab=256, head_dim=32, window=8,
                          lru_width=64,
                          pattern=("rglru", "rglru", "attn_local"),
                          tail=("rglru", "rglru"), dtype="float32")
