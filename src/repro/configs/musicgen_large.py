"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens.

Backbone only; the EnCodec frontend is a STUB — inputs are 4 parallel
codebook token streams [B, S, 4] (delay-pattern handling lives in the
application layer, not the backbone).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, head_dim=64,
    pattern=("attn",), n_codebooks=4, mlp_act="gelu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=64, head_dim=16, n_codebooks=4,
                          dtype="float32")
