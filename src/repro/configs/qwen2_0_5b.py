"""Qwen2-0.5B [arXiv:2407.10671]: GQA with QKV bias."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151936, head_dim=64, qkv_bias=True,
    pattern=("attn",), rope_theta=1_000_000.0, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=56, n_heads=7, n_kv_heads=1,
                          d_ff=128, vocab=256, head_dim=8, dtype="float32")
