"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: llama-arch small dense LM."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152, head_dim=64,
    pattern=("attn",), rope_theta=10_000.0, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16,
                          dtype="float32")
