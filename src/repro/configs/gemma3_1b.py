"""Gemma3-1B [hf:google/gemma-3-1b-pt]: 5:1 local:global attention, 128k ctx.

26 layers = 4 scan groups x (5 local + 1 global) + 2 local tail.
Sliding window 512 for local layers.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab=262144, head_dim=256,
    pattern=("attn_local",) * 5 + ("attn",),
    tail=("attn_local", "attn_local"),
    window=512, rope_theta=1_000_000.0, tie_embeddings=True,
    mlp_act="gelu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=8, d_model=48, n_heads=2, n_kv_heads=1,
                          d_ff=96, vocab=256, head_dim=24, window=8,
                          dtype="float32")
