"""DeepSeek-V3-671B [arXiv:2412.19437]: MLA + 256-expert MoE top-8 + shared.

61 layers: 3 leading dense-FFN layers + 58 MoE layers.  MLA dims per the
paper: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v 128.  MTP head
omitted (noted in DESIGN.md §Arch-applicability).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280, head_dim=128,
    pattern=("attn",), n_dense_layers=3,
    moe=True, n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=160, vocab=256, head_dim=16,
                          n_dense_layers=1, n_experts=8, n_shared_experts=1,
                          top_k=2, moe_d_ff=48,
                          mla=True, q_lora_rank=32, kv_lora_rank=16,
                          qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                          dtype="float32")
