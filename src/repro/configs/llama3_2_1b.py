"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]: small llama3 dense LM."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=128256, head_dim=64,
    pattern=("attn",), rope_theta=500_000.0, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=8, n_kv_heads=2,
                          d_ff=160, vocab=256, head_dim=8, dtype="float32")
