"""Llama-3.2-11B-Vision [hf]: text backbone w/ cross-attn image layers.

40 layers = 8 scan groups x (4 self + 1 cross).  Vision frontend is a STUB:
input_specs provides precomputed patch embeddings [B, 1601, 7680].
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    rope_theta=500_000.0,
    cross_attn_tokens=1601, cross_attn_dim=7680,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16,
                          cross_attn_tokens=17, cross_attn_dim=48,
                          dtype="float32")
