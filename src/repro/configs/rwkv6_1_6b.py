"""RWKV6-1.6B "Finch" [arXiv:2404.05892]: attention-free, data-dep decay."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64, rwkv_head_dim=64,
    pattern=("rwkv",),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=2, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=32, rwkv_head_dim=32,
                          dtype="float32")
