"""Mixture-of-Experts with sort-based capacity dispatch (expert-parallel).

TPU-native adaptation of MegaBlocks-style dispatch: per batch row, token→
expert assignments are sorted by expert id, packed into fixed-capacity expert
buffers (equal blocks => MXU-friendly grouped einsum, no ragged ops), experts
computed as a block-diagonal einsum with the expert dim sharded over the
``model`` mesh axis (EP), and results scattered back with combine weights.
Dropped tokens (overflow beyond capacity) pass through the residual, standard
for capacity-based routing.

Covers mixtral-8x7b (8e top-2, softmax gate, renorm) and deepseek-v3 (256e
top-8 + 1 shared expert, sigmoid gate with renorm — per the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import ParamInfo, shard
from .config import ModelConfig
from .layers import adtype, mlp_apply, mlp_defs


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    defs = {
        "router": ParamInfo((d, e), "float32", (None, "expert")),
        # FSDP dim: sharding the non-contracting dim instead was tried and
        # REFUTED — XLA materializes the fully-gathered expert stack
        # (333 GiB/device); the contracting-dim layout costs partial-sum
        # all-reduces but stays 7x smaller (EXPERIMENTS.md §Perf cell B).
        "wi": ParamInfo((e, d, f), cfg.param_dtype,
                        ("expert", None, "mlp"), fsdp_dim=1),
        "wg": ParamInfo((e, d, f), cfg.param_dtype,
                        ("expert", None, "mlp"), fsdp_dim=1),
        "wo": ParamInfo((e, f, d), cfg.param_dtype,
                        ("expert", "mlp", None), fsdp_dim=2),
    }
    if cfg.n_shared_experts > 0:
        defs["shared"] = mlp_defs(
            cfg, d_ff=cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff))
    return defs


def expert_capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    c = int(np.ceil(tokens_per_row * cfg.top_k / cfg.n_experts
                    * cfg.capacity_factor))
    return max(8, int(np.ceil(c / 8)) * 8)


def _dispatch_row(e_flat: jax.Array, capacity: int, n_experts: int):
    """Per-row dispatch indices.

    e_flat: [S*k] expert id per assignment (row-major over (token, k)).
    Returns (src_assign, slot, keep): for each sorted assignment, its source
    assignment index, its slot in the [E*C] buffer, and validity.
    """
    order = jnp.argsort(e_flat)                      # stable
    se = e_flat[order]
    group_start = jnp.searchsorted(se, jnp.arange(n_experts))
    pos = jnp.arange(se.shape[0]) - group_start[se]
    keep = pos < capacity
    slot = se * capacity + jnp.minimum(pos, capacity - 1)
    return order, slot, keep


def moe_apply(cfg: ModelConfig, p, x):
    """x: [B, S, d] -> [B, S, d]."""
    dt = adtype(cfg)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = expert_capacity(cfg, s)

    # Router (fp32 for stable softmax/sigmoid).
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    if cfg.n_shared_experts > 0:   # deepseek-style sigmoid scoring
        scores = jax.nn.sigmoid(logits)
    else:                          # mixtral-style softmax scoring
        scores = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(scores, k)            # [B,S,k]
    topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)
    topw = topw.astype(dt)

    e_flat = topi.reshape(b, s * k)
    w_flat = topw.reshape(b, s * k)

    order, slot, keep = jax.vmap(
        lambda ef: _dispatch_row(ef, cap, e))(e_flat)
    src_tok = order // k                              # token index per slot

    # Gather tokens into expert buffers [B, E*C, d].  All scatters/gathers
    # are vmapped over batch so the batch dim is a *scatter batch dim* —
    # 2D-indexed .at[bidx, slot] forms are unpartitionable and force XLA
    # SPMD to replicate the full dispatch buffer (30 GB/layer for
    # deepseek-v3; see EXPERIMENTS.md §Perf cell B).
    gathered = jax.vmap(lambda xr, tr: xr[tr])(x, src_tok)
    gathered = gathered * keep[..., None].astype(dt)
    buf = jax.vmap(
        lambda g, sl: jnp.zeros((e * cap, d), dtype=dt).at[sl].set(g))(
        gathered, slot)
    buf = buf.reshape(b, e, cap, d)
    buf = shard(buf, "batch", "expert", None, None)

    # Grouped expert FFN (block-diagonal einsum; E sharded over model).
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dt))
    g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dt))
    h = act(g) * h
    y = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))
    y = shard(y, "batch", "expert", None, None)
    y = y.reshape(b, e * cap, d)

    # Scatter back with combine weights (vmapped: see note above).
    w_sorted = jnp.take_along_axis(w_flat, order, axis=1)
    contrib = jax.vmap(lambda yr, sl: yr[sl])(y, slot)
    contrib = contrib * (w_sorted * keep)[..., None].astype(dt)
    out = jax.vmap(
        lambda c, tk: jnp.zeros((s, d), dtype=dt).at[tk].add(c))(
        contrib, src_tok)
    out = shard(out, "batch", None, "embed")

    if cfg.n_shared_experts > 0:
        out = out + mlp_apply(cfg, p["shared"], x)
    return out


def aux_load_balance_loss(cfg: ModelConfig, x, p) -> jax.Array:
    """Switch-style load-balance auxiliary loss (fraction * probability)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, topi = jax.lax.top_k(probs, cfg.top_k)
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    prob = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * prob)
