"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Real-Gated Linear Recurrent Unit:   h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t)
with  a_t = exp(−c·softplus(Λ)·r_t),  r_t = σ(W_r x_t),  i_t = σ(W_i x_t).

Training uses ``jax.lax.associative_scan`` over (a_t, b_t) pairs — the
TPU-native parallel form (log-depth, no warp shuffles needed).  Decode is a
single fused step carrying (h, conv_state).  The full Griffin block is:
in-proj → [branch1: temporal conv(4) → RG-LRU] ⊙ gelu(branch2) → out-proj.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import ParamInfo, shard
from .config import ModelConfig
from .layers import adtype

_C = 8.0  # Griffin's fixed recurrence sharpness


def rglru_defs(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    cw = cfg.conv_width
    return {
        "w_in1": ParamInfo((d, w), cfg.param_dtype, (None, "lru"),
                           fsdp_dim=0),
        "w_in2": ParamInfo((d, w), cfg.param_dtype, (None, "lru"),
                           fsdp_dim=0),
        "conv": ParamInfo((cw, w), cfg.param_dtype, ("conv", "lru")),
        "w_i": ParamInfo((w, w), cfg.param_dtype, (None, "lru"), fsdp_dim=0),
        "w_r": ParamInfo((w, w), cfg.param_dtype, (None, "lru"), fsdp_dim=0),
        "lam": ParamInfo((w,), cfg.param_dtype, ("lru",), init_scale=0.65),
        "w_out": ParamInfo((w, d), cfg.param_dtype, ("lru", None),
                           fsdp_dim=1),
    }


def rglru_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    w, cw = cfg.lru_width or cfg.d_model, cfg.conv_width
    return {
        "h": ParamInfo((batch, w), cfg.dtype, ("batch", "lru")),
        "conv": ParamInfo((batch, cw - 1, w), cfg.dtype,
                          ("batch", None, "lru")),
    }


def _gates(cfg, p, u):
    dt = adtype(cfg)
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_r"].astype(dt))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_i"].astype(dt))
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * u.astype(jnp.float32)
    return a, b


def _conv_full(p, u, dt):
    """Causal temporal conv over [B,S,W] with kernel [CW,W]."""
    cw = p["conv"].shape[0]
    pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    k = p["conv"].astype(dt)
    out = sum(pad[:, i:i + u.shape[1], :] * k[i] for i in range(cw))
    return out


def rglru_apply(cfg: ModelConfig, p, x, *, cache: Optional[dict] = None):
    """x: [B,S,d] (train) or [B,1,d] (decode with cache)."""
    dt = adtype(cfg)
    u1 = jnp.einsum("bsd,dw->bsw", x, p["w_in1"].astype(dt))
    u2 = jnp.einsum("bsd,dw->bsw", x, p["w_in2"].astype(dt))
    u1 = shard(u1, "batch", None, "lru")

    if cache is None:
        u1c = _conv_full(p, u1, dt)
        a, b = _gates(cfg, p, u1c)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = h.astype(dt)
        new_cache = None
    else:
        # Decode: update conv state, one recurrence step.
        conv_st = cache["conv"]                       # [B, CW-1, W]
        window = jnp.concatenate([conv_st, u1], axis=1)  # [B, CW, W]
        k = p["conv"].astype(dt)
        u1c = jnp.einsum("bcw,cw->bw", window, k)[:, None, :]
        a, b = _gates(cfg, p, u1c)
        h_prev = cache["h"].astype(jnp.float32)
        h = (a[:, 0] * h_prev + b[:, 0]).astype(dt)[:, None, :]
        new_cache = {"h": h[:, 0], "conv": window[:, 1:], }

    y = h * jax.nn.gelu(u2)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(dt))
    return shard(out, "batch", None, "embed"), new_cache
