"""Composable transformer building blocks (functional, sharding-annotated).

Every block ships a ``*_defs(cfg)`` returning a ParamInfo tree and a
``*_apply(cfg, params, ...)`` pure function.  Attention supports GQA/MQA,
RoPE, causal + sliding-window masks, QKV bias, logit soft-capping, cross
attention, and single-token decode against a KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import ParamInfo, shard
from .config import ModelConfig


def adtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(cfg: ModelConfig) -> dict:
    return {"scale": ParamInfo((cfg.d_model,), cfg.param_dtype, ("embed",),
                               init_scale=0.0)}


def rmsnorm_apply(cfg: ModelConfig, p, x):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + cfg.norm_eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_in = cfg.cross_attn_dim if cross else d
    defs = {
        "wq": ParamInfo((d, h, hd), cfg.param_dtype, (None, "heads", None),
                        fsdp_dim=0),
        "wk": ParamInfo((kv_in, kv, hd), cfg.param_dtype,
                        (None, "kv_heads", None), fsdp_dim=0),
        "wv": ParamInfo((kv_in, kv, hd), cfg.param_dtype,
                        (None, "kv_heads", None), fsdp_dim=0),
        "wo": ParamInfo((h, hd, d), cfg.param_dtype, ("heads", None, None),
                        fsdp_dim=2),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamInfo((h, hd), cfg.param_dtype, ("heads", None),
                               init_scale=0.0)
        defs["bk"] = ParamInfo((kv, hd), cfg.param_dtype, ("kv_heads", None),
                               init_scale=0.0)
        defs["bv"] = ParamInfo((kv, hd), cfg.param_dtype, ("kv_heads", None),
                               init_scale=0.0)
    return defs


def _qkv(cfg: ModelConfig, p, x, kv_x):
    dt = adtype(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask: Optional[jax.Array]):
    """Grouped scaled-dot-product attention.

    q: [B,Sq,H,D]; k/v: [B,Skv,KV,D]; mask: broadcastable to [B,1,1,Sq,Skv].
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, sq, kvh, g, d)
    scale = 1.0 / np.sqrt(cfg.head_dim)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)


def _sdpa_blocked(cfg: ModelConfig, q, k, v, window: int,
                  q_block: int = 512, scale: float | None = None):
    """Flash-style blocked attention (XLA-level): scan over query blocks so
    the [Sq,Skv] logits never materialize — per-block peak is
    [B,KV,G,q_block,Skv].  Causal (+ sliding window) masking is computed per
    block from positions.  The Pallas kernel (kernels/flash_attention.py) is
    the TPU-tiled version of the same schedule."""
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    kvh = k.shape[2]
    g = h // kvh
    scale = scale or 1.0 / np.sqrt(cfg.head_dim)
    q_block = min(q_block, sq)
    nb = sq // q_block
    assert sq % q_block == 0, (sq, q_block)
    qb = q.reshape(b, nb, q_block, h, d).transpose(1, 0, 2, 3, 4)
    # Pin layouts across the scan so XLA does not re-shard k/v (or the qb
    # slices) on every q-block iteration — the in-loop all-to-alls dominate
    # the collective term otherwise (EXPERIMENTS.md §Perf, llama cell).
    qb = shard(qb, None, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    kpos = jnp.arange(k.shape[1])

    acc_dt = jnp.float32 if cfg.softmax_f32 else jnp.bfloat16

    def body(carry, inp):
        qi, blk = inp
        qi = qi.reshape(b, q_block, kvh, g, d)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qi, k).astype(
            acc_dt) * scale
        if cfg.logit_softcap > 0:
            c = cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        qpos = blk * q_block + jnp.arange(q_block)
        m = kpos[None, :] <= qpos[:, None]
        if window > 0:
            m = m & (qpos[:, None] - kpos[None, :] < window)
        logits = jnp.where(m[None, None, None], logits,
                           jnp.asarray(-3e4 if acc_dt == jnp.bfloat16
                                       else -1e30, acc_dt))
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        out = out.reshape(b, q_block, h, dv)
        return carry, shard(out, "batch", None, "heads", None)

    # Inner remat: without it the scan's backward saves per-block probs —
    # i.e. the full [Sq,Skv] logits across iterations, defeating the blocked
    # structure.  With it, backward recomputes each block from q,k,v (the
    # flash-backward schedule).
    _, outs = jax.lax.scan(jax.checkpoint(body), (), (qb, jnp.arange(nb)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


# Sequences at or above this length use the blocked attention path (tests
# monkeypatch this down to cover the blocked path on CPU-sized inputs).
BLOCKED_ATTN_THRESHOLD = 2048


def causal_mask(sq: int, skv: int, window: int = 0) -> jax.Array:
    """[1,1,1,Sq,Skv] boolean mask; window>0 => sliding window."""
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (qpos - kpos < window)
    return m[None, None, None]


def decode_mask(pos: jax.Array, skv: int, window: int = 0) -> jax.Array:
    """Mask for one-token decode at absolute position ``pos``.

    ``pos`` is a scalar (shared position clock) or a ``[B]`` vector
    (per-slot position clocks, continuous batching).  Returns
    ``[1,1,1,1,Skv]`` / ``[B,1,1,1,Skv]`` respectively.
    """
    if jnp.ndim(pos) == 0:
        kpos = jnp.arange(skv)[None, :]
        m = kpos <= pos
        if window > 0:
            m = m & (pos - kpos < window)
        return m[None, None, None]
    kpos = jnp.arange(skv)[None, :]
    p = pos[:, None]
    m = kpos <= p
    if window > 0:
        m = m & (p - kpos < window)
    return m[:, None, None, None, :]


def attention_apply(cfg: ModelConfig, p, x, *, positions, window: int = 0,
                    cache: Optional[dict] = None, kv_x=None):
    """Self/cross attention.

    Train (cache None): full-sequence causal (+window) attention.
    Decode (cache dict with k,v,[pos]): x is [B,1,D]; returns updated cache.
    Cross attention (kv_x set): no mask, no cache update of kv_x.
    """
    cross = kv_x is not None
    kv_src = kv_x if cross else x
    q, k, v = _qkv(cfg, p, x, kv_src)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if cache is None else positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)

    new_cache = None
    if cross:
        mask = None
    elif cache is None:
        if x.shape[1] >= BLOCKED_ATTN_THRESHOLD:
            out = _sdpa_blocked(cfg, q, k, v, window)
            dt_ = adtype(cfg)
            y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt_))
            return shard(y, "batch", "seq", "embed"), None
        mask = causal_mask(x.shape[1], x.shape[1], window)
    else:
        pos = cache["pos"]
        length = cache["k"].shape[1]
        per_slot = jnp.ndim(pos) > 0   # [B] position clocks (continuous
        #                                batching) vs one shared scalar
        if window > 0 and length <= window:
            # Ring buffer: slot j holds absolute position pos-((pos-j) mod L).
            slot = jnp.mod(pos, length)
            if per_slot:
                rows = jnp.arange(k.shape[0])
                k_all = cache["k"].at[rows, slot].set(k[:, 0])
                v_all = cache["v"].at[rows, slot].set(v[:, 0])
                abs_pos = pos[:, None] - jnp.mod(
                    pos[:, None] - jnp.arange(length)[None, :], length)
                mask = (abs_pos >= 0)[:, None, None, None, :]
            else:
                k_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k, slot, axis=1)
                v_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v, slot, axis=1)
                abs_pos = pos - jnp.mod(pos - jnp.arange(length), length)
                mask = (abs_pos >= 0)[None, None, None, None, :]
        else:
            if per_slot:
                rows = jnp.arange(k.shape[0])
                k_all = cache["k"].at[rows, pos].set(k[:, 0])
                v_all = cache["v"].at[rows, pos].set(v[:, 0])
            else:
                k_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k, pos, axis=1)
                v_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v, pos, axis=1)
            mask = decode_mask(pos, length, window)
        new_cache = {"k": k_all, "v": v_all, "pos": pos + 1}
        k, v = k_all, v_all

    out = _sdpa(cfg, q, k, v, mask)
    dt = adtype(cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    y = shard(y, "batch", None, "embed")
    return (y, new_cache) if cache is not None else (y, None)


def attn_cache_defs(cfg: ModelConfig, batch: int, max_len: int,
                    window: int = 0) -> dict:
    """KV-cache ParamInfo tree for one attention layer."""
    s = min(max_len, window) if window > 0 else max_len
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": ParamInfo((batch, s, kv, hd), cfg.dtype,
                       ("batch", "kv_seq", "kv_heads", None)),
        "v": ParamInfo((batch, s, kv, hd), cfg.dtype,
                       ("batch", "kv_seq", "kv_heads", None)),
    }


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "wi": ParamInfo((d, f), cfg.param_dtype, (None, "mlp"), fsdp_dim=0),
        "wg": ParamInfo((d, f), cfg.param_dtype, (None, "mlp"), fsdp_dim=0),
        "wo": ParamInfo((f, d), cfg.param_dtype, ("mlp", None), fsdp_dim=1),
    }


def mlp_apply(cfg: ModelConfig, p, x):
    dt = adtype(cfg)
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
    h = act(g) * h
    h = shard(h, "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    return shard(y, "batch", None, "embed")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> dict:
    defs = {"tokens": ParamInfo((cfg.vocab, cfg.d_model), cfg.param_dtype,
                                ("vocab", None), fsdp_dim=1,
                                init_scale=1.0)}
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamInfo((cfg.d_model, cfg.vocab),
                                    cfg.param_dtype, (None, "vocab"),
                                    fsdp_dim=0)
    return defs


def embed_apply(cfg: ModelConfig, p, tokens):
    dt = adtype(cfg)
    x = jnp.take(p["tokens"].astype(dt), tokens, axis=0)
    return shard(x, "batch", None, "embed")


def unembed_apply(cfg: ModelConfig, p, x):
    dt = adtype(cfg)
    w = (p["tokens"].astype(dt).T if cfg.tie_embeddings
         else p["unembed"].astype(dt))
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(logits, "batch", None, "vocab")
