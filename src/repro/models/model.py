"""Model assembly: scanned layer stacks, train forward + loss, decode step.

Layer stacks are ``lax.scan`` over stacked per-layer params — HLO size and
compile time are O(1) in depth (61-layer deepseek compiles like 1 layer).
Heterogeneous stacks (gemma3 5:1 local:global, recurrentgemma (rec,rec,attn),
llama-vision (4 self + 1 cross)) scan over *groups*: each scan step applies
the config's ``pattern`` of block kinds; remainder layers live in a scanned
``tail`` stack; deepseek's leading dense-FFN layers in a ``dense`` stack.

The paper's technique enters here: every block tags its intermediates with
``checkpoint_name`` and the scan body is wrapped in ``jax.checkpoint`` whose
policy comes from the DTR planner (cfg.remat = none|full|dtr).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.ad_checkpoint import checkpoint_name

from ..distributed.sharding import ParamInfo, shard, shape_structs
from .config import ModelConfig
from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import rglru as RG
from . import rwkv as RW


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def _block_defs(cfg: ModelConfig, kind: str, moe_layer: bool) -> dict:
    d = {"norm1": L.rmsnorm_defs(cfg), "norm2": L.rmsnorm_defs(cfg)}
    if kind in ("attn", "attn_local", "cross"):
        d["attn"] = MLA.mla_defs(cfg) if cfg.mla else L.attention_defs(cfg)
        if kind == "cross":
            d["norm_c"] = L.rmsnorm_defs(cfg)
            d["cross"] = L.attention_defs(cfg, cross=True)
        d["ffn"] = MOE.moe_defs(cfg) if moe_layer else L.mlp_defs(cfg)
    elif kind == "rglru":
        d["rec"] = RG.rglru_defs(cfg)
        d["ffn"] = L.mlp_defs(cfg)
    elif kind == "rwkv":
        d["mix"] = RW.rwkv_defs(cfg)
    else:
        raise ValueError(kind)
    return d


def _stack_info(info: ParamInfo, n: int) -> ParamInfo:
    return ParamInfo((n, *info.shape), info.dtype,
                     (None, *(info.axes or (None,) * len(info.shape))),
                     fsdp_dim=None if info.fsdp_dim is None
                     else info.fsdp_dim + 1,
                     init_scale=info.init_scale)


def _stack_tree(tree, n: int):
    return jax.tree.map(lambda i: _stack_info(i, n), tree,
                        is_leaf=lambda x: isinstance(x, ParamInfo))


def param_defs(cfg: ModelConfig) -> dict:
    defs: dict[str, Any] = {"embed": _embed_defs(cfg)}
    if cfg.n_dense_layers:
        dense = _block_defs(cfg, "attn", moe_layer=False)
        defs["dense"] = _stack_tree(dense, cfg.n_dense_layers)
    group = {f"slot{i}": _block_defs(cfg, kind, moe_layer=cfg.moe)
             for i, kind in enumerate(cfg.pattern)}
    defs["groups"] = _stack_tree(group, cfg.n_groups)
    if cfg.tail:
        tail = {f"slot{i}": _block_defs(cfg, kind, moe_layer=cfg.moe)
                for i, kind in enumerate(cfg.tail)}
        defs["tail"] = _stack_tree(tail, 1)
    defs["final_norm"] = L.rmsnorm_defs(cfg)
    return defs


def _embed_defs(cfg: ModelConfig) -> dict:
    if cfg.n_codebooks > 0:   # musicgen: K codebook tables + K output heads
        return {
            "tokens": ParamInfo((cfg.n_codebooks, cfg.vocab, cfg.d_model),
                                cfg.param_dtype, (None, "vocab", None),
                                fsdp_dim=2, init_scale=1.0),
            "unembed": ParamInfo((cfg.n_codebooks, cfg.d_model, cfg.vocab),
                                 cfg.param_dtype, (None, None, "vocab"),
                                 fsdp_dim=1),
        }
    return L.embed_defs(cfg)


def init_params(cfg: ModelConfig, key) -> Any:
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamInfo))
    keys = jax.random.split(key, len(leaves))

    def one(info: ParamInfo, k):
        if info.init_scale == 0.0:
            return jnp.zeros(info.shape, jnp.dtype(info.dtype))
        fan = info.shape[-1] if len(info.shape) else 1
        scale = info.init_scale if info.init_scale != 0.02 \
            else 1.0 / np.sqrt(max(fan, 1))
        return (jax.random.normal(k, info.shape) * scale).astype(
            jnp.dtype(info.dtype))

    return jax.tree.unflatten(treedef, [one(i, k) for i, k in
                                        zip(leaves, keys)])


def param_structs(cfg: ModelConfig):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return shape_structs(param_defs(cfg))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _ffn(cfg, p, x, moe_layer: bool):
    if moe_layer:
        return MOE.moe_apply(cfg, p, x)
    return L.mlp_apply(cfg, p, x)


def block_apply(cfg: ModelConfig, kind: str, p, x, *, positions,
                moe_layer: bool, cache: Optional[dict] = None,
                img_kv=None):
    """Pre-norm residual block; returns (x, new_cache)."""
    new_cache: dict = {}
    if kind in ("attn", "attn_local", "cross"):
        h = L.rmsnorm_apply(cfg, p["norm1"], x)
        window = cfg.window if kind == "attn_local" else 0
        attn_cache = None if cache is None else cache.get("attn")
        if cfg.mla:
            a, c2 = MLA.mla_apply(cfg, p["attn"], h, positions=positions,
                                  cache=attn_cache)
        else:
            a, c2 = L.attention_apply(cfg, p["attn"], h, positions=positions,
                                      window=window, cache=attn_cache)
        if c2 is not None:
            new_cache["attn"] = c2
        x = x + checkpoint_name(a, "attn_out")
        if kind == "cross":
            hc = L.rmsnorm_apply(cfg, p["norm_c"], x)
            ca, _ = L.attention_apply(cfg, p["cross"], hc,
                                      positions=positions, kv_x=img_kv)
            x = x + checkpoint_name(ca, "cross_out")
        h2 = L.rmsnorm_apply(cfg, p["norm2"], x)
        f = _ffn(cfg, p["ffn"], h2, moe_layer)
        x = x + checkpoint_name(f, "ffn_out")
    elif kind == "rglru":
        h = L.rmsnorm_apply(cfg, p["norm1"], x)
        rec_cache = None if cache is None else cache.get("rec")
        r, c2 = RG.rglru_apply(cfg, p["rec"], h, cache=rec_cache)
        if c2 is not None:
            new_cache["rec"] = c2
        x = x + checkpoint_name(r, "rec_out")
        h2 = L.rmsnorm_apply(cfg, p["norm2"], x)
        x = x + checkpoint_name(L.mlp_apply(cfg, p["ffn"], h2), "ffn_out")
    elif kind == "rwkv":
        h = L.rmsnorm_apply(cfg, p["norm1"], x)
        mix_cache = None if cache is None else cache.get("mix")
        t, c2 = RW.rwkv_time_mix(cfg, p["mix"], h, cache=mix_cache)
        x = x + checkpoint_name(t, "attn_out")
        h2 = L.rmsnorm_apply(cfg, p["norm2"], x)
        f, c3 = RW.rwkv_channel_mix(cfg, p["mix"], h2, cache=mix_cache)
        x = x + checkpoint_name(f, "ffn_out")
        if c2 is not None:
            new_cache["mix"] = {**c2, **(c3 or {})}
    else:
        raise ValueError(kind)
    x = shard(x, "batch", "seq", "embed")
    return x, (new_cache or None)


# ---------------------------------------------------------------------------
# Remat policy (the paper's technique, applied to the scan body)
# ---------------------------------------------------------------------------

def remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if cfg.remat == "dtr":
        # Planned offline via core.planner.plan_model_policy; default saves
        # block outputs only (the residual-stream checkpoints DTR keeps on
        # homogeneous stacks — see EXPERIMENTS.md §Perf for planned variants).
        return jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ffn_out")
    if cfg.remat.startswith("names:"):
        names = [n for n in cfg.remat[6:].split(",") if n]
        return jax.checkpoint_policies.save_only_these_names(*names)
    raise ValueError(cfg.remat)


def _maybe_remat(cfg: ModelConfig, fn):
    pol = remat_policy(cfg)
    if pol is None:
        return fn
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Forward (train)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _opt_barrier(tree):
    """Differentiable ``optimization_barrier`` (older jax has no AD rule).

    The barrier is identity; cotangents pass through their own barrier so the
    backward pass keeps the same hoisting protection as the forward.
    """
    return jax.lax.optimization_barrier(tree)


def _opt_barrier_fwd(tree):
    return jax.lax.optimization_barrier(tree), None


def _opt_barrier_bwd(_, ct):
    return (jax.lax.optimization_barrier(ct),)


_opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


def _embed(cfg: ModelConfig, p, tokens):
    dt = L.adtype(cfg)
    if cfg.n_codebooks > 0:
        # tokens: [B,S,K]
        tabs = p["tokens"].astype(dt)
        x = sum(jnp.take(tabs[i], tokens[..., i], axis=0)
                for i in range(cfg.n_codebooks))
    else:
        x = jnp.take(p["tokens"].astype(dt), tokens, axis=0)
    if cfg.name.startswith(("gemma", "recurrentgemma")):
        x = x * np.sqrt(cfg.d_model).astype(dt)
    return shard(x, "batch", "seq", "embed")


def _unembed(cfg: ModelConfig, p, x):
    dt = L.adtype(cfg)
    if cfg.n_codebooks > 0:
        logits = jnp.einsum("bsd,kdv->bskv", x, p["unembed"].astype(dt))
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tokens"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(dt))
    return shard(logits, "batch", None, "vocab")


def forward(cfg: ModelConfig, params, tokens, img_embed=None):
    """Full-sequence forward -> logits.

    tokens: [B,S] int32 (or [B,S,K] for codebook models).
    img_embed: [B,N,cross_dim] for VLM backbones (stub frontend output).
    """
    x = _embed(cfg, params["embed"], tokens)
    s = x.shape[1]
    positions = jnp.arange(s)
    img_kv = img_embed.astype(L.adtype(cfg)) if img_embed is not None else None

    def group_body(kinds, moe_on):
        def body(carry, slot_params):
            # Barrier: keep the per-layer FSDP all-gather INSIDE the scan
            # body — without it XLA commutes gather/slice and hoists the
            # full gathered param stack out of the loop (81 GiB resident
            # for deepseek-v3; EXPERIMENTS.md §Perf cell B).
            slot_params = _opt_barrier(slot_params)
            h = carry
            for i, kind in enumerate(kinds):
                h, _ = block_apply(cfg, kind, slot_params[f"slot{i}"], h,
                                   positions=positions, moe_layer=moe_on,
                                   img_kv=img_kv)
            return h, None
        return body

    if cfg.n_dense_layers:
        def dense_body(carry, lp):
            lp = _opt_barrier(lp)
            h, _ = block_apply(cfg, "attn", lp, carry, positions=positions,
                               moe_layer=False)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(cfg, dense_body), x,
                            params["dense"])

    body = _maybe_remat(cfg, group_body(cfg.pattern, cfg.moe))
    x, _ = jax.lax.scan(body, x, params["groups"])

    if cfg.tail:
        tbody = _maybe_remat(cfg, group_body(cfg.tail, cfg.moe))
        x, _ = jax.lax.scan(tbody, x, params["tail"])

    x = L.rmsnorm_apply(cfg, params["final_norm"], x)
    return _unembed(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params, batch):
    """Next-token cross entropy (fp32 logits for the softmax)."""
    tokens = batch["tokens"]
    logits = forward(cfg, params, tokens, batch.get("img_embed"))
    logits = logits.astype(jnp.float32)
    if cfg.n_codebooks > 0:
        inp, tgt = logits[:, :-1], tokens[:, 1:]
        logp = jax.nn.log_softmax(inp, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
        return jnp.mean(nll)
    inp, tgt = logits[:, :-1], tokens[:, 1:]
    logp = jax.nn.log_softmax(inp, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Decode (serve)
# ---------------------------------------------------------------------------

def _block_cache_defs(cfg: ModelConfig, kind: str, batch: int,
                      max_len: int) -> dict:
    if kind in ("attn", "attn_local", "cross"):
        window = cfg.window if kind == "attn_local" else 0
        if cfg.mla:
            return {"attn": MLA.mla_cache_defs(cfg, batch, max_len)}
        return {"attn": L.attn_cache_defs(cfg, batch, max_len, window)}
    if kind == "rglru":
        return {"rec": RG.rglru_cache_defs(cfg, batch)}
    if kind == "rwkv":
        return {"mix": RW.rwkv_cache_defs(cfg, batch)}
    raise ValueError(kind)


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    defs: dict[str, Any] = {}
    if cfg.n_dense_layers:
        defs["dense"] = _stack_tree(
            _block_cache_defs(cfg, "attn", batch, max_len),
            cfg.n_dense_layers)
    group = {f"slot{i}": _block_cache_defs(cfg, kind, batch, max_len)
             for i, kind in enumerate(cfg.pattern)}
    defs["groups"] = _stack_tree(group, cfg.n_groups)
    if cfg.tail:
        tail = {f"slot{i}": _block_cache_defs(cfg, kind, batch, max_len)
                for i, kind in enumerate(cfg.tail)}
        defs["tail"] = _stack_tree(tail, 1)
    return defs


def cache_structs(cfg: ModelConfig, batch: int, max_len: int):
    return shape_structs(cache_defs(cfg, batch, max_len))


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(
        lambda i: jnp.zeros(i.shape, jnp.dtype(i.dtype)),
        cache_defs(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, ParamInfo))


def decode_step(cfg: ModelConfig, params, token, cache, pos, img_embed=None):
    """One-token decode: token [B,1] (or [B,1,K]) at absolute position pos.

    Returns (logits, new_cache).  ``pos`` is a traced int32 scalar (one
    shared position clock) or a ``[B]`` vector of per-slot clocks —
    continuous batching, where each slot's request sits at its own
    position.  Caches are stacked per scan group and updated functionally.
    """
    x = _embed(cfg, params["embed"], token)
    # Rope wants positions broadcastable to [..., S] with S=1 here:
    # scalar -> [1]; per-slot [B] -> [B, 1].
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    img_kv = img_embed.astype(L.adtype(cfg)) if img_embed is not None else None

    def inject(c):
        return {**c, "pos": pos} if "k" in c or "ckv" in c else c

    def group_scan(kinds, pstack, cstack, moe_on):
        def body(carry, inp):
            h = carry
            slot_params, slot_cache = inp
            new_slots = {}
            for i, kind in enumerate(kinds):
                blk_cache = {k2: inject(v2) if isinstance(v2, dict) else v2
                             for k2, v2 in slot_cache[f"slot{i}"].items()}
                h, nc = block_apply(cfg, kind, slot_params[f"slot{i}"], h,
                                    positions=positions, moe_layer=moe_on,
                                    cache=blk_cache, img_kv=img_kv)
                nc = nc or {}
                # Drop the scalar 'pos' from carried cache state.
                nc = {k2: ({kk: vv for kk, vv in v2.items() if kk != "pos"}
                           if isinstance(v2, dict) else v2)
                      for k2, v2 in nc.items()}
                new_slots[f"slot{i}"] = nc
            return h, new_slots
        return body

    new_cache: dict[str, Any] = {}
    if cfg.n_dense_layers:
        def dense_body(carry, inp):
            lp, lc = inp
            blk_cache = {k2: inject(v2) for k2, v2 in lc.items()}
            h, nc = block_apply(cfg, "attn", lp, carry, positions=positions,
                                moe_layer=False, cache=blk_cache)
            nc = {k2: {kk: vv for kk, vv in v2.items() if kk != "pos"}
                  for k2, v2 in (nc or {}).items()}
            return h, nc
        x, new_cache["dense"] = jax.lax.scan(
            dense_body, x, (params["dense"], cache["dense"]))

    body = group_scan(cfg.pattern, params["groups"], cache["groups"], cfg.moe)
    x, new_cache["groups"] = jax.lax.scan(
        body, x, (params["groups"], cache["groups"]))

    if cfg.tail:
        tbody = group_scan(cfg.tail, params["tail"], cache["tail"], cfg.moe)
        x, new_cache["tail"] = jax.lax.scan(
            tbody, x, (params["tail"], cache["tail"]))

    x = L.rmsnorm_apply(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params["embed"], x)
    return logits, new_cache
