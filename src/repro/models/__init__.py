"""Model zoo: composable blocks + scanned stacks for all assigned archs."""
from .config import ModelConfig
from .model import (
    cache_structs, decode_step, forward, init_cache, init_params, loss_fn,
    param_defs, param_structs,
)

__all__ = [
    "ModelConfig", "cache_structs", "decode_step", "forward", "init_cache",
    "init_params", "loss_fn", "param_defs", "param_structs",
]
