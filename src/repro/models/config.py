"""Unified model configuration covering all ten assigned architectures.

One dataclass parameterizes the whole zoo: dense GQA transformers, local/
global mixed attention (gemma3), sliding-window (mixtral), QKV-bias (qwen2),
cross-attention VLM backbones (llama-3.2-vision), audio-codebook decoders
(musicgen), MoE (mixtral / deepseek-v3 with MLA), RG-LRU hybrids
(recurrentgemma) and RWKV6.  Per-layer heterogeneity is expressed through a
*pattern*: the layer stack is a scanned sequence of groups, each group being a
fixed tuple of block kinds (see models/model.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

BlockKind = Literal[
    "attn",        # self-attention block (global or windowed via window)
    "attn_local",  # self-attention with sliding window
    "cross",       # self-attn + cross-attn (VLM layers)
    "rglru",       # Griffin recurrent block
    "rwkv",        # RWKV6 time-mix + channel-mix
]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                    # 0 => d_model // n_heads

    # Attention structure
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0                      # sliding window for attn_local (0=global)
    logit_softcap: float = 0.0           # gemma-style attn logit soft-capping

    # Layer pattern: scanned groups + unrolled tail.
    # pattern: tuple of BlockKind applied per scan step; n_groups * len(pattern)
    # + len(tail) must equal n_layers.
    pattern: tuple[str, ...] = ("attn",)
    tail: tuple[str, ...] = ()

    # MLP
    mlp_act: str = "silu"                # silu|gelu (gated)

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                    # expert hidden dim (deepseek: 2048)
    n_dense_layers: int = 0              # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25

    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # RG-LRU (recurrentgemma / griffin)
    lru_width: int = 0
    conv_width: int = 4

    # RWKV6
    rwkv_head_dim: int = 64

    # Modality frontends (stubs: precomputed embeddings per the assignment)
    n_codebooks: int = 0                 # musicgen: 4
    cross_attn_tokens: int = 0           # vlm: number of vision tokens
    cross_attn_dim: int = 0              # vlm: vision embedding dim

    # Numerics / training
    softmax_f32: bool = True        # f32 attention logits (bf16 = perf knob)
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # Remat (the paper's technique): policy selected by the DTR planner.
    remat: str = "none"                  # none|dtr|full|names
    remat_budget_frac: float = 0.5       # fraction of per-device HBM for acts

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        n_pattern = len(self.pattern)
        body = self.n_layers - len(self.tail) - self.n_dense_layers
        assert body % n_pattern == 0, (
            f"{self.name}: {body} body layers not divisible by pattern "
            f"{self.pattern}")

    @property
    def n_groups(self) -> int:
        return (self.n_layers - len(self.tail) - self.n_dense_layers) \
            // len(self.pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS) ---------------------
    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        kinds: list[str] = []
        kinds += list(self.pattern) * self.n_groups
        kinds += list(self.tail)
        kinds = ["attn"] * self.n_dense_layers + kinds

        for kind in kinds:
            total += 2 * d  # norms
            if kind in ("attn", "attn_local", "cross"):
                if self.mla:
                    qk_head = self.qk_nope_dim + self.qk_rope_dim
                    total += d * self.q_lora_rank
                    total += self.q_lora_rank * h * qk_head
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * h * (
                        self.qk_nope_dim + self.v_head_dim)
                    total += h * self.v_head_dim * d
                else:
                    total += d * h * hd + 2 * d * kv * hd + h * hd * d
                if kind == "cross":
                    total += (d * h * hd + 2 * self.cross_attn_dim * kv * hd
                              + h * hd * d + d)
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * self.conv_width + 3 * w + w * d
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,out
                total += 6 * d * 64         # lora mixers (approx)
                total += 2 * d * f // 2     # channel mix (r,k,v)
            # FFN
            if kind in ("attn", "attn_local", "cross"):
                is_moe_layer = self.moe
                if is_moe_layer:
                    total += d * self.n_experts  # router
                    total += self.n_experts * 3 * d * self.moe_d_ff
                    total += self.n_shared_experts * 3 * d * self.moe_d_ff
                else:
                    total += 3 * d * f
        # deepseek: leading dense layers use d_ff, already counted via moe
        # approximation; close enough for roofline purposes.
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_layers = self.n_layers - self.n_dense_layers
        all_expert = moe_layers * self.n_experts * 3 * d * self.moe_d_ff
        active_expert = moe_layers * self.top_k * 3 * d * self.moe_d_ff
        return int(full - all_expert + active_expert)
