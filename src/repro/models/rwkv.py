"""RWKV6 ("Finch") — attention-free time-mix with data-dependent decay.

TPU-native adaptation: the GPU reference uses a custom CUDA scan; here the
recurrence is computed in *chunked* form — within-chunk interactions as an
MXU-friendly masked matmul, cross-chunk state carried by ``lax.scan`` — the
same reformulation used for linear attention on TPU.  Decode is a single
state-update step.

Faithful pieces: per-channel data-dependent decay w_t = exp(−exp(w0 + LoRA(x)))
(Finch's core novelty), bonus ``u`` term, token-shift mixing, silu output
gate, grouped head norm, squared-ReLU channel-mix.  Simplification recorded
in DESIGN.md: token-shift mixing coefficients are learned-static (μ) rather
than the paper's data-dependent ddlerp — the recurrence itself keeps full
data dependence.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import ParamInfo, shard
from .config import ModelConfig
from .layers import adtype

_LORA = 64
_CHUNK = 16


def rwkv_defs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    pd = cfg.param_dtype
    return {
        # time-mix
        "mu": ParamInfo((5, d), pd, (None, None), init_scale=0.5),
        "w0": ParamInfo((d,), pd, (None,), init_scale=-0.6),
        "wA": ParamInfo((d, _LORA), pd, (None, None)),
        "wB": ParamInfo((_LORA, d), pd, (None, None)),
        "u": ParamInfo((h, dh), pd, ("heads", None), init_scale=0.3),
        "wr": ParamInfo((d, d), pd, (None, "heads"), fsdp_dim=0),
        "wk": ParamInfo((d, d), pd, (None, "heads"), fsdp_dim=0),
        "wv": ParamInfo((d, d), pd, (None, "heads"), fsdp_dim=0),
        "wg": ParamInfo((d, d), pd, (None, "heads"), fsdp_dim=0),
        "wout": ParamInfo((d, d), pd, ("heads", None), fsdp_dim=1),
        "ln_x": ParamInfo((d,), pd, (None,), init_scale=0.0),
        # channel-mix
        "mu_c": ParamInfo((2, d), pd, (None, None), init_scale=0.5),
        "wr_c": ParamInfo((d, d), pd, (None, None), fsdp_dim=0),
        "wk_c": ParamInfo((d, f), pd, (None, "mlp"), fsdp_dim=0),
        "wv_c": ParamInfo((f, d), pd, ("mlp", None), fsdp_dim=1),
    }


def rwkv_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    h, dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {
        "state": ParamInfo((batch, h, dh, dh), "float32",
                           ("batch", "heads", None, None)),
        "x_att": ParamInfo((batch, d), cfg.dtype, ("batch", None)),
        "x_ffn": ParamInfo((batch, d), cfg.dtype, ("batch", None)),
    }


def _shift(x, prev=None):
    """x_{t-1} along seq; ``prev`` fills t=0 (decode carries it)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _chunked_wkv(r, k, v, logw, u):
    """Chunked linear-attention recurrence with per-channel decay.

    r,k,v: [B,S,H,D]; logw: [B,S,H,D] (log decay, <=0); u: [H,D].
    Returns out [B,S,H,D].
    """
    b, s, h, d = r.shape
    c = _CHUNK
    assert s % c == 0, f"seq {s} not divisible by chunk {c}"
    n = s // c
    rc = r.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)   # [N,B,H,C,D]
    kc = k.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)
    lwc = logw.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4).astype(
        jnp.float32)

    tri_lower = jnp.tril(jnp.ones((c, c), bool), k=-1)        # τ < t

    def body(state, inp):
        rcu, kcu, vcu, lw = inp                               # [B,H,C,D]
        rcu = rcu.astype(jnp.float32)
        kcu = kcu.astype(jnp.float32)
        vcu = vcu.astype(jnp.float32)
        cum = jnp.cumsum(lw, axis=2)                          # logW_t
        cum_prev = cum - lw                                   # logW_{t-1}
        # inter-chunk: (r_t * W_{t-1}) @ S0
        inter = jnp.einsum("bhtd,bhde->bhte", rcu * jnp.exp(cum_prev), state)
        # intra-chunk: A[t,τ] = Σ_d r_t k_τ exp(logW_{t-1} - logW_τ), τ<t
        diff = cum_prev[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,H,t,τ,D]
        diff = jnp.where(tri_lower[None, None, :, :, None], diff, -1e30)
        att = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rcu, kcu, jnp.exp(diff))
        # diagonal bonus: r_t·(u ⊙ k_t) v_t
        diag = jnp.einsum("bhtd,hd,bhtd->bht", rcu, u.astype(jnp.float32),
                          kcu)
        intra = jnp.einsum("bhts,bhse->bhte", att, vcu) \
            + diag[..., None] * vcu
        # state update: S1 = W_C ⊙ S0 + Σ_τ (W_C/W_τ ⊙ k_τ) v_τ^T
        wtot = cum[:, :, -1:, :]                              # logW_C
        kdec = kcu * jnp.exp(wtot - cum)
        new_state = state * jnp.exp(wtot.squeeze(2))[..., None] \
            + jnp.einsum("bhsd,bhse->bhde", kdec, vcu)
        return new_state, inter + intra

    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    _, outs = jax.lax.scan(body, s0, (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return out


def _head_norm(cfg, p, x):
    """Per-head RMS norm with learned scale (GroupNorm analogue)."""
    b, s, h, d = x.shape
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(
        jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + 1e-5)
    y = y.reshape(b, s, h * d) * (1.0 + p["ln_x"].astype(jnp.float32))
    return y


def rwkv_time_mix(cfg: ModelConfig, p, x, *, cache: Optional[dict] = None):
    dt = adtype(cfg)
    b, s, d = x.shape
    h, dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xs = _shift(x, None if cache is None else cache["x_att"])
    mu = p["mu"].astype(dt)
    xr, xk, xv, xw, xg = (_mix(x, xs, mu[i]) for i in range(5))

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt)).reshape(b, s, h, dh)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt)).reshape(b, s, h, dh)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt)))
    # Data-dependent decay (Finch): w = exp(-exp(w0 + tanh(x A) B)).
    lora = jnp.einsum("bsl,ld->bsd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl", xw,
                                          p["wA"].astype(dt))),
                      p["wB"].astype(dt))
    logw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32)
                             + lora.astype(jnp.float32), -8.0, 4.0))
    logw = logw.reshape(b, s, h, dh)

    if cache is None:
        out = _chunked_wkv(r, k, v, logw, p["u"])
        new_cache = None
    else:
        state = cache["state"]                                 # [B,H,D,D]
        r1 = r[:, 0].astype(jnp.float32)
        k1 = k[:, 0].astype(jnp.float32)
        v1 = v[:, 0].astype(jnp.float32)
        w1 = jnp.exp(logw[:, 0])
        u = p["u"].astype(jnp.float32)
        kv = jnp.einsum("bhd,bhe->bhde", k1, v1)
        out = jnp.einsum("bhd,bhde->bhe", r1, state + u[None, :, :, None] * kv)
        out = out[:, None].reshape(b, 1, h, dh)
        state = state * w1[..., None] + kv
        new_cache = {"state": state, "x_att": x[:, -1]}

    y = _head_norm(cfg, p, out).astype(dt) * g
    y = jnp.einsum("bsd,de->bse", y, p["wout"].astype(dt))
    return shard(y, "batch", None, "embed"), new_cache


def rwkv_channel_mix(cfg: ModelConfig, p, x, *,
                     cache: Optional[dict] = None):
    dt = adtype(cfg)
    xs = _shift(x, None if cache is None else cache["x_ffn"])
    mu = p["mu_c"].astype(dt)
    xk, xr = _mix(x, xs, mu[0]), _mix(x, xs, mu[1])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr_c"].astype(dt)))
    k = jnp.einsum("bsd,df->bsf", xk, p["wk_c"].astype(dt))
    k = jnp.square(jax.nn.relu(k))
    k = shard(k, "batch", None, "mlp")
    y = r * jnp.einsum("bsf,fd->bsd", k, p["wv_c"].astype(dt))
    new_cache = None if cache is None else {"x_ffn": x[:, -1]}
    return shard(y, "batch", None, "embed"), new_cache
