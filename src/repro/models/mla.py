"""Multi-head Latent Attention (DeepSeek-V3).

Queries and KV are low-rank compressed; only the KV latent (kv_lora_rank) and
the shared RoPE key (qk_rope_dim) are cached at decode — MLA's memory win.
Train path expands latents to full heads; decode path uses the *absorbed*
formulation (scores computed in latent space), which is the
compute-efficient TPU form.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import ParamInfo, shard
from .config import ModelConfig
from . import layers as _L
from .layers import (_sdpa_blocked, adtype, causal_mask, decode_mask, rope)


def mla_defs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rop, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": ParamInfo((d, ql), cfg.param_dtype, (None, None),
                          fsdp_dim=0),
        "q_norm": ParamInfo((ql,), cfg.param_dtype, (None,), init_scale=0.0),
        "wq_b": ParamInfo((ql, h, nope + rop), cfg.param_dtype,
                          (None, "heads", None), fsdp_dim=0),
        "wkv_a": ParamInfo((d, kl + rop), cfg.param_dtype, (None, None),
                           fsdp_dim=0),
        "kv_norm": ParamInfo((kl,), cfg.param_dtype, (None,),
                             init_scale=0.0),
        "wk_b": ParamInfo((kl, h, nope), cfg.param_dtype,
                          (None, "heads", None), fsdp_dim=0),
        "wv_b": ParamInfo((kl, h, vd), cfg.param_dtype,
                          (None, "heads", None), fsdp_dim=0),
        "wo": ParamInfo((h, vd, d), cfg.param_dtype,
                        ("heads", None, None), fsdp_dim=2),
    }


def _rms(x, scale, eps):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def mla_cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return {
        "ckv": ParamInfo((batch, max_len, cfg.kv_lora_rank), cfg.dtype,
                         ("batch", "kv_seq", None)),
        "krope": ParamInfo((batch, max_len, cfg.qk_rope_dim), cfg.dtype,
                           ("batch", "kv_seq", None)),
    }


def mla_apply(cfg: ModelConfig, p, x, *, positions,
              cache: Optional[dict] = None):
    dt = adtype(cfg)
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rop, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / np.sqrt(nope + rop)

    # --- queries ---
    cq = _rms(jnp.einsum("bsd,dq->bsq", x, p["wq_a"].astype(dt)),
              p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", cq, p["wq_b"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    q_nope = shard(q_nope, "batch", None, "heads", None)

    # --- KV latent ---
    kv_a = jnp.einsum("bsd,dk->bsk", x, p["wkv_a"].astype(dt))
    ckv, k_rope_new = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    ckv = _rms(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope_new = rope(k_rope_new[:, :, None, :], positions,
                      cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        # Train: expand latents to per-head keys/values.
        k_nope = jnp.einsum("bsk,khn->bshn", ckv, p["wk_b"].astype(dt))
        v = jnp.einsum("bsk,khv->bshv", ckv, p["wv_b"].astype(dt))
        k_nope = shard(k_nope, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
        if s >= _L.BLOCKED_ATTN_THRESHOLD:
            # Flash-style blocked path (MHA layout: kv heads == heads);
            # RoPE halves concatenated into a single qk vector — the full
            # [S,S] logits never materialize.
            q_full = jnp.concatenate(
                [q_nope, q_rope], axis=-1)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(
                    k_rope_new[:, :, None, :],
                    (*k_nope.shape[:3], rop))], axis=-1)
            out = _sdpa_blocked(cfg, q_full, k_full, v, window=0,
                                scale=scale)
        else:
            logits = (jnp.einsum("bqhn,bshn->bhqs", q_nope, k_nope)
                      + jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope_new))
            logits = logits.astype(jnp.float32) * scale
            mask = causal_mask(s, s)[:, 0]  # [1,1,S,S]
            logits = jnp.where(mask, logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1).astype(dt)
            out = jnp.einsum("bhqs,bshv->bqhv", probs, v)
        new_cache = None
    else:
        # Decode (absorbed): score/aggregate directly in latent space.
        pos = cache["pos"]
        if jnp.ndim(pos) > 0:
            # Per-slot position clocks (continuous batching).
            rows = jnp.arange(ckv.shape[0])
            ckv_all = cache["ckv"].at[rows, pos].set(ckv[:, 0])
            kr_all = cache["krope"].at[rows, pos].set(k_rope_new[:, 0])
        else:
            ckv_all = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv, pos, axis=1)
            kr_all = jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope_new, pos, axis=1)
        new_cache = {"ckv": ckv_all, "krope": kr_all, "pos": pos + 1}
        # absorb: q_lat[b,q,h,kl] = q_nope . wk_b^T
        q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope, p["wk_b"].astype(dt))
        logits = (jnp.einsum("bqhk,bsk->bhqs", q_lat, ckv_all)
                  + jnp.einsum("bqhr,bsr->bhqs", q_rope, kr_all))
        logits = logits.astype(jnp.float32) * scale
        mask = decode_mask(pos, ckv_all.shape[1])[:, 0]
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhqs,bsk->bqhk", probs, ckv_all)
        out = jnp.einsum("bqhk,khv->bqhv", o_lat, p["wv_b"].astype(dt))

    y = jnp.einsum("bqhv,hvd->bqd", out, p["wo"].astype(dt))
    y = shard(y, "batch", None, "embed")
    return y, new_cache
