"""``python -m repro.check``: run the correctness passes from CI.

Modes (both run with repo defaults when no flag is given):

* ``--lint PATH...`` — AST-lint every ``.py`` file under the paths
  (default ``src benchmarks``); exit 1 on any unsuppressed finding.
* ``--traces DIR`` — statically verify every ``*.log`` golden trace in
  ``DIR`` (default ``tests/traces``) and replay each one through a
  sanitized runtime (``sanitize=True``) over a small heuristic × budget
  grid, including one offload-enabled cell; exit 1 on any lint error or
  :class:`~repro.check.sanitizer.SanitizerViolation`.  OOM/thrash
  results are acceptable outcomes (pressure is the point), violations
  are not.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .lint import lint_paths
from .sanitizer import SanitizerViolation
from .trace_lint import lint_log

#: replay grid for --traces: small but exercises the exact and the
#: equivalence-class heuristics under real pressure.
TRACE_HEURISTICS = ("h_dtr", "h_dtr_eq")
#: train traces thrash below ~0.8 activation (see tests/test_trace_golden);
#: pressure without guaranteed-thrash keeps the gate fast.
TRAIN_FRACTIONS = (0.9, 0.8)
DEFAULT_FRACTIONS = (0.8, 0.5)
THRASH_FACTOR = 3.0
#: full-audit cadence for the corpus replays: transition hooks cover every
#: event regardless; a full O(storages) sweep every 16 ops keeps the CI
#: step a few seconds while still auditing hundreds of snapshots per run.
AUDIT_EVERY = 16


def run_lint(paths: list[str]) -> int:
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    n = len(findings)
    print(f"repro.check --lint: {n} finding(s) in {' '.join(paths)}")
    return 1 if findings else 0


def run_traces(trace_dir: str) -> int:
    # Imports deferred: --lint must not require a working runtime.
    from ..core.graph import Log
    from ..core.simulator import measure_baseline, resolve_budget
    from ..offload import OffloadConfig
    from ..trace.replay import run_trace

    logs = sorted(Path(trace_dir).glob("*.log"))
    if not logs:
        print(f"repro.check --traces: no *.log files in {trace_dir}")
        return 1
    failures = 0
    cells = 0
    for path in logs:
        log = Log.loads(path.read_text())
        issues = lint_log(log)
        errors = [i for i in issues if i.severity == "error"]
        for i in errors:
            print(f"{path.name}: {i}")
        if errors:
            failures += 1
            continue
        peak, _ = measure_baseline(log)
        pinned = log.pinned_bytes()
        fractions = (TRAIN_FRACTIONS if "train" in log.name
                     else DEFAULT_FRACTIONS)
        grid = [(h, f, None) for h in TRACE_HEURISTICS for f in fractions]
        # One offload-enabled cell per trace exercises the host-tier and
        # byte-conservation checks under prefetch traffic.
        grid.append(("h_dtr", fractions[-1],
                     OffloadConfig(host_budget=0.5 * peak,
                                   h2d_bandwidth=peak, d2h_bandwidth=peak)))
        for h, f, off in grid:
            cells += 1
            budget = resolve_budget(f, peak, pinned, "activation")
            tag = f"{path.name} {h}@{f}" + (" +offload" if off else "")
            try:
                res, _ = run_trace(log, h, budget,
                                   thrash_factor=THRASH_FACTOR, offload=off,
                                   sanitize=AUDIT_EVERY)
            except SanitizerViolation as e:
                failures += 1
                print(f"  {tag}: SANITIZER VIOLATION\n{e}")
                continue
            print(f"  {tag}: {'ok' if res.ok else res.error_kind}")
    print(f"repro.check --traces: {len(logs)} trace(s), {cells} sanitized "
          f"replay cell(s), {failures} failure(s)")
    return 1 if failures else 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="static trace verifier + sanitized replay + repo lint")
    ap.add_argument("--lint", nargs="+", metavar="PATH",
                    help="AST-lint these files/directories")
    ap.add_argument("--traces", metavar="DIR",
                    help="verify + sanitized-replay every *.log in DIR")
    args = ap.parse_args(argv)
    rc = 0
    ran = False
    if args.lint:
        ran = True
        rc |= run_lint(args.lint)
    if args.traces:
        ran = True
        rc |= run_traces(args.traces)
    if not ran:
        rc = run_lint(["src", "benchmarks"])
        rc |= run_traces("tests/traces")
    return rc


if __name__ == "__main__":
    sys.exit(main())
