"""Static trace verifier: liveness + remat-closure + alias/pin analysis.

One linear pass over a ``core.graph.Log`` mirrors the refcounting state
``graph.replay`` would drive through the runtime — *without* running any
replay — and reports structural defects before they can corrupt a run:

* malformed CALL metadata blocks (via the same ``parse_call_block`` the
  replayer uses, so the two consumers cannot drift);
* uses of tensors that were never defined, or whose external refcount
  already hit zero (``use-after-release``; under the ``banish`` policy a
  refcount-zero storage is eventually *permanently* freed, so the same
  defect is reported as ``use-after-banish``);
* release-underflow / double release;
* alias outputs carrying nonzero MEMORY sizes, aliases of released
  storages, MUTATE targets that are not inputs;
* non-finite / negative op costs and negative sizes (a NaN cost would
  poison the simulated clock and every heuristic score downstream);
* unreachable recompute paths: under ``banish``, a live tensor whose
  remat closure crosses a banished storage without an intervening pinned
  ancestor can never be rematerialized once evicted.

Anything the replayer would survive but that lies about liveness (reusing
a still-live name, releasing a pinned constant that stays resident
anyway, stray metadata instructions) is a *warning*; ``verify_log``
raises only on errors.  ``trace.replay.run_trace`` calls ``check_log``
on every log it replays (memoized per log object), so a malformed trace
fails fast with a structured report instead of a mid-replay KeyError.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from ..core.graph import (Alias, Call, Constant, Copy, CopyFrom, Log,
                          Memory, Mutate, Release, parse_call_block)

#: fields a lint result is allowed to distinguish severities on
SEVERITIES = ("error", "warning")

#: names of the storage attributes heuristic keys may read — documented
#: here because the trace verifier and the AST lint share the contract.
SUBSCRIBED_KEY_FIELDS = frozenset(("local_cost", "dead_cost", "size", "sid"))


@dataclass(frozen=True)
class TraceIssue:
    code: str
    severity: str           # "error" | "warning"
    index: int              # instruction index (-1 for log-level issues)
    message: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.code} @ instr {self.index}: " \
               f"{self.message}"


class TraceLintError(ValueError):
    """A log failed static verification; ``.issues`` carries the errors."""

    def __init__(self, log_name: str, issues: list[TraceIssue]) -> None:
        errors = [i for i in issues if i.severity == "error"]
        lines = "\n  ".join(str(i) for i in errors[:8])
        more = f"\n  ... and {len(errors) - 8} more" if len(errors) > 8 else ""
        super().__init__(
            f"trace {log_name!r} failed static verification "
            f"({len(errors)} error(s)):\n  {lines}{more}")
        self.issues = issues


class _State:
    """Shadow refcount state for one linear pass (mirrors graph.replay)."""

    def __init__(self, dealloc: str) -> None:
        self.dealloc = dealloc
        self.env: dict[str, int] = {}       # name -> tensor id
        self.trefs: dict[int, int] = {}     # tensor id -> external refcount
        self.tsid: dict[int, int] = {}      # tensor id -> storage id
        self.ssize: dict[int, int] = {}
        self.srefs: dict[int, int] = {}     # storage refcount (sum of views)
        self.sconst: set[int] = set()
        self.sdeps: dict[int, set[int]] = {}
        self.schildren: dict[int, set[int]] = {}
        self.banished: set[int] = set()
        self.pinned: set[int] = set()
        self._safe: dict[int, bool] = {}    # remat-closure memo (per epoch)
        self._next_tid = 0
        self._next_sid = 0

    # -- tensor/storage creation ----------------------------------------
    def new_storage(self, size: int, constant: bool = False) -> int:
        sid = self._next_sid
        self._next_sid += 1
        self.ssize[sid] = size
        self.srefs[sid] = 0
        self.sdeps[sid] = set()
        self.schildren[sid] = set()
        if constant:
            self.sconst.add(sid)
            self.pinned.add(sid)
        return sid

    def new_tensor(self, name: str, sid: int) -> int:
        tid = self._next_tid
        self._next_tid += 1
        self.env[name] = tid
        self.trefs[tid] = 1
        self.tsid[tid] = sid
        self.srefs[sid] += 1
        return tid

    # -- banish simulation ------------------------------------------------
    def storage_released(self, sid: int) -> None:
        """Storage refcount hit zero: under ``banish`` it will eventually
        be permanently freed, pinning its current children (exactly what
        ``DTRRuntime._try_banish`` does; deferral only delays the event)."""
        if self.dealloc != "banish" or sid in self.banished:
            return
        self.banished.add(sid)
        for c in self.schildren[sid]:
            self.pinned.add(c)
        self._safe.clear()              # remat-closure memo is epoch-scoped

    def remat_safe(self, sid: int) -> bool:
        """Can ``sid`` be rematerialized if evicted?  False iff its remat
        closure crosses a banished storage with no pinned ancestor
        shielding it.  Pinned / constant storages are never evicted, so
        the walk stops there; banish-free runs are trivially safe.

        Well-formed logs cannot fail this: the banish path pins every
        surviving child, which shields all transitive consumers — the
        check guards log *producers* (trace editors, plan-to-log
        lowerings) that write Release placement or dep structure by hand.
        """
        if not self.banished:
            return True
        memo = self._safe
        if sid in memo:
            return memo[sid]
        # Iterative post-order DFS; cycle members left unresolved are
        # treated as safe (a dep cycle is only expressible in hand-built
        # logs, and one confusing error beats a cascade).
        visiting: set[int] = set()
        stack: list[tuple[int, bool]] = [(sid, False)]
        while stack:
            x, post = stack.pop()
            if post:
                visiting.discard(x)
                ok = True
                for d in self.sdeps[x]:
                    if d in self.banished:
                        ok = False
                        break
                    if d in self.pinned or d in self.sconst:
                        continue
                    if not memo.get(d, True):
                        ok = False
                        break
                memo[x] = ok
                continue
            if x in memo or x in visiting:
                continue
            if x in self.banished:
                memo[x] = False
                continue
            if x in self.pinned or x in self.sconst:
                memo[x] = True
                continue
            visiting.add(x)
            stack.append((x, True))
            for d in self.sdeps[x]:
                if (d not in memo and d not in visiting
                        and d not in self.banished
                        and d not in self.pinned and d not in self.sconst):
                    stack.append((d, False))
        return memo.get(sid, True)


def lint_log(log: Log, dealloc: str = "eager") -> list[TraceIssue]:
    """Statically verify ``log``; returns all issues (errors + warnings).

    ``dealloc`` selects the deallocation policy the log will replay
    under: the ``banish`` policy turns use-after-release into
    use-after-banish (a permanent-free hazard) and enables the
    remat-closure reachability analysis.
    """
    assert dealloc in ("ignore", "eager", "banish")
    st = _State(dealloc)
    issues: list[TraceIssue] = []

    def err(code: str, i: int, msg: str) -> None:
        issues.append(TraceIssue(code, "error", i, msg))

    def warn(code: str, i: int, msg: str) -> None:
        issues.append(TraceIssue(code, "warning", i, msg))

    def use(name: str, i: int, what: str) -> int | None:
        """Validate a tensor use; returns its tensor id (None if broken)."""
        tid = st.env.get(name)
        if tid is None:
            err("undefined-tensor", i,
                f"{what} {name!r} was never defined")
            return None
        sid = st.tsid[tid]
        if st.trefs[tid] <= 0:
            if sid in st.banished:
                err("use-after-banish", i,
                    f"{what} {name!r} uses a banished storage "
                    f"(refcount hit zero under dealloc='banish')")
            elif sid in st.sconst:
                warn("stale-constant-use", i,
                     f"{what} {name!r} was released but its pinned "
                     f"constant storage stays resident under "
                     f"dealloc={dealloc!r}")
            else:
                err("use-after-release", i,
                    f"{what} {name!r} has no external references left "
                    f"(the runtime may have pruned it as dead)")
        elif not st.remat_safe(sid):
            err("unreachable-recompute", i,
                f"{what} {name!r} cannot be rematerialized if evicted: "
                f"its recompute closure crosses a banished storage")
        return tid

    def release(tid: int, i: int, name: str) -> None:
        if st.trefs[tid] <= 0:
            err("release-underflow", i,
                f"RELEASE of {name!r} underflows its refcount "
                f"(already {st.trefs[tid]})")
            return
        st.trefs[tid] -= 1
        sid = st.tsid[tid]
        st.srefs[sid] -= 1
        if st.srefs[sid] <= 0:
            st.storage_released(sid)

    def define(name: str, sid: int, i: int) -> int:
        old = st.env.get(name)
        if old is not None and st.trefs[old] > 0:
            warn("shadowed-definition", i,
                 f"output {name!r} shadows a still-live tensor "
                 f"(its external reference leaks)")
        return st.new_tensor(name, sid)

    instrs = log.instrs
    n = len(instrs)
    consumed: set[int] = set()          # metadata indices owned by a block
    i = 0
    while i < n:
        ins = instrs[i]
        if isinstance(ins, Constant):
            mem = instrs[i + 1] if i + 1 < n else None
            if not (isinstance(mem, Memory) and mem.t == ins.t):
                err("malformed-constant", i,
                    f"CONSTANT {ins.t!r} is not followed by its MEMORY")
                define(ins.t, st.new_storage(0, constant=True), i)
                i += 1
                continue
            if mem.size < 0:
                err("bad-size", i + 1,
                    f"MEMORY for {ins.t!r} has negative size {mem.size}")
            consumed.add(i + 1)
            define(ins.t, st.new_storage(mem.size, constant=True), i)
            i += 2
            continue
        if isinstance(ins, Call):
            if not (isinstance(ins.cost, (int, float))
                    and math.isfinite(ins.cost) and ins.cost >= 0):
                err("bad-cost", i,
                    f"CALL {ins.op!r} has non-finite or negative cost "
                    f"{ins.cost!r}")
            try:
                sizes, alias_names, j = parse_call_block(instrs, i)
            except (AssertionError, IndexError) as e:
                err("malformed-call-block", i,
                    f"CALL {ins.op!r}: metadata block does not match "
                    f"outputs {ins.outputs} ({e or 'truncated'})")
                sizes = [0] * len(ins.outputs)
                alias_names = [None] * len(ins.outputs)
                j = i + 1
            else:
                consumed.update(range(i + 1, j))
            in_tids = [use(t, i, "CALL input") for t in ins.inputs]
            in_sids = {st.tsid[t] for t in in_tids if t is not None}
            out_sids: list[int] = []
            for k, (t, size, al) in enumerate(
                    zip(ins.outputs, sizes, alias_names)):
                if al is not None:
                    if size != 0:
                        err("alias-size", i,
                            f"output {t!r} aliases {al!r} but carries "
                            f"nonzero MEMORY size {size}")
                    atid = use(al, i, "ALIAS target")
                    sid = (st.tsid[atid] if atid is not None
                           else st.new_storage(0))
                else:
                    if size < 0:
                        err("bad-size", i,
                            f"output {t!r} has negative size {size}")
                    sid = st.new_storage(max(size, 0))
                define(t, sid, i)
                out_sids.append(sid)
            for osid in set(out_sids):
                for isid in in_sids:
                    if isid != osid:
                        st.sdeps[osid].add(isid)
                        st.schildren[isid].add(osid)
            i = j
            continue
        if isinstance(ins, Mutate):
            if not (isinstance(ins.cost, (int, float))
                    and math.isfinite(ins.cost) and ins.cost >= 0):
                err("bad-cost", i,
                    f"MUTATE {ins.op!r} has non-finite or negative cost "
                    f"{ins.cost!r}")
            inputs = set(ins.inputs)
            for t in ins.mutated:
                if t not in inputs:
                    err("mutate-not-input", i,
                        f"MUTATE {ins.op!r} mutates {t!r} which is not "
                        f"among its inputs {ins.inputs}")
            in_tids = {t: use(t, i, "MUTATE input") for t in ins.inputs}
            in_sids = {st.tsid[tid] for tid in in_tids.values()
                       if tid is not None}
            # Copy-on-write rewrite (graph.replay): fresh versions of the
            # mutated tensors replace the old bindings, old refs released.
            for t in ins.mutated:
                old = in_tids.get(t)
                if t not in inputs or old is None:
                    continue
                sid = st.new_storage(st.ssize[st.tsid[old]])
                for isid in in_sids:
                    if isid != sid:
                        st.sdeps[sid].add(isid)
                        st.schildren[isid].add(sid)
                release(old, i, t)
                st.new_tensor(t, sid)
            i += 1
            continue
        if isinstance(ins, Copy):
            tid = use(ins.t_in, i, "COPY source")
            if tid is not None:
                old = st.env.get(ins.t_out)
                if (old is not None and old != tid
                        and st.trefs[old] > 0):
                    warn("shadowed-definition", i,
                         f"COPY target {ins.t_out!r} shadows a "
                         f"still-live tensor")
                st.env[ins.t_out] = tid
                st.trefs[tid] += 1
                st.srefs[st.tsid[tid]] += 1
            i += 1
            continue
        if isinstance(ins, CopyFrom):
            out = st.env.get(ins.t_out)
            if out is None:
                err("undefined-tensor", i,
                    f"COPYFROM target {ins.t_out!r} was never defined")
            tid = use(ins.t_in, i, "COPYFROM source")
            if tid is not None:
                if out is not None:
                    release(out, i, ins.t_out)
                st.env[ins.t_out] = tid
                st.trefs[tid] += 1
                st.srefs[st.tsid[tid]] += 1
            i += 1
            continue
        if isinstance(ins, Release):
            tid = st.env.get(ins.t)
            if tid is None:
                err("undefined-tensor", i,
                    f"RELEASE of {ins.t!r} which was never defined")
            else:
                release(tid, i, ins.t)
            i += 1
            continue
        if isinstance(ins, (Memory, Alias)):
            if i not in consumed:
                warn("stray-metadata", i,
                     f"{type(ins).__name__} instruction not attached to "
                     f"any CONSTANT/CALL block (replay skips it)")
            i += 1
            continue
        err("unknown-instruction", i,
            f"unknown instruction {type(ins).__name__}")
        i += 1
    return issues


def verify_log(log: Log, dealloc: str = "eager") -> list[TraceIssue]:
    """``lint_log`` + raise :class:`TraceLintError` if any errors.

    Returns the warnings (if any) for callers that want to surface them.
    """
    issues = lint_log(log, dealloc=dealloc)
    if any(i.severity == "error" for i in issues):
        raise TraceLintError(log.name, issues)
    return [i for i in issues if i.severity == "warning"]


def check_log(log: Log, dealloc: str = "eager") -> None:
    """Memoized ``verify_log`` for hot replay paths.

    Budget sweeps replay the same ``Log`` object hundreds of times; the
    verdict is cached on the instance per dealloc policy (logs are not
    mutated after construction anywhere in the repo).
    """
    cache = getattr(log, "_lint_verdict", None)
    if cache is None:
        cache = {}
        log._lint_verdict = cache
    hit = cache.get(dealloc)
    if hit is not None:
        if hit is not True:
            raise hit
        return
    try:
        verify_log(log, dealloc=dealloc)
    except TraceLintError as e:
        cache[dealloc] = e
        raise
    cache[dealloc] = True
