"""repro.check: static trace verifier, runtime shadow sanitizer, repo lint.

Three independent correctness passes, all runnable via ``python -m
repro.check`` and gated in CI:

* :mod:`repro.check.trace_lint` — static liveness / remat-closure /
  alias-pin analysis over ``core.graph.Log`` programs, run automatically
  before every ``trace.replay.run_trace`` replay;
* :mod:`repro.check.sanitizer` — a shadow model cross-checking every
  runtime transition (evict, remat, offload, fetch, banish, death,
  compaction) plus periodic full-state audits (byte conservation, index
  parity, union-find root sums), enabled with ``DTRRuntime(...,
  sanitize=True)`` / ``simulate(..., sanitize=True)``;
* :mod:`repro.check.lint` — an AST linter for repo-specific rules
  (``object.__setattr__`` bypasses of the ``StorageRec`` notification
  hook, non-strict ``json.dump``, swallowed exceptions, heuristic
  ``key()`` purity).
"""
from .lint import LintFinding, lint_paths, lint_source
from .sanitizer import SanitizerViolation, ShadowSanitizer
from .trace_lint import (TraceIssue, TraceLintError, check_log, lint_log,
                         verify_log)

__all__ = [
    "LintFinding", "lint_paths", "lint_source",
    "SanitizerViolation", "ShadowSanitizer",
    "TraceIssue", "TraceLintError", "check_log", "lint_log", "verify_log",
]
