"""Runtime shadow sanitizer: cross-check every DTR state transition.

Enabled with ``DTRRuntime(..., sanitize=True)`` (or ``simulate(...,
sanitize=True)`` / ``run_trace(..., sanitize=True)``).  The sanitizer is
a pure *observer*: it never writes a storage attribute, never calls the
counting ``CostUnionFind.find`` (a raw parent walk keeps ``accesses``
and therefore ``meta_accesses`` bit-exact), and never touches eviction-
index state — a sanitized run produces byte-identical results to an
unsanitized one or the sanitizer itself is buggy (tested in
``tests/test_check.py``).

Two layers:

* **transition hooks** (O(1), always on): legality of each evict /
  offload / fetch / banish / death / compaction event *before* the
  runtime mutates state — never-evict-pinned/locked/constant, no double
  free, offload state-machine legality (offload only from resident
  non-offloaded, fetch only of an offloaded record the host tier holds),
  host-capacity overcommit, compaction must conserve free bytes;
* **full-state audits** (O(storages), every ``every``-th op and always
  at ``finalize``): per-storage flag consistency, view/storage refcount
  agreement, evictable-set parity with the ``EvictIndex``, byte
  conservation ``device + host(+ in-flight prefetch) == accounted``, and
  union-find root-sum consistency against the ground-truth grouping of
  joined members.

Violations raise :class:`SanitizerViolation` carrying a structured
``.code`` and a ``.state`` dump of the relevant slice of runtime state.
"""
from __future__ import annotations

from typing import Optional

_ABS_TOL = 1e-6


class SanitizerViolation(RuntimeError):
    """An invariant the shadow model tracks was broken.

    ``code`` is a stable machine-readable identifier (e.g.
    ``"evict-pinned"``, ``"byte-conservation"``); ``state`` is a dict
    snapshot of the violating slice of runtime state.
    """

    def __init__(self, code: str, message: str, state: dict) -> None:
        lines = "\n".join(f"    {k} = {v!r}" for k, v in state.items())
        super().__init__(f"[{code}] {message}\n  state:\n{lines}")
        self.code = code
        self.state = state


def _storage_state(s) -> dict:
    return {
        "sid": s.sid, "size": s.size, "resident": s.resident,
        "pinned": s.pinned, "banished": s.banished, "constant": s.constant,
        "offloaded": s.offloaded, "dead": s.dead, "locks": s.locks,
        "refs": s.refs, "local_cost": s.local_cost,
    }


def _raw_root(uf, x: int) -> int:
    """Non-mutating, non-counting parent walk (bit-exactness: the real
    ``find`` path-halves and increments ``accesses``, which feeds the
    ``meta_accesses`` telemetry the benchmarks pin)."""
    p = uf._parent
    while p[x] != x:
        x = p[x]
    return x


class ShadowSanitizer:
    """Observer attached to one :class:`~repro.core.runtime.DTRRuntime`.

    ``every`` sets the full-audit cadence in operators (1 = audit after
    every op; larger values keep the O(storages) sweep off the hot path
    for long traces — transition hooks stay on regardless, and
    ``finalize`` always triggers a final audit).
    """

    def __init__(self, rt, every: int = 1) -> None:
        self.rt = rt
        self.every = max(1, int(every))
        self.audits = 0
        self.checks = 0
        self._ops_seen = 0

    # ------------------------------------------------------------------
    # Transition hooks (O(1), invoked by the runtime *before* mutation)
    # ------------------------------------------------------------------
    def _fail(self, code: str, message: str, state: dict) -> None:
        rt = self.rt
        state = dict(state)
        state.setdefault("clock", rt.clock)
        state.setdefault("ops_executed", rt.ops_executed)
        state.setdefault("memory", rt.memory)
        raise SanitizerViolation(code, message, state)

    def pre_evict(self, s) -> None:
        self.checks += 1
        st = _storage_state(s)
        if s.banished:
            self._fail("evict-banished",
                       f"evicting banished storage {s.sid}", st)
        if not s.resident:
            self._fail("evict-nonresident",
                       f"evicting non-resident storage {s.sid} "
                       f"(double free)", st)
        if s.constant:
            self._fail("evict-constant",
                       f"evicting constant storage {s.sid}", st)
        if s.pinned:
            self._fail("evict-pinned",
                       f"evicting pinned storage {s.sid}", st)
        if s.locks > 0:
            self._fail("evict-locked",
                       f"evicting storage {s.sid} with {s.locks} "
                       f"live lock(s)", st)

    def pre_offload(self, s) -> None:
        self.checks += 1
        st = _storage_state(s)
        eng = self.rt.offload
        if eng is None:
            self._fail("offload-no-engine",
                       f"offloading storage {s.sid} without an engine", st)
        if s.offloaded or (eng is not None and eng.holds(s.sid)):
            self._fail("offload-already",
                       f"offloading storage {s.sid} which is already "
                       f"host-resident", st)
        if s.size <= 0:
            self._fail("offload-empty",
                       f"offloading zero-byte storage {s.sid}", st)
        # Same legality preconditions as eviction (the victim paths share
        # the evictable() gate).
        if s.banished:
            self._fail("evict-banished",
                       f"offloading banished storage {s.sid}", st)
        if not s.resident:
            self._fail("evict-nonresident",
                       f"offloading non-resident storage {s.sid}", st)
        if s.constant:
            self._fail("evict-constant",
                       f"offloading constant storage {s.sid}", st)
        if s.pinned:
            self._fail("evict-pinned",
                       f"offloading pinned storage {s.sid}", st)
        if s.locks > 0:
            self._fail("evict-locked",
                       f"offloading storage {s.sid} with {s.locks} "
                       f"live lock(s)", st)
        if eng is not None and eng.host.used + s.size > eng.host.capacity:
            st["host_used"] = eng.host.used
            st["host_capacity"] = eng.host.capacity
            self._fail("offload-overcommit",
                       f"offloading {s.size}B would overcommit the host "
                       f"tier", st)

    def pre_fetch(self, s) -> None:
        self.checks += 1
        st = _storage_state(s)
        eng = self.rt.offload
        if not s.offloaded:
            self._fail("fetch-not-offloaded",
                       f"fetching storage {s.sid} which is not "
                       f"offloaded", st)
        if eng is None or not eng.holds(s.sid):
            self._fail("fetch-no-record",
                       f"fetching storage {s.sid} with no host-tier "
                       f"record", st)
        if s.resident:
            self._fail("fetch-resident",
                       f"fetching storage {s.sid} which is already "
                       f"device-resident", st)
        if s.banished:
            self._fail("fetch-banished",
                       f"fetching banished storage {s.sid}", st)
        if s.dead:
            self._fail("fetch-dead",
                       f"fetching dead storage {s.sid}", st)

    def pre_banish(self, s) -> None:
        self.checks += 1
        st = _storage_state(s)
        if s.banished:
            self._fail("banish-double",
                       f"banishing already-banished storage {s.sid}", st)
        if s.refs > 0:
            self._fail("banish-live",
                       f"banishing storage {s.sid} with {s.refs} live "
                       f"external reference(s)", st)

    def pre_kill(self, s) -> None:
        self.checks += 1
        st = _storage_state(s)
        if s.dead:
            self._fail("kill-double",
                       f"killing already-dead storage {s.sid}", st)
        if s.refs > 0:
            self._fail("kill-live",
                       f"killing storage {s.sid} with {s.refs} live "
                       f"external reference(s)", st)
        storages = self.rt.storages
        for csid in s.children:
            c = storages[csid]
            if not c.dead and not c.banished:
                st["child"] = _storage_state(c)
                self._fail("kill-live-child",
                           f"killing storage {s.sid} whose child {csid} "
                           f"is neither dead nor banished", st)

    def note_compaction(self, before, after) -> None:
        """Compaction relocates blocks; it must conserve free bytes and
        never shrink the largest free span (that is its whole point)."""
        self.checks += 1
        st = {"before": before.as_dict(), "after": after.as_dict()}
        if abs(after.free - before.free) > _ABS_TOL:
            self._fail("compaction-leak",
                       f"pool compaction changed free bytes "
                       f"{before.free} -> {after.free}", st)
        if after.largest_free + _ABS_TOL < before.largest_free:
            self._fail("compaction-fragmented",
                       f"pool compaction shrank the largest free span "
                       f"{before.largest_free} -> {after.largest_free}", st)

    # ------------------------------------------------------------------
    # Full-state audit (O(storages))
    # ------------------------------------------------------------------
    def on_op(self) -> None:
        self._ops_seen += 1
        if self._ops_seen % self.every == 0:
            self.audit()

    def audit(self) -> None:
        rt = self.rt
        self.audits += 1
        storages = rt.storages
        # -- per-storage flag consistency (sid order => deterministic
        #    first failure, which the mutation tests key on) -------------
        for sid in sorted(storages):
            s = storages[sid]
            st = _storage_state(s)
            if s.resident and s.offloaded:
                self._fail("resident-and-offloaded",
                           f"storage {sid} is both device- and "
                           f"host-resident", st)
            if s.banished and s.resident:
                self._fail("banished-resident",
                           f"banished storage {sid} still resident", st)
            if s.banished and s.offloaded:
                self._fail("banished-resident",
                           f"banished storage {sid} still holds a host "
                           f"copy", st)
            if s.constant and not s.resident and not s.banished:
                self._fail("constant-evicted",
                           f"constant storage {sid} was evicted", st)
            if s.locks < 0:
                self._fail("negative-locks",
                           f"storage {sid} has negative lock count", st)
            if s.dead and s.refs > 0:
                self._fail("dead-live",
                           f"dead storage {sid} has {s.refs} live "
                           f"reference(s)", st)
            if s.dead:
                for csid in s.children:
                    c = storages[csid]
                    if not c.dead and not c.banished:
                        st["child"] = _storage_state(c)
                        self._fail("dead-live-child",
                                   f"dead storage {sid} has live child "
                                   f"{csid}", st)
        # -- view/storage agreement --------------------------------------
        vrefs: dict[int, int] = {sid: 0 for sid in storages}
        for t in rt.tensors.values():
            if t.sid not in storages:
                self._fail("view-orphan",
                           f"tensor {t.tid} points at unknown storage "
                           f"{t.sid}", {"tid": t.tid, "sid": t.sid})
            vrefs[t.sid] += max(t.refs, 0)
            s = storages[t.sid]
            if t.defined and not s.resident:
                self._fail("defined-nonresident",
                           f"tensor {t.tid} is defined but its storage "
                           f"{t.sid} is not resident",
                           {"tid": t.tid, **_storage_state(s)})
        for sid in sorted(storages):
            s = storages[sid]
            if s.refs != vrefs[sid]:
                self._fail("refs-desync",
                           f"storage {sid} caches refs={s.refs} but its "
                           f"views sum to {vrefs[sid]}",
                           {**_storage_state(s), "view_sum": vrefs[sid]})
        # -- evictable-set parity with the EvictIndex --------------------
        if rt.index is not None:
            expect = {sid for sid, s in storages.items()
                      if s.evictable() and s.size > 0}
            got = rt.index.members
            if got != expect:
                self._fail("index-desync",
                           f"EvictIndex membership diverged from the "
                           f"evictable set",
                           {"missing": sorted(expect - got),
                            "extra": sorted(got - expect)})
        # -- byte conservation -------------------------------------------
        dev = sum(s.size for s in storages.values() if s.resident)
        inflight = 0.0
        if rt.offload is not None:
            inflight = sum(rec.nbytes
                           for rec in rt.offload._recs.values()
                           if rec.ready_at is not None)
        accounted = dev + inflight
        if abs(rt.memory - accounted) > _ABS_TOL:
            self._fail("byte-conservation",
                       f"device counter {rt.memory} != resident bytes "
                       f"{dev} + in-flight prefetch {inflight}",
                       {"memory": rt.memory, "resident": dev,
                        "inflight": inflight})
        if rt.peak_memory + _ABS_TOL < rt.memory:
            self._fail("peak-below-memory",
                       f"peak_memory {rt.peak_memory} below current "
                       f"memory {rt.memory}",
                       {"peak": rt.peak_memory, "memory": rt.memory})
        # -- pool-allocator residency parity -------------------------------
        alloc = rt.allocator
        if alloc is not None and alloc.pool is not None:
            pool = alloc.pool
            expect = {sid for sid, s in storages.items()
                      if s.resident and s.size > 0}
            if rt.offload is not None:
                # In-flight prefetches hold a device reservation (a pool
                # block) before the storage flips resident.
                expect |= {sid for sid, rec in rt.offload._recs.items()
                           if rec.ready_at is not None
                           and storages[sid].size > 0}
            got = pool.resident_sids()
            if got != expect:
                self._fail("pool-desync",
                           f"pool block ownership diverged from resident "
                           f"storages",
                           {"missing": sorted(expect - got),
                            "extra": sorted(got - expect)})
            placed = sum(storages[sid].size for sid in got)
            if abs(pool.used - placed) > _ABS_TOL:
                self._fail("pool-bytes",
                           f"pool used={pool.used} but placed storages "
                           f"sum to {placed}",
                           {"used": pool.used, "expected": placed})
        # -- host-tier parity ----------------------------------------------
        if rt.offload is not None:
            eng = rt.offload
            flagged = {sid for sid, s in storages.items() if s.offloaded}
            recs = set(eng._recs)
            hostset = set(eng.host._resident)
            if not (flagged == recs == hostset):
                self._fail("host-desync",
                           f"offloaded flags / engine records / host "
                           f"residency disagree",
                           {"flagged": sorted(flagged),
                            "engine": sorted(recs),
                            "host": sorted(hostset)})
            hbytes = sum(storages[sid].size for sid in flagged)
            if abs(eng.host.used - hbytes) > _ABS_TOL:
                self._fail("host-bytes",
                           f"host tier used={eng.host.used} but offloaded "
                           f"storages sum to {hbytes}",
                           {"used": eng.host.used, "expected": hbytes})
            if eng.host.used > eng.host.capacity + _ABS_TOL:
                self._fail("host-overcommit",
                           f"host tier used={eng.host.used} exceeds "
                           f"capacity={eng.host.capacity}",
                           {"used": eng.host.used,
                            "capacity": eng.host.capacity})
        # -- union-find root-sum consistency -------------------------------
        if rt.uf is not None:
            uf = rt.uf
            expect_sums: dict[int, float] = {}
            for s in storages.values():
                if s.uf_joined and s.uf >= 0:
                    r = _raw_root(uf, s.uf)
                    expect_sums[r] = expect_sums.get(r, 0.0) + s.local_cost
            for r, want in sorted(expect_sums.items()):
                got = uf._cost[r]
                tol = _ABS_TOL + 1e-9 * abs(want)
                if abs(got - want) > tol:
                    self._fail("uf-root-sum",
                               f"union-find root {r} caches cost {got} "
                               f"but joined members sum to {want}",
                               {"root": r, "cached": got, "expected": want,
                                "members": sorted(
                                    s.sid for s in storages.values()
                                    if s.uf_joined and s.uf >= 0
                                    and _raw_root(uf, s.uf) == r)})


def attach(rt, sanitize) -> Optional[ShadowSanitizer]:
    """Resolve the ``sanitize`` runtime argument into a sanitizer.

    ``False``/``None``/``0`` => no sanitizer; ``True`` => audit every op;
    an int N > 0 => audit every N ops (transition hooks always on)."""
    if not sanitize:
        return None
    every = 1 if sanitize is True else int(sanitize)
    return ShadowSanitizer(rt, every=every)
