r"""Repo-specific AST lint rules, run by ``python -m repro.check --lint``.

Rules (all repo-specific — generic style is out of scope):

* ``setattr-bypass`` — ``object.__setattr__(s, ...)`` on anything but
  ``self`` outside ``core/runtime.py``.  ``StorageRec.__setattr__`` is a
  notification hook: writes to watched fields tell the eviction index to
  re-band the storage, and a raw ``object.__setattr__`` silently skips
  that — the index then serves stale victims (the bug class behind the
  audit of ``offload/engine.py``).
* ``strict-json`` — every ``json.dump``/``json.dumps`` call must pass
  ``allow_nan=False``.  All committed BENCH/report payloads are strict
  JSON (no ``Infinity``/``NaN`` literals) and CI greps for violations;
  a writer without the flag can silently produce unparseable reports.
* ``swallowed-exception`` — ``except:`` / ``except Exception:`` /
  ``except BaseException:`` that neither binds the exception (``as e``
  followed by reporting is the legitimate driver-loop pattern) nor
  re-``raise``\ s anywhere in the handler body.  PR 8 fixed a real
  instance (a bare except faking chen_sqrt feasibility); handlers must
  name the types they expect, surface the error, or re-raise.
* ``key-purity`` — in a ``Heuristic`` subclass declaring
  ``separable = True``, the ``key(self, rt, s)`` method may read only
  the storage fields the eviction index subscribes to
  (``local_cost``, ``dead_cost``, ``size``, ``sid``) and must not read
  ``rt.clock`` / ``rt.staleness`` (staleness belongs in the shared
  denominator, not the banded key — a clock-dependent key would go
  stale without any invalidation event).

Suppression: append ``# repro-lint: allow[rule-name]`` to the flagged
line (or the line directly above it).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path

ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\[([a-z0-9_,\- ]+)\]")

#: storage attributes a separable ``key()`` may read — the fields whose
#: writes notify the eviction index (plus the immutable size/sid).
KEY_ALLOWED_S_FIELDS = frozenset(("local_cost", "dead_cost", "size", "sid"))
#: runtime attributes a separable ``key()`` must NOT read.
KEY_FORBIDDEN_RT_FIELDS = frozenset(("clock", "staleness"))


@dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


def _suppressions(src: str) -> dict[int, set[str]]:
    """Line number -> rule names allowed there (flagged line or line above)."""
    out: dict[int, set[str]] = {}
    for lineno, line in enumerate(src.splitlines(), start=1):
        m = ALLOW_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out.setdefault(lineno, set()).update(rules)
            out.setdefault(lineno + 1, set()).update(rules)
    return out


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, allow_setattr_bypass: bool) -> None:
        self.path = path
        self.allow_setattr_bypass = allow_setattr_bypass
        self.findings: list[LintFinding] = []

    def _add(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(LintFinding(
            self.path, getattr(node, "lineno", 0),
            getattr(node, "col_offset", 0), rule, message))

    # -- setattr-bypass ----------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr == "__setattr__"
                and isinstance(f.value, ast.Name) and f.value.id == "object"
                and not self.allow_setattr_bypass):
            target = node.args[0] if node.args else None
            if not (isinstance(target, ast.Name) and target.id == "self"):
                self._add(node, "setattr-bypass",
                          "object.__setattr__ bypasses the StorageRec "
                          "notification hook the eviction index depends "
                          "on; write the attribute normally or move the "
                          "code into core/runtime.py")
        if (isinstance(f, ast.Attribute)
                and f.attr in ("dump", "dumps")
                and isinstance(f.value, ast.Name) and f.value.id == "json"):
            strict = any(
                kw.arg == "allow_nan"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is False
                for kw in node.keywords)
            if not strict:
                self._add(node, "strict-json",
                          f"json.{f.attr} without allow_nan=False can "
                          f"emit Infinity/NaN literals no strict parser "
                          f"accepts; all report writers must be strict")
        self.generic_visit(node)

    # -- swallowed-exception -------------------------------------------------
    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        broad = (node.type is None
                 or (isinstance(node.type, ast.Name)
                     and node.type.id in ("Exception", "BaseException")))
        if broad and node.name is None:
            reraises = any(isinstance(n, ast.Raise)
                           for n in ast.walk(node))
            if not reraises:
                what = ("bare except"
                        if node.type is None
                        else f"except {node.type.id}")  # type: ignore[union-attr]
                self._add(node, "swallowed-exception",
                          f"{what}: swallows every error without "
                          f"re-raising; name the exception types this "
                          f"handler actually expects")
        self.generic_visit(node)

    # -- key-purity -------------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        separable = False
        for stmt in node.body:
            if (isinstance(stmt, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "separable"
                            for t in stmt.targets)
                    and isinstance(stmt.value, ast.Constant)
                    and stmt.value.value is True):
                separable = True
            if (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and stmt.target.id == "separable"
                    and isinstance(stmt.value, ast.Constant)
                    and stmt.value.value is True):
                separable = True
        if separable:
            for stmt in node.body:
                if (isinstance(stmt, ast.FunctionDef)
                        and stmt.name == "key"):
                    self._check_key_purity(stmt)
        self.generic_visit(node)

    def _check_key_purity(self, fn: ast.FunctionDef) -> None:
        args = [a.arg for a in fn.args.args]
        if len(args) < 3:
            return
        rt_name, s_name = args[1], args[2]
        for n in ast.walk(fn):
            if not (isinstance(n, ast.Attribute)
                    and isinstance(n.ctx, ast.Load)
                    and isinstance(n.value, ast.Name)):
                continue
            base = n.value.id
            if base == s_name and n.attr not in KEY_ALLOWED_S_FIELDS:
                self._add(n, "key-purity",
                          f"separable key() reads {s_name}.{n.attr}, "
                          f"outside the invalidation-subscribed set "
                          f"{sorted(KEY_ALLOWED_S_FIELDS)}; the eviction "
                          f"index would serve stale keys")
            elif base == rt_name and n.attr in KEY_FORBIDDEN_RT_FIELDS:
                self._add(n, "key-purity",
                          f"separable key() reads {rt_name}.{n.attr}; "
                          f"clock-dependent terms belong in the shared "
                          f"staleness denominator, not the banded key")


def lint_source(src: str, path: str = "<string>") -> list[LintFinding]:
    """Lint one Python source string; returns unsuppressed findings."""
    allow_bypass = path.replace("\\", "/").endswith("core/runtime.py")
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [LintFinding(path, e.lineno or 0, e.offset or 0,
                            "syntax-error", str(e.msg))]
    v = _Visitor(path, allow_bypass)
    v.visit(tree)
    sup = _suppressions(src)
    return [f for f in v.findings
            if f.rule not in sup.get(f.line, ())]


def lint_paths(paths: list[str]) -> list[LintFinding]:
    """Lint every ``.py`` file under ``paths`` (files or directories)."""
    files: list[Path] = []
    for p in paths:
        pp = Path(p)
        if pp.is_dir():
            files.extend(sorted(
                f for f in pp.rglob("*.py")
                if "__pycache__" not in f.parts
                and not any(part.startswith(".") for part in f.parts)))
        elif pp.suffix == ".py":
            files.append(pp)
    findings: list[LintFinding] = []
    for f in files:
        findings.extend(lint_source(f.read_text(), str(f)))
    return findings
