"""Distributed substrate: logical sharding rules, collectives, monitoring."""
from .sharding import (
    LOGICAL_RULES, ParamInfo, axis_resources, current_mesh, mesh_context,
    param_pspec, pspec, shard,
)

__all__ = [
    "LOGICAL_RULES", "ParamInfo", "axis_resources", "current_mesh",
    "mesh_context", "param_pspec", "pspec", "shard",
]
