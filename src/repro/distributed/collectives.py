"""Distributed-optimization collectives.

``compressed_psum``: int8-quantized gradient all-reduce — per-chunk scale
quantization, integer psum, dequantize.  Cuts DP all-reduce bytes 4× vs f32
(2× vs bf16) at ~1e-2 relative error; opt-in per train-step config.  Runs
under ``shard_map`` over the data axes; exact-dtype fallback otherwise.

``reduce_scatter_grads`` / ``all_gather_params``: explicit ZeRO-1 decomposed
collectives for overlap experiments (§Perf): XLA can schedule the
reduce-scatter of step N's grads against step N+1's forward all-gathers.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization (deterministic)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32))) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_names: tuple[str, ...]):
    """int8 all-reduce of a gradient pytree over ``axis_names``.

    Must be called inside shard_map (or any context where ``axis_names`` are
    bound).  Quantizes each leaf, psums int32 accumulators and the per-leaf
    scales separately (sum of per-shard dequantized values == dequantized sum
    because each shard carries its own scale — we psum scale-weighted ints).
    """
    def one(g):
        q, scale = quantize_int8(g)
        # Each shard contributes q*scale; psum of products needs the products
        # themselves — send int8 payload + scalar scale, reduce the
        # dequantized value via psum of (q in int32) when scales are shared.
        # For correctness with per-shard scales: psum(q * scale) done as
        # f32 psum of a scalar-rescaled int8 tensor is just f32 psum again.
        # Instead: all shards adopt the max scale (one extra scalar psum),
        # then integer-psum the requantized payloads.
        smax = jax.lax.pmax(scale, axis_names)
        qr = jnp.clip(jnp.round(dequantize_int8(q, scale) / smax),
                      -127, 127).astype(jnp.int32)
        total = jax.lax.psum(qr, axis_names)
        return (total.astype(jnp.float32) * smax).astype(g.dtype)

    return jax.tree.map(one, grads)


def make_compressed_allreduce(mesh: Mesh, data_axes: tuple[str, ...]):
    """shard_map-wrapped int8 gradient all-reduce over the data axes.

    Returns fn(grads)->grads usable outside shard_map.  Grad leaves must be
    replicated over ``data_axes`` in their sharding minus the reduction —
    i.e. this implements the DP-mean (divides by group size).
    """
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    if not axes:
        return lambda g: g
    n = int(np.prod([mesh.shape[a] for a in axes]))

    def fn(local_grads):
        summed = compressed_psum(local_grads, axes)
        return jax.tree.map(lambda x: x / n, summed)

    return fn


# ---------------------------------------------------------------------------
# Explicit DP decomposition (overlap material for §Perf)
# ---------------------------------------------------------------------------

def psum_grads(grads, mesh: Mesh, data_axes=("pod", "data")):
    """Plain (exact) DP grad mean via sharding constraint — lets XLA choose
    all-reduce vs reduce-scatter+all-gather under SPMD."""
    return grads  # SPMD inserts the reduction from out_shardings; hook point.
