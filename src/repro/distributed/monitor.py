"""Straggler / health monitoring for long-running multi-pod jobs.

No real cluster exists in this container, so this is the framework layer a
deployment would wire to its scheduler: per-step wall-time EWMA + outlier
detection, NaN/divergence guards, and an action hook (log, checkpoint-and-
exclude, abort).  launch/train.py drives it every step; tests exercise the
detection logic directly.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class StepStats:
    step: int
    seconds: float
    loss: float
    grad_norm: float
    flagged: bool = False
    reason: str = ""


@dataclass
class StragglerMonitor:
    """EWMA-based step-time outlier detection.

    A step slower than ``threshold``× the EWMA is flagged (straggling host /
    preemption precursor / input stall).  ``patience`` consecutive flags fire
    ``on_straggler`` (deployments: exclude pod, re-shard, checkpoint)."""
    alpha: float = 0.1
    threshold: float = 2.0
    patience: int = 3
    on_straggler: Optional[Callable[[StepStats], None]] = None
    _ewma: float = field(default=0.0, init=False)
    _consecutive: int = field(default=0, init=False)
    history: list[StepStats] = field(default_factory=list, init=False)

    def record(self, step: int, seconds: float, loss: float = 0.0,
               grad_norm: float = 0.0) -> StepStats:
        st = StepStats(step, seconds, loss, grad_norm)
        if self._ewma == 0.0:
            self._ewma = seconds
        elif seconds > self.threshold * self._ewma:
            st.flagged = True
            st.reason = (f"step {seconds:.3f}s > {self.threshold}x "
                         f"ewma {self._ewma:.3f}s")
            self._consecutive += 1
            if self._consecutive >= self.patience and self.on_straggler:
                self.on_straggler(st)
                self._consecutive = 0
        else:
            self._consecutive = 0
        # Only fold non-outliers into the EWMA (robust baseline).
        if not st.flagged:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * seconds
        self.history.append(st)
        return st

    @property
    def ewma(self) -> float:
        return self._ewma


@dataclass
class DivergenceGuard:
    """NaN/inf and loss-spike detection with skip/restore policy.

    ``check`` returns the action for this step: "ok", "skip" (drop the
    update), or "restore" (roll back to the last checkpoint) after
    ``max_skips`` consecutive bad steps."""
    spike_factor: float = 10.0
    max_skips: int = 3
    _ewma_loss: float = field(default=0.0, init=False)
    _skips: int = field(default=0, init=False)

    def check(self, loss: float, grad_norm: float) -> str:
        bad = (math.isnan(loss) or math.isinf(loss)
               or math.isnan(grad_norm) or math.isinf(grad_norm))
        if not bad and self._ewma_loss > 0:
            bad = loss > self.spike_factor * self._ewma_loss
        if bad:
            self._skips += 1
            return "restore" if self._skips > self.max_skips else "skip"
        self._skips = 0
        self._ewma_loss = (0.9 * self._ewma_loss + 0.1 * loss
                           if self._ewma_loss else loss)
        return "ok"


@dataclass
class MemorySample:
    step: int
    peak_bytes: float
    used_bytes: float = 0.0
    largest_free: float = 0.0
    frag_ratio: float = 0.0
    failed_fits: int = 0
    evict_windows: int = 0
    has_frag: bool = False          # frag fields valid (allocator telemetry)


@dataclass
class MemoryMonitor:
    """Memory telemetry for launch-time dashboards.

    Tracks peak bytes per step and, when a fragmentation-aware allocator is
    active (``repro.alloc``), the pool's health: largest free block (the
    number that actually bounds the next allocation, not free bytes),
    external-fragmentation ratio, failed contiguous fits, and window
    evictions.  ``frag`` accepts a ``repro.alloc.FragStats`` or any object
    with those attributes; dashboards alert on ``largest_free`` collapsing
    while free bytes look healthy — the failure mode byte counters miss."""
    history: list[MemorySample] = field(default_factory=list)
    peak_bytes: float = field(default=0.0, init=False)

    def record(self, step: int, peak_bytes: float,
               frag=None) -> MemorySample:
        sample = MemorySample(step=step, peak_bytes=peak_bytes)
        if frag is not None:
            sample.has_frag = True
            sample.used_bytes = getattr(frag, "used", 0.0)
            sample.largest_free = getattr(frag, "largest_free", 0.0)
            sample.frag_ratio = getattr(frag, "frag_ratio", 0.0)
            sample.failed_fits = getattr(frag, "failed_fits", 0)
            sample.evict_windows = getattr(frag, "evict_windows", 0)
        self.peak_bytes = max(self.peak_bytes, peak_bytes)
        self.history.append(sample)
        return sample

    def summary(self) -> dict:
        """Aggregate for dashboards: peak bytes + worst fragmentation seen.

        Fragmentation fields aggregate only over samples that carried
        allocator telemetry — a telemetry-less run (CPU backend) must not
        read as largest-free-block collapse.  None when never recorded."""
        frag = [s for s in self.history if s.has_frag]
        last = frag[-1] if frag else None
        return {
            "peak_bytes": self.peak_bytes,
            "min_largest_free": (min(s.largest_free for s in frag)
                                 if frag else None),
            "max_frag_ratio": (max(s.frag_ratio for s in frag)
                               if frag else None),
            "failed_fits": last.failed_fits if last else 0,
            "evict_windows": last.evict_windows if last else 0,
        }


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
