"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code annotates tensors with *logical* axes (``shard(x, "batch", None,
"embed")``); a single rules table maps logical axes to physical mesh axes.
Flipping parallelism strategy (pure DP, TP, FSDP, SP, EP) touches only this
table / per-run overrides — never model code.

Physical mesh axes: ``("pod", "data", "model")`` multi-pod or
``("data", "model")`` single-pod (launch/mesh.py).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis -> physical mesh axis (or tuple of axes, or None=replicated).
LOGICAL_RULES: dict[str, object] = {
    "batch": ("pod", "data"),   # data parallel over pod x data
    "seq": None,                # sequence replicated by default (SP flips this)
    "seq_model": "model",       # explicit sequence-parallel annotation
    "embed": None,              # activation d_model dim replicated
    "heads": "model",           # TP over attention heads
    "kv_heads": "model",
    "mlp": "model",             # TP over FFN hidden
    "vocab": "model",           # TP over vocab (embedding + logits)
    "expert": "model",          # EP: experts over model axis
    "expert_cap": ("pod", "data"),  # expert capacity dim over data
    "kv_seq": None,             # KV-cache sequence dim
    "fsdp": ("pod", "data"),    # param dim additionally sharded when FSDP on
    "lru": "model",             # RG-LRU width
    "conv": None,
}


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.overrides: dict[str, object] = {}
        self.fsdp: bool = False


_STATE = _State()


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], overrides: dict | None = None,
                 fsdp: bool = False):
    """Activate a mesh + rule overrides for model-code sharding constraints."""
    prev = (_STATE.mesh, _STATE.overrides, _STATE.fsdp)
    _STATE.mesh = mesh
    _STATE.overrides = dict(overrides or {})
    _STATE.fsdp = fsdp
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _STATE.mesh, _STATE.overrides, _STATE.fsdp = prev


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def fsdp_enabled() -> bool:
    return _STATE.fsdp


def _resolve(axis: Optional[str], mesh: Mesh) -> object:
    if axis is None:
        return None
    rules = {**LOGICAL_RULES, **_STATE.overrides}
    phys = rules.get(axis, None)
    if phys is None:
        return None
    if isinstance(phys, (tuple, list)):
        present = tuple(a for a in phys if a in mesh.axis_names)
        return present if present else None
    return phys if phys in mesh.axis_names else None


def _fit(r, dim: Optional[int], mesh: Mesh):
    """Keep only a prefix of mesh axes whose product divides ``dim``.

    GQA head counts (3, 2, 1…) and tiny batches don't divide a 16-way axis;
    we degrade to replication (or partial sharding for tuple axes) instead
    of failing — the divisibility rule GSPMD enforces on explicit shardings.
    """
    if r is None or dim is None:
        return r
    axes = r if isinstance(r, tuple) else (r,)
    kept = []
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
        if dim % prod == 0:
            kept.append(a)
        else:
            break
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def pspec(*axes: Optional[str], mesh: Optional[Mesh] = None,
          shape: Optional[tuple] = None) -> P:
    """PartitionSpec from logical axes under the active rules.

    With ``shape``, axes that don't divide the dimension are dropped
    (prefix-reduced for tuple mappings)."""
    mesh = mesh or _STATE.mesh
    if mesh is None:
        return P()
    resolved, used = [], set()
    for i, ax in enumerate(axes):
        r = _resolve(ax, mesh)
        if shape is not None:
            r = _fit(r, shape[i] if i < len(shape) else None, mesh)
        # Never map two tensor dims to the same mesh axis.
        flat = r if isinstance(r, tuple) else (r,) if r else ()
        if any(f in used for f in flat):
            r = None
        else:
            used.update(flat)
        resolved.append(r)
    return P(*resolved)


def shard(x, *axes: Optional[str]):
    """Apply a logical sharding constraint (no-op without an active mesh)."""
    mesh = _STATE.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, pspec(*axes, mesh=mesh,
                                     shape=tuple(x.shape))))


# ---------------------------------------------------------------------------
# Parameter metadata
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamInfo:
    """Shape/dtype/logical-axes record for one parameter tensor.

    ``fsdp_dim``: dimension index to shard additionally over the data axis
    when FSDP is enabled (ZeRO-3-style parameter sharding).
    """
    shape: tuple[int, ...]
    dtype: str = "float32"
    axes: tuple[Optional[str], ...] = ()
    fsdp_dim: Optional[int] = None
    init_scale: float = 0.02

    def __post_init__(self):
        assert len(self.axes) in (0, len(self.shape)), (self.shape, self.axes)


def param_pspec(info: ParamInfo, mesh: Optional[Mesh] = None,
                fsdp: Optional[bool] = None) -> P:
    mesh = mesh or _STATE.mesh
    if mesh is None:
        return P()
    fsdp = _STATE.fsdp if fsdp is None else fsdp
    axes = list(info.axes) if info.axes else [None] * len(info.shape)
    if fsdp and info.fsdp_dim is not None and axes[info.fsdp_dim] is None:
        axes[info.fsdp_dim] = "fsdp"
    return pspec(*axes, mesh=mesh, shape=tuple(info.shape))


def axis_resources(tree, mesh: Optional[Mesh] = None, fsdp: bool = False):
    """Map a pytree of ParamInfo to a pytree of NamedShardings."""
    mesh = mesh or _STATE.mesh

    def one(info: ParamInfo):
        return NamedSharding(mesh, param_pspec(info, mesh=mesh, fsdp=fsdp))

    return jax.tree.map(one, tree,
                        is_leaf=lambda x: isinstance(x, ParamInfo))


def shape_structs(tree):
    """ParamInfo tree -> ShapeDtypeStruct tree (for dry-run lowering)."""
    def one(info: ParamInfo):
        return jax.ShapeDtypeStruct(info.shape, np.dtype(info.dtype))

    return jax.tree.map(one, tree,
                        is_leaf=lambda x: isinstance(x, ParamInfo))
