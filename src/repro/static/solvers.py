"""Static checkpoint-selection solvers over a heterogeneous ``Chain``.

The chain model (see ``chain.py``): candidates ``i = 0..n-1`` in
production order, each with byte size ``m_i`` and segment recompute cost
``c_i``.  A *plan* keeps a subset ``S`` resident across their far gaps
and drops the rest:

* extra recompute  ``cost(S) = Σ_{i∉S} c_i``  — each dropped candidate's
  producing segment is replayed once when its far use arrives (exact on
  chain-shaped traces; the plan evaluator reports exact numbers for any
  trace);
* peak bytes  ``peak(S) = floor + Σ_{i∈S} m_i + max-run(S)``  where
  ``max-run`` is the largest total size of a maximal run of consecutive
  dropped candidates — during that run's replay all its intermediates
  are simultaneously live (Chen's segment-residency model).

Solvers:

* ``chen_sqrt``    — √n segmentation by candidate count (budget-oblivious,
                     feasibility reported honestly);
* ``chen_greedy``  — threshold greedy: close a segment when its bytes
                     exceed ``tau``; sweeps ``tau`` and keeps the cheapest
                     feasible plan;
* ``optimal_dp``   — Beaumont-style heterogeneous DP, exact in this model:
                     Pareto frontier over (kept bytes, max run bytes) per
                     last-kept anchor.  Returns the min over {DP, both
                     Chen variants, keep-all}, so DP ≤ Chen by
                     construction on every feasible instance;
* ``enumerate_optimal`` — exhaustive subset oracle for differential tests
                     (n ≤ 20).

All solvers are pure functions of (chain, budget); ties break toward
keeping lower-index candidates, so plans are deterministic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from .chain import Chain, ChainItem

#: Pareto-state cap per anchor; exceeding it truncates by cost and marks
#: the DP answer inexact (never triggered by the golden corpus at the
#: default candidate cap, but the flag keeps truncation honest).
MAX_STATES = 2000

#: Chains longer than this additionally run the DP on a size-balanced
#: block coarsening (keep/drop decided per consecutive block); the
#: expanded plan is scored on the full chain and flagged inexact.
DP_MAX_ITEMS = 48

#: Work budget for the exact DP (transitions + dominance-scan touches).
#: Frontier blowups (loose budgets on long heterogeneous chains) abort
#: the exact solve, leaving the block DP / Chen family to cover the
#: cell; tight-budget instances (small frontiers) still solve exactly
#: well past 100 items.
DP_MAX_STEPS = 4_000_000


class _StepLimit(Exception):
    pass


@dataclass
class Plan:
    """One checkpoint selection, scored under the chain model."""
    keep: frozenset[int]            # item indices kept resident
    cost: float                     # extra recompute (model)
    peak: float                     # floor + kept + max dropped run (model)
    budget: float
    solver: str
    feasible: bool
    exact: bool = True              # False when the DP truncated states
    meta: dict = field(default_factory=dict)

    @property
    def dropped(self) -> int:
        return int(self.meta.get("n", 0)) - len(self.keep)


def plan_cost(chain: Chain, keep) -> float:
    return sum(it.cost for i, it in enumerate(chain.items) if i not in keep)


def plan_peak(chain: Chain, keep) -> float:
    kept = sum(it.size for i, it in enumerate(chain.items) if i in keep)
    run = maxrun = 0.0
    for i, it in enumerate(chain.items):
        if i in keep:
            run = 0.0
        else:
            run += it.size
            maxrun = max(maxrun, run)
    # finalize holds all kept storages at once regardless of the plan
    return max(chain.floor + kept + maxrun, chain.final_bytes)


def _mk(chain: Chain, keep, budget: float, solver: str,
        exact: bool = True, **meta) -> Plan:
    keep = frozenset(keep)
    cost = plan_cost(chain, keep)
    peak = plan_peak(chain, keep)
    meta.setdefault("n", len(chain))
    return Plan(keep, cost, peak, budget, solver,
                feasible=peak <= budget, exact=exact, meta=meta)


# ---------------------------------------------------------------------------
# Chen et al. (2016)
# ---------------------------------------------------------------------------

def chen_sqrt(chain: Chain, budget: float = math.inf) -> Plan:
    """√n segmentation by count: keep every k-th candidate, k = ⌈√n⌉."""
    n = len(chain)
    if n == 0:
        return _mk(chain, (), budget, "chen_sqrt")
    k = max(int(math.ceil(math.sqrt(n))), 1)
    keep = set(range(k - 1, n, k))
    return _mk(chain, keep, budget, "chen_sqrt", k=k)


def chen_greedy(chain: Chain, budget: float) -> Plan:
    """Threshold greedy: drop until the open segment's bytes exceed tau.

    Chen's greedy checkpoints "every b bytes"; with heterogeneous sizes
    the right ``tau`` is not known in closed form, so the solver sweeps
    the distinct candidate thresholds (every prefix-run byte count, plus
    a √(total·mean) pivot) and keeps the cheapest feasible plan.  With no
    feasible threshold it returns the peak-minimizing one, flagged
    infeasible.
    """
    n = len(chain)
    if n == 0:
        return _mk(chain, (), budget, "chen_greedy")
    sizes = [it.size for it in chain.items]
    total = sum(sizes)
    taus = sorted({0.0, total} | {float(s) for s in sizes}
                  | {math.sqrt(total * max(s, 1.0)) for s in sizes})
    best: Optional[Plan] = None
    fallback: Optional[Plan] = None
    for tau in taus:
        keep = set()
        run = 0.0
        for i, m in enumerate(sizes):
            run += m
            if run > tau:
                keep.add(i)
                run = 0.0
        p = _mk(chain, keep, budget, "chen_greedy", tau=tau)
        if fallback is None or p.peak < fallback.peak:
            fallback = p
        if p.feasible and (best is None or p.cost < best.cost):
            best = p
    return best if best is not None else fallback


# ---------------------------------------------------------------------------
# Heterogeneous optimal DP (Beaumont et al., arXiv:1911.13214 regime)
# ---------------------------------------------------------------------------

def _dp(chain: Chain, budget: float,
        max_steps: Optional[int] = None) -> Optional[Plan]:
    """Exact min-cost selection with peak ≤ budget (None if infeasible).

    State: after deciding a prefix ending with kept anchor ``j`` (0 =
    virtual start), a Pareto frontier of (kept_bytes, max_run, cost,
    parent) tuples.  Transition j -> k (keep k, drop j+1..k-1) adds the
    dropped run's cost and folds its bytes into max_run; a final hop to
    the virtual end drops the tail.  Both resources only grow along a
    path, so states with ``floor + kept + maxrun > budget`` prune early.

    Raises ``_StepLimit`` after ``max_steps`` transition steps.
    """
    n = len(chain)
    avail = budget - chain.floor
    if avail < 0 or chain.final_bytes > budget:
        return None
    steps = 0
    sizes = [it.size for it in chain.items]
    costs = [it.cost for it in chain.items]
    pm = [0.0]
    pc = [0.0]
    for m, c in zip(sizes, costs):
        pm.append(pm[-1] + m)
        pc.append(pc[-1] + c)

    # State: (kept_bytes, max_run, cost, anchor, parent_state | None).
    # Parent pointers reference state tuples directly, so dominance pruning
    # (which rewrites frontier lists) cannot invalidate a reconstruction.
    frontier: list[list[tuple]] = [[] for _ in range(n + 2)]
    frontier[0] = [(0.0, 0.0, 0.0, 0, None)]
    exact = True

    def push(j: int, state: tuple) -> None:
        nonlocal steps
        kept, maxrun, cost = state[0], state[1], state[2]
        lst = frontier[j]
        steps += len(lst) + 1
        for s in lst:
            if s[0] <= kept and s[1] <= maxrun and s[2] <= cost:
                return                   # dominated
        lst[:] = [s for s in lst
                  if not (kept <= s[0] and maxrun <= s[1] and cost <= s[2])]
        lst.append(state)

    for j in range(n + 1):               # anchor 0 = start, j = item j-1 kept
        states = frontier[j]
        if not states:
            continue
        if len(states) > MAX_STATES:
            states.sort(key=lambda s: (s[2], s[0], s[1]))
            del states[MAX_STATES:]
            exact = False
        for state in list(states):
            kept, maxrun = state[0], state[1]
            cost = state[2]
            steps += n + 1 - j
            if max_steps is not None and steps > max_steps:
                raise _StepLimit
            for k in range(j + 1, n + 2):
                run_b = pm[min(k - 1, n)] - pm[j]
                run_c = pc[min(k - 1, n)] - pc[j]
                nmax = max(maxrun, run_b)
                if kept + nmax > avail:
                    break                # run bytes only grow with k
                if k <= n:               # keep item k-1
                    if kept + sizes[k - 1] + nmax > avail:
                        continue         # this anchor is too big; later may fit
                    push(k, (kept + sizes[k - 1], nmax, cost + run_c,
                             k, state))
                else:                    # virtual end: tail dropped
                    push(k, (kept, nmax, cost + run_c, k, state))

    end = frontier[n + 1]
    if not end:
        return None
    best = min(end, key=lambda s: (s[2], s[0]))
    keep: set[int] = set()
    node = best[4]                       # skip the virtual-end hop itself
    while node is not None:
        if 1 <= node[3] <= n:
            keep.add(node[3] - 1)
        node = node[4]
    p = _mk(chain, keep, budget, "optimal_dp")
    p.exact = exact
    return p


def _dp_blocks(chain: Chain, budget: float) -> Optional[Plan]:
    """DP on a size-balanced coarsening of a long chain.

    Consecutive items are grouped into at most ``DP_MAX_ITEMS`` blocks of
    roughly equal bytes; the DP keeps or drops whole blocks.  Because
    blocks are consecutive, scoring the expanded keep set on the full
    chain gives exactly the block-level cost and peak — the restriction
    is only over which subsets are reachable, so the answer is feasible
    but possibly suboptimal (``exact=False``).
    """
    n = len(chain)
    sizes = [it.size for it in chain.items]
    target = max(sum(sizes) / DP_MAX_ITEMS, 1.0)
    blocks: list[list[int]] = []
    cur: list[int] = []
    acc = 0.0
    for i, m in enumerate(sizes):
        cur.append(i)
        acc += m
        if acc >= target and len(blocks) < DP_MAX_ITEMS - 1:
            blocks.append(cur)
            cur, acc = [], 0.0
    if cur:
        blocks.append(cur)
    bitems = [ChainItem(sid=-(b + 1),
                        size=sum(chain.items[i].size for i in members),
                        cost=sum(chain.items[i].cost for i in members),
                        producer=chain.items[members[-1]].producer)
              for b, members in enumerate(blocks)]
    bchain = Chain(bitems, chain.floor, chain.base_cost,
                   name=chain.name + "/blocks", n_ops=chain.n_ops,
                   n_candidates_total=chain.n_candidates_total)
    p = _dp(bchain, budget)
    if p is None:
        return None
    keep = {i for b in p.keep for i in blocks[b]}
    out = _mk(chain, keep, budget, "optimal_dp", exact=False,
              coarsened=len(blocks))
    return out


def enumerate_optimal(chain: Chain, budget: float) -> Optional[Plan]:
    """Brute-force subset oracle (differential tests only; n ≤ 20)."""
    n = len(chain)
    assert n <= 20, "enumeration oracle is exponential"
    best: Optional[Plan] = None
    for mask in range(1 << n):
        keep = {i for i in range(n) if mask >> i & 1}
        p = _mk(chain, keep, budget, "enumerate")
        if p.feasible and (best is None or (p.cost, len(p.keep))
                           < (best.cost, len(best.keep))):
            best = p
    return best


def optimal_dp(chain: Chain, budget: float) -> Optional[Plan]:
    """Best known plan at ``budget``: the DP optimum, floored by the Chen
    variants and keep-all (so ``optimal_dp ≤ chen_*`` holds structurally
    even if the DP ever truncates).  None when no selection fits."""
    try:
        dp = _dp(chain, budget, max_steps=DP_MAX_STEPS)
    except _StepLimit:
        dp = None
    blocks = _dp_blocks(chain, budget) if len(chain) > DP_MAX_ITEMS else None
    cands = [p for p in (dp, blocks,
                         chen_greedy(chain, budget),
                         chen_sqrt(chain, budget),
                         _mk(chain, range(len(chain)), budget, "keep_all"))
             if p is not None and p.feasible]
    if not cands:
        return None
    best = min(cands, key=lambda p: (p.cost, len(p.keep)))
    if best.solver != "optimal_dp":
        best = Plan(best.keep, best.cost, best.peak, budget, "optimal_dp",
                    best.feasible, best.exact,
                    dict(best.meta, via=best.solver))
    return best


SOLVERS = {
    "chen_sqrt": chen_sqrt,
    "chen_greedy": chen_greedy,
    "optimal_dp": optimal_dp,
}
