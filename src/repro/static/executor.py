"""Execute a static checkpointing plan through the real DTR runtime.

A compiled plan (``StaticPlan``) is a *drop set* plus each dropped
storage's touch ordinals.  The executor enforces the classic static
semantics — a dropped checkpoint is resident only while an adjacent
operator touches it — by evicting, after every replay call, each dropped
storage whose next touch is not the immediately following op.  This rule
covers both the planned gap entries *and* rebuild remnants: a dropped
storage rematerialized as a dependency of some later gap replay is
dropped again right after that call, instead of lingering resident for
the rest of the run (the failure mode of a fixed ordinal->sids schedule,
whose eviction points cannot anticipate remat-triggered rebuilds).

Plans run through the same ``DTRRuntime`` / ``PoolAllocator`` stack the
online heuristics use — budget unconstrained, victim selection disabled
(``_pick_victim`` raises), every eviction dictated by the plan — so
static and online overheads are measured under identical memory
accounting, remat recursion, and clock rules.

Two consumers must agree bit-for-bit:

* ``execute_plan`` — the real run (``PlanRuntime`` + ``graph.replay``);
* ``evaluate_plan`` — a self-contained symbolic simulator over the
  ``LogView`` event stream that predicts remat ops, evictions, compute
  and peak memory *without* constructing a runtime.

``evaluate_plan`` mirrors the runtime's order of operations exactly
(materialization recursion, allocation points, eager-release evictions,
the post-op drop rule and garbage sweep, finalize), so equality of its
prediction with the executed counters is the differential gate that the
planner's model of the runtime is faithful — any drift in either is a
test failure, not a tolerance.

One rule has no counterpart in the online engine: a storage whose last
RELEASE already happened but that was rematerialized again (as a
dependency of a later gap) will never see another release, so with an
unconstrained budget it would stay resident forever.  After each
scheduled op, both sides sweep these refs-zero revenants (collected at
rematerialization time), charging the evictions to the plan.
"""
from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass
from typing import Mapping, Optional, Sequence

from ..core.graph import Log, replay
from ..core.heuristics import by_name
from ..core.runtime import DTRRuntime
from ..core.simulator import RunResult, make_allocator, result_from_runtime
from .chain import Chain, LogView, build_view, trim_touches


@dataclass(frozen=True)
class StaticPlan:
    """Compiled plan: storages to drop / trim, and when they are touched."""
    drop: tuple[int, ...]                       # sids, sorted
    touches: Mapping[int, tuple[int, ...]]      # sid -> sorted op ordinals
    #: free-tail storages (evicted after their last touch in every plan —
    #: zero remat cost; see ``chain.trim_touches``), sorted, disjoint
    #: from ``drop``
    trim: tuple[int, ...] = ()

    def next_touch(self, sid: int, k: int) -> Optional[int]:
        """First touch ordinal strictly after op ``k`` (None if exhausted)."""
        ts = self.touches[sid]
        i = bisect_right(ts, k)
        return ts[i] if i < len(ts) else None


def compile_plan(view: LogView, chain: Chain,
                 keep: frozenset[int] | set[int] | Sequence[int]
                 ) -> StaticPlan:
    """Compile a solver selection (``keep`` = item indices into
    ``chain.items``) into an executable drop plan."""
    keep = set(keep)
    drop = sorted(it.sid for i, it in enumerate(chain.items)
                  if i not in keep)
    touches = {}
    for sid in drop:
        s = view.storages[sid]
        ts = set(s.uses) | ({s.producer} if s.producer is not None
                            else set())
        if s.kept:                  # finalize rematerializes it once more
            ts.add(view.n_ops)
        touches[sid] = tuple(sorted(ts))
    trims = trim_touches(view)
    trim = tuple(sid for sid in sorted(trims) if sid not in touches)
    for sid in trim:
        touches[sid] = trims[sid]
    return StaticPlan(tuple(drop), touches, trim)


class PlanRuntime(DTRRuntime):
    """DTRRuntime with victim selection disabled and plan-driven evictions.

    The budget is unconstrained so the admission loop never looks for a
    victim; ``_pick_victim`` raises to guarantee the heuristic is
    structurally out of the loop (any call would be a bug, not a silent
    fallback to online behaviour).
    """

    def __init__(self, plan: StaticPlan, allocator=None) -> None:
        super().__init__(budget=float("inf"), heuristic=by_name("h_lru"),
                         dealloc="eager", index=False, allocator=allocator)
        self._plan = plan
        self._ordinal = 0               # replay-level call index
        self._in_call = False
        self._garbage: set[int] = set() # rematted storages with refs <= 0
        self.forced_evictions = 0
        self.trimmed = 0
        self.swept = 0

    def _pick_victim(self, exclude):
        raise AssertionError(
            "static plan execution must never consult the online heuristic")

    def _on_remat(self, s):
        super()._on_remat(s)
        if s.refs <= 0:
            self._garbage.add(s.sid)

    def call(self, op_name, cost, input_tids, out_sizes,
             aliases=None, out_names=None):
        if self._in_call:        # a remat replay inside _ensure_defined
            return super().call(op_name, cost, input_tids, out_sizes,
                                aliases=aliases, out_names=out_names)
        self._in_call = True
        try:
            out = super().call(op_name, cost, input_tids, out_sizes,
                               aliases=aliases, out_names=out_names)
        finally:
            self._in_call = False
        k = self._ordinal
        self._ordinal += 1
        self._sweep(k)
        return out

    def _sweep(self, k: int) -> None:
        # Drop rule: a dropped storage stays resident only into an
        # immediately adjacent touch.
        for sid in self._plan.drop:
            s = self.storages.get(sid)
            if s is None or not s.resident or not s.evictable():
                continue
            nt = self._plan.next_touch(sid, k)
            if nt is None or nt > k + 1:
                self._evict(s)
                self.forced_evictions += 1
        # Trim rule: a free-tail storage is evicted once it is past its
        # last touch — no future touch means no remat can ever follow.
        for sid in self._plan.trim:
            s = self.storages.get(sid)
            if s is None or not s.resident or not s.evictable():
                continue
            if self._plan.next_touch(sid, k) is None:
                self._evict(s)
                self.trimmed += 1
        if self._garbage:
            for sid in sorted(self._garbage):
                s = self.storages[sid]
                if s.refs <= 0 and s.evictable():
                    self._evict(s)
                    self.swept += 1
            self._garbage.clear()

    def finalize(self) -> None:
        # Rebuild finalize-kept tensors one at a time, sweeping dropped
        # rebuild dependencies between them: one concurrent remat cone
        # instead of all of them at once.  Mirrors DTRRuntime.finalize
        # (refs > 0 -> ensure + lock) with a sweep after each ensure;
        # locked storages are not evictable, so already-finalized kept
        # tensors survive the sweeps.
        k = self._ordinal               # == n_ops: every touch is past
        for t in list(self.tensors.values()):
            if t.refs > 0 and not self.storages[t.sid].banished:
                self._ensure_defined([t.tid])
                self.storages[t.sid].locks += 1
                self._sweep(k)


def execute_plan(log: Log, plan: StaticPlan,
                 alloc_mode: Optional[str] = None) -> RunResult:
    """Replay ``log`` with evictions forced by ``plan``.

    Returns a standard ``RunResult`` (``budget`` is reported as ``inf``:
    feasibility against a byte budget is judged by comparing
    ``peak_memory`` to it, exactly like the honest fig3 feasibility
    check).
    """
    rt = PlanRuntime(plan, allocator=make_allocator(alloc_mode))
    replay(log, rt)
    return result_from_runtime(rt, budget=float("inf"), ok=True)


# ---------------------------------------------------------------------------
# Symbolic evaluator (the runtime mirror)
# ---------------------------------------------------------------------------

@dataclass
class PlanEval:
    """Predicted execution profile of a plan (must equal the real run)."""
    remat_ops: int
    evictions: int
    compute: float
    base_compute: float
    peak_memory: float
    ops_executed: int

    @property
    def overhead(self) -> float:
        return self.compute / max(self.base_compute, 1e-12)


def evaluate_plan(view: LogView, plan: StaticPlan) -> PlanEval:
    """Predict ``execute_plan``'s counters from the ``LogView`` alone.

    Bit-exact mirror of the runtime path: same float-summation order for
    compute (ops perform in the same sequence), same integer byte
    arithmetic for memory, same eviction triggers (eager release, the
    drop rule, garbage sweep, finalize remats).
    """
    n_t = len(view.tensors)
    n_s = len(view.storages)
    defined = [False] * n_t
    resident = [False] * n_s
    tref = [0] * n_t
    sref = [0] * n_s
    locked = [False] * n_s          # finalize locks (mirror of s.locks)
    sizes = [s.size for s in view.storages]
    const = [s.constant for s in view.storages]
    garbage: set[int] = set()

    mem = 0.0
    peak = 0.0
    compute = 0.0
    base = 0.0
    remats = 0
    evictions = 0
    executed = 0

    tensors = view.tensors
    ops = view.ops

    def evict(sid: int) -> None:
        nonlocal mem, evictions
        resident[sid] = False
        for tid in view.storages[sid].tids:
            defined[tid] = False
        mem -= sizes[sid]
        evictions += 1

    def perform(k: int, first: bool) -> None:
        nonlocal mem, peak, compute, base, remats, executed
        op = ops[k]
        need = 0
        placed = []
        for tid in op.out_tids:
            t = tensors[tid]
            if not t.is_alias and not resident[t.sid]:
                need += sizes[t.sid]
                placed.append(t.sid)
        mem += need
        peak = max(peak, mem)
        for sid in placed:
            resident[sid] = True
            if not first and sref[sid] <= 0:
                garbage.add(sid)
        for tid in op.out_tids:
            if resident[tensors[tid].sid]:
                defined[tid] = True
        compute += op.cost
        executed += 1
        if first:
            base += op.cost
        else:
            remats += 1

    def ensure(tid: int) -> None:
        # Iterative mirror of DTRRuntime._ensure_defined: frames push their
        # undefined inputs in order and pop LIFO, so ops perform in the
        # exact sequence (and float-sum order) the runtime uses.
        if defined[tid]:
            return
        stack = [tid]
        while stack:
            t = stack[-1]
            if defined[t]:
                stack.pop()
                continue
            k = tensors[t].oid
            assert k is not None, "evaluator reached an evicted constant"
            undef = [u for u in ops[k].in_tids if not defined[u]]
            if undef:
                stack.extend(undef)
                continue
            perform(k, first=False)
            stack.pop()

    def release(tid: int) -> None:
        tref[tid] -= 1
        sid = tensors[tid].sid
        sref[sid] -= 1
        if sref[sid] <= 0 and not const[sid] and resident[sid]:
            evict(sid)

    def sweep(k: int) -> None:
        for sid in plan.drop:
            if not resident[sid] or const[sid] or locked[sid]:
                continue
            nt = plan.next_touch(sid, k)
            if nt is None or nt > k + 1:
                evict(sid)
        for sid in plan.trim:
            if not resident[sid] or const[sid] or locked[sid]:
                continue
            if plan.next_touch(sid, k) is None:
                evict(sid)
        if garbage:
            for sid in sorted(garbage):
                if (sref[sid] <= 0 and resident[sid] and not const[sid]
                        and not locked[sid]):
                    evict(sid)
            garbage.clear()

    for ev in view.events:
        kind = ev[0]
        if kind == "const":
            sid = ev[1]
            tid = view.storages[sid].tids[0]
            tref[tid] += 1
            sref[sid] += 1
            resident[sid] = True
            defined[tid] = True
            mem += sizes[sid]
            peak = max(peak, mem)
        elif kind == "op":
            k = ev[1]
            op = ops[k]
            for tid in op.out_tids:
                tref[tid] += 1
                sref[tensors[tid].sid] += 1
            for u in op.in_tids:
                ensure(u)
            perform(k, first=True)
            sweep(k)
        elif kind == "rel":
            release(ev[1])
        elif kind == "addref":
            tid = ev[1]
            tref[tid] += 1
            sref[tensors[tid].sid] += 1
        else:                            # pragma: no cover
            raise AssertionError(f"unknown event {ev!r}")

    # finalize(): every externally referenced tensor is rematerialized and
    # locked, one at a time, with a sweep between rebuilds (mirror of
    # PlanRuntime.finalize).
    n_ops = view.n_ops
    for tid in range(n_t):
        if tref[tid] > 0:
            ensure(tid)
            locked[tensors[tid].sid] = True
            sweep(n_ops)

    return PlanEval(remat_ops=remats, evictions=evictions, compute=compute,
                    base_compute=base, peak_memory=peak,
                    ops_executed=executed)


def predict_and_execute(log: Log, view: LogView | None, plan: StaticPlan,
                        alloc_mode: Optional[str] = None
                        ) -> tuple[PlanEval, RunResult]:
    """Convenience: evaluator prediction + real execution of one plan."""
    if view is None:
        view = build_view(log)
    return evaluate_plan(view, plan), execute_plan(log, plan,
                                                   alloc_mode=alloc_mode)
