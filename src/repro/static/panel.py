"""Eval-guided static plan panel: the honest DTR-vs-static comparison.

The chain model (``solvers.py``) is exact on chain-shaped traces but can
be arbitrarily wrong on DAGs: dropping a storage whose rebuild cone
threads the weight-update chain replays half the trace, and the model
cannot see that.  The panel therefore treats solver plans as *proposals*
and judges every plan with the exact evaluator (``evaluate_plan``, the
bit-exact runtime mirror):

1. **Solo screen** — evaluate each candidate's drop in isolation against
   the trim-only baseline; candidates whose solo drop *raises* the real
   peak (cascade-toxic) are excluded from the greedy.
2. **Greedy frontier** — walk the safe candidates (best measured peak
   reduction first), accumulating drops that still reduce the evaluated
   peak; every accepted step yields a (peak, compute, keep) point.
3. **Per-budget selection** — pool the frontier points with solver
   proposals (heterogeneous DP, both Chen variants, keep-all) evaluated
   at each budget; a plan is feasible iff its *evaluated* peak fits the
   budget, and the cheapest feasible plan wins.  Solver proposals are
   pooled across budgets, so the winning cost is monotone non-increasing
   in the budget by construction.

Every number reported for the winner is an exact prediction of what
``execute_plan`` does through the real runtime (the parity gate in the
tests enforces this bit-for-bit), so DTR rows and static rows in a
benchmark table share one accounting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from .chain import Chain, LogView
from .executor import PlanEval, StaticPlan, compile_plan, evaluate_plan
from .solvers import chen_greedy, chen_sqrt, optimal_dp

#: Solver proposals are generated at these fractions of each budget —
#: the model's peak is optimistic on DAGs, so planning against a tighter
#: model budget often lands the *evaluated* peak under the real one.
MU_LADDER = (1.0, 0.85, 0.7)


@dataclass
class PlanPoint:
    """One evaluated plan: the frontier/selection currency of the panel."""
    keep: frozenset[int]            # chain item indices kept
    ev: PlanEval                    # exact evaluator profile
    source: str                     # "trim_only" | "greedy" | solver name

    @property
    def peak(self) -> float:
        return self.ev.peak_memory

    @property
    def compute(self) -> float:
        return self.ev.compute

    @property
    def overhead(self) -> float:
        return self.ev.overhead


@dataclass
class Frontier:
    """Trim baseline + greedy peak/compute tradeoff points + pooled
    solver proposals (grows as budgets are queried)."""
    points: list[PlanPoint]
    n_safe: int                     # candidates whose solo drop helped
    n_toxic: int                    # candidates excluded by the screen

    def min_peak(self) -> float:
        return min(p.peak for p in self.points)


def _point(view: LogView, chain: Chain, keep, source: str) -> PlanPoint:
    keep = frozenset(keep)
    return PlanPoint(keep, evaluate_plan(view, compile_plan(view, chain,
                                                            keep)), source)


def build_frontier(view: LogView, chain: Chain,
                   max_screen: int = 512) -> Frontier:
    """Solo-screen all candidates, then grow a greedy drop frontier.

    ``max_screen`` caps the screening work on very long chains (largest
    candidates are screened first; the tail is treated as toxic, which
    only costs plan quality, never correctness).
    """
    n = len(chain)
    allk = frozenset(range(n))
    base = _point(view, chain, allk, "trim_only")
    points = [base]
    if n == 0:
        return Frontier(points, 0, 0)

    order = sorted(range(n), key=lambda i: (-chain.items[i].size, i))
    screened = order[:max_screen]
    solo = []
    for i in screened:
        ev = evaluate_plan(view, compile_plan(view, chain, allk - {i}))
        solo.append((ev.peak_memory - base.peak, ev.compute - base.compute,
                     i))
    safe = sorted((s for s in solo if s[0] < 0))
    n_toxic = len(solo) - len(safe)

    cur: set[int] = set()
    cur_peak = base.peak
    for _, _, i in safe:
        keep = allk - cur - {i}
        ev = evaluate_plan(view, compile_plan(view, chain, keep))
        if ev.peak_memory < cur_peak:
            cur.add(i)
            cur_peak = ev.peak_memory
            points.append(PlanPoint(frozenset(keep), ev, "greedy"))
    return Frontier(points, len(safe), n_toxic)


def _solver_proposals(chain: Chain, budget: float):
    """(source, keep) proposals from the model-level solvers at ``budget``."""
    out = []
    for mu in MU_LADDER:
        p = optimal_dp(chain, mu * budget)
        if p is not None:
            out.append((f"optimal_dp@{mu:g}", p.keep))
    out.append(("chen_sqrt", chen_sqrt(chain, budget).keep))
    out.append(("chen_greedy", chen_greedy(chain, budget).keep))
    return out


def best_static_plan(view: LogView, chain: Chain, frontier: Frontier,
                     budget: float) -> Optional[PlanPoint]:
    """Cheapest plan whose *evaluated* peak fits ``budget`` (None if no
    known plan fits).  Solver proposals generated for this budget are
    pooled into the frontier, so later (smaller) budgets see them too
    and the winning compute is monotone in the budget."""
    seen = {p.keep for p in frontier.points}
    for source, keep in _solver_proposals(chain, budget):
        keep = frozenset(keep)
        if keep in seen:
            continue
        seen.add(keep)
        frontier.points.append(_point(view, chain, keep, source))
    feas = [p for p in frontier.points if p.peak <= budget]
    if not feas:
        return None
    return min(feas, key=lambda p: (p.compute, len(p.keep) - len(chain)))


def compile_point(view: LogView, chain: Chain,
                  point: PlanPoint) -> StaticPlan:
    """The executable plan for a selected panel point."""
    return compile_plan(view, chain, point.keep)


def static_panel(view: LogView, chain: Chain, budgets: Sequence[float]
                 ) -> tuple[Frontier, dict[float, Optional[PlanPoint]]]:
    """Best static plan per budget (largest budget first, pooled plans)."""
    frontier = build_frontier(view, chain)
    out: dict[float, Optional[PlanPoint]] = {}
    for b in sorted(budgets, reverse=True):
        out[b] = best_static_plan(view, chain, frontier, b)
    return frontier, out
