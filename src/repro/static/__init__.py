"""Static checkpointing planners over captured traces (``repro.static``).

The Checkmate bridge: extract a heterogeneous checkpointing chain from a
``core.graph.Log`` (``chain``), plan on it with Chen segmentation / Chen
greedy / the heterogeneous optimal DP (``solvers``), floor every budget
cell with an LP relaxation over the full DAG (``lpbound``), and replay
plans through the real DTR runtime with the heuristic disabled
(``executor``) so static and online overheads share one accounting.
"""
from .chain import (Chain, ChainItem, LogView, build_view, extract_chain,
                    synthetic_chain, trim_touches)
from .executor import (PlanEval, PlanRuntime, StaticPlan, compile_plan,
                       evaluate_plan, execute_plan, predict_and_execute)
from .lpbound import LPBound, lp_lower_bound
from .panel import (Frontier, PlanPoint, best_static_plan, build_frontier,
                    compile_point, static_panel)
from .solvers import (SOLVERS, Plan, chen_greedy, chen_sqrt,
                      enumerate_optimal, optimal_dp, plan_cost, plan_peak)

__all__ = [
    "Chain", "ChainItem", "LogView", "build_view", "extract_chain",
    "synthetic_chain", "trim_touches",
    "Plan", "SOLVERS", "chen_greedy", "chen_sqrt", "enumerate_optimal",
    "optimal_dp", "plan_cost", "plan_peak",
    "LPBound", "lp_lower_bound",
    "PlanEval", "PlanRuntime", "StaticPlan", "compile_plan",
    "evaluate_plan", "execute_plan", "predict_and_execute",
    "Frontier", "PlanPoint", "best_static_plan", "build_frontier",
    "compile_point", "static_panel",
]
