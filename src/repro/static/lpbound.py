"""LP-relaxation lower bound on recompute cost over the full op DAG.

Checkmate (arXiv:1910.02653) lower-bounds any rematerialization schedule
with the LP relaxation of its ILP.  An ILP solver is unavailable in this
container, so we use the *fractional covering* core of that relaxation,
which needs no integer machinery and stays valid for every execution that
follows the trace's operator order — online DTR runs and executed static
plans alike:

* variable ``z_s ∈ [0,1]`` per potentially-evictable storage — "was ``s``
  ever dropped while still needed later";
* at each *pinch* op ``t`` whose must-resident bytes exceed the budget
  ``B``, the bytes shed must cover the deficit:
  ``Σ_{s ∈ L_t} m_s z_s ≥ need_t − B``;
* objective ``min Σ c_s z_s`` where ``c_s`` lower-bounds the recompute
  price of dropping ``s`` (its producer's cost, split across the
  producer's owning outputs — one replay revives all siblings, so each
  may only claim its share).

``L_t`` contains storages produced at or before ``t`` with a touch
strictly after ``t`` (so dropping them implies a later replay), excluding
constants and op ``t``'s own tensors (those are unsheddable at ``t`` and
counted in ``need_t``); storages past their last touch shed for free and
appear in neither side.  Any feasible schedule induces a 0/1 assignment
satisfying every constraint with cost ≤ its true recompute cost, hence
the LP optimum is a valid floor.  Dropping constraints only loosens the
bound, so the constraint set is capped at the deepest deficits.

Solvers: ``scipy.optimize.linprog`` (method="highs") when importable —
the exact LP optimum; otherwise a greedy dual-feasible ascent (process
pinches by descending deficit, raise each dual price to the tightest
remaining ratio ``c_s / m_s``) — a weaker but still valid bound by weak
duality, reported with ``exact=False``.
"""
from __future__ import annotations

from dataclasses import dataclass

from .chain import LogView

#: Constraint cap: pinch ops are ranked by deficit and only the deepest
#: this-many enter the LP (a pure relaxation — the bound stays valid).
MAX_CONSTRAINTS = 128


@dataclass
class LPBound:
    """Lower bound on extra recompute cost at one byte budget."""
    value: float                    # Σ c_s z_s floor (0.0 when unconstrained)
    exact: bool                     # True: LP optimum; False: dual-greedy
    infeasible: bool                # some pinch cannot be covered at all
    n_vars: int
    n_constraints: int
    solver: str                     # "scipy" | "dual_greedy" | "trivial"

    def overhead_floor(self, base_cost: float) -> float:
        return (base_cost + self.value) / max(base_cost, 1e-12)


def _touches(view: LogView):
    """Per-storage sorted touch ordinals (producer, uses, finalize)."""
    n = view.n_ops
    out = []
    for s in view.storages:
        t = list(s.uses)
        if s.producer is not None:
            t.append(s.producer)
        if s.kept:
            t.append(n)             # finalize materializes it once more
        out.append(sorted(set(t)))
    return out

def _remat_price(view: LogView) -> list[float]:
    """c_s: producer cost split across the producer's owning outputs."""
    owners: dict[int, int] = {}
    for s in view.storages:
        if s.producer is not None and s.size > 0:
            owners[s.producer] = owners.get(s.producer, 0) + 1
    price = []
    for s in view.storages:
        if s.producer is None or s.size <= 0:
            price.append(0.0)
        else:
            price.append(s.producer_cost / owners[s.producer])
    return price


def lp_lower_bound(view: LogView, budget: float) -> LPBound:
    """Valid recompute-cost floor for any order-preserving schedule at
    ``budget`` bytes (eager/ignore deallocation; constants resident)."""
    n = view.n_ops
    touches = _touches(view)
    price = _remat_price(view)

    # Build, per op t: must-resident bytes and the sheddable live set L_t.
    # A storage is *fixed* at t when t is one of its touches (inputs/
    # outputs of op t must be resident) or it is a constant; it is
    # *flexible* (in L_t) between touches.  Difference arrays give the
    # fixed/flexible byte profiles in O(storages + touches).
    const_bytes = sum(s.size for s in view.storages if s.constant)

    flex_delta = [0.0] * (n + 1)
    fixed_at: dict[int, float] = {}
    cand: list[int] = []            # storages that are ever flexible
    for s in view.storages:
        if s.constant or s.size <= 0 or s.producer is None:
            continue
        ts = touches[s.sid]
        for t in ts:
            if t < n:
                fixed_at[t] = fixed_at.get(t, 0) + s.size
        flexible = False
        for a, b in zip(ts, ts[1:]):
            if b - a >= 2:          # live-but-untouched span (a, b)
                flex_delta[a + 1] += s.size
                flex_delta[min(b, n)] -= s.size
                flexible = True
        if flexible:
            cand.append(s.sid)

    deficits: list[tuple[float, int]] = []
    acc = 0.0
    for t in range(n):
        acc += flex_delta[t]
        need = const_bytes + fixed_at.get(t, 0.0) + acc
        if need > budget:
            deficits.append((need - budget - acc, t))  # store fixed-side gap
    if not deficits or not cand:
        if deficits:                # pressure exists but nothing sheddable
            return LPBound(float("inf"), True, True, 0, len(deficits),
                           "trivial")
        return LPBound(0.0, True, False, len(cand), 0, "trivial")

    # Keep the deepest pinches (by full deficit need - budget).
    full = sorted(((fd + _flex_at(view, touches, cand, t), t)
                   for fd, t in deficits), reverse=True)
    # _flex_at recomputes Σ L_t; equivalent to acc at t but explicit per
    # retained constraint so rows and right-hand sides cannot drift.
    rows: list[tuple[int, dict[int, float], float]] = []
    for need_minus_b, t in full[:MAX_CONSTRAINTS]:
        members = _live_set(view, touches, cand, t)
        d = need_minus_b
        if d <= 0:
            continue
        cover = sum(view.storages[sid].size for sid in members)
        if cover < d - 1e-9:
            return LPBound(float("inf"), True, True, len(cand),
                           len(rows) + 1, "trivial")
        rows.append((t, {sid: float(view.storages[sid].size)
                         for sid in members}, d))
    if not rows:
        return LPBound(0.0, True, False, len(cand), 0, "trivial")

    var_ids = sorted({sid for _, mem, _ in rows for sid in mem})
    bound, exact, solver = _solve(rows, var_ids, price)
    return LPBound(bound, exact, False, len(var_ids), len(rows), solver)


def _flex_at(view: LogView, touches, cand, t: int) -> float:
    return sum(view.storages[sid].size
               for sid in _live_set(view, touches, cand, t))


def _live_set(view: LogView, touches, cand, t: int) -> list[int]:
    """Members of L_t: flexible (live, untouched, needed-later) at op t."""
    import bisect
    out = []
    for sid in cand:
        ts = touches[sid]
        i = bisect.bisect_right(ts, t)
        # live span (prev touch, next touch) strictly containing t
        if 0 < i < len(ts) and ts[i - 1] < t < ts[i]:
            out.append(sid)
    return out


def _solve(rows, var_ids, price) -> tuple[float, bool, str]:
    try:
        import numpy as np
        from scipy.optimize import linprog
    except ImportError:
        return _dual_greedy(rows, var_ids, price), False, "dual_greedy"
    idx = {sid: i for i, sid in enumerate(var_ids)}
    c = np.array([price[sid] for sid in var_ids])
    A = np.zeros((len(rows), len(var_ids)))
    b = np.zeros(len(rows))
    for r, (_, mem, d) in enumerate(rows):
        for sid, m in mem.items():
            A[r, idx[sid]] = -m
        b[r] = -d
    res = linprog(c, A_ub=A, b_ub=b, bounds=[(0.0, 1.0)] * len(var_ids),
                  method="highs")
    if not res.success:             # numerical trouble: fall back, stay valid
        return _dual_greedy(rows, var_ids, price), False, "dual_greedy"
    return float(res.fun), True, "scipy"


def _dual_greedy(rows, var_ids, price) -> float:
    """Dual-feasible ascent: a valid (weaker) floor without scipy.

    Relaxing the z ≤ 1 caps gives a pure covering LP whose dual asks for
    prices ``y_t ≥ 0`` with ``Σ_t m_s y_t ≤ c_s``; any feasible ``y``
    yields the bound ``Σ_t d_t y_t`` by weak duality (and dropping the
    caps only lowers the optimum, so the bound transfers).  Greedy:
    biggest deficits first, each priced at the tightest remaining
    ``c_s / m_s`` over its members.
    """
    slack = {sid: price[sid] for sid in var_ids}
    bound = 0.0
    for _, mem, d in sorted(rows, key=lambda r: (-r[2], r[0])):
        y = min((slack[sid] / m for sid, m in mem.items() if m > 0),
                default=0.0)
        if y <= 0:
            continue
        bound += d * y
        for sid, m in mem.items():
            slack[sid] -= y * m
    return bound
