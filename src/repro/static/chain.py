"""Linearize a captured ``core.graph.Log`` into a checkpointing chain.

Static planners (Chen segmentation, the heterogeneous optimal DP — see
``solvers.py``) operate on a *chain* abstraction: an ordered list of
checkpoint candidates, each carrying real bytes and the real cost of the
operator segment that produces it.  This module extracts that chain from a
trace:

* ``LogView`` interprets the instruction stream once (mirroring
  ``graph.replay``'s environment handling of CALL/MUTATE/COPY/COPYFROM/
  RELEASE, via the shared ``parse_call_block``) into flat op/tensor/storage
  tables plus per-storage liveness intervals in *op-ordinal* time — the
  substrate shared by the chain extractor, the LP lower bound
  (``lpbound.py``) and the plan evaluator/executor (``executor.py``).

* ``extract_chain`` selects the checkpoint candidate set.  The classic
  construction uses articulation points of the op DAG (cuts crossed by a
  single storage); on captured fwd+bwd traces every forward cut is crossed
  by the whole saved-activation front, so the candidate set generalizes to
  the storages that *span* a cut — storages held across at least one
  operator that does not touch them (a "far" use).  Each candidate carries
  the byte size it pins across its gap and the cost of the operator segment
  separating it from the previous candidate; an articulation point is the
  special case where the candidate is the only storage crossing its cut.

Storages that survive to ``finalize`` (gradients/loss — the output
condition) and constants (pinned weights) are never candidates: they are an
unevictable residency floor shared by every plan, online or static.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from ..core.graph import (Call, Constant, Copy, CopyFrom, Log, Memory,
                          Mutate, Release, parse_call_block)

#: Default cap on chain length: the biggest-byte candidates are kept as
#: chain items (they dominate the memory planning problem); the tail is
#: folded into the always-resident floor.
MAX_CANDIDATES = 128


@dataclass
class OpV:
    """One executed operator (CALL, or the copy-on-write rewrite of MUTATE)."""
    k: int                          # op ordinal (replay call index)
    name: str
    cost: float
    in_tids: tuple[int, ...]
    out_tids: tuple[int, ...]


@dataclass
class TensorV:
    tid: int
    sid: int
    oid: Optional[int]              # producer op ordinal; None for constants
    is_alias: bool


@dataclass
class StorageV:
    sid: int
    size: int
    constant: bool = False
    producer: Optional[int] = None  # op ordinal that creates the storage
    producer_cost: float = 0.0      # that op's cost (remat lower bound)
    tids: list[int] = field(default_factory=list)
    uses: list[int] = field(default_factory=list)   # op ordinals consuming it
    death: Optional[int] = None     # refs hit 0 after this op ordinal
    kept: bool = False              # externally referenced at finalize


@dataclass
class LogView:
    """Flat, analysis-friendly interpretation of a log."""
    name: str
    ops: list[OpV]
    tensors: list[TensorV]
    storages: list[StorageV]
    #: replay-ordered event stream: ("const", sid) | ("op", k) |
    #: ("rel", tid) | ("addref", tid) — exactly the runtime calls
    #: ``graph.replay`` makes, so a symbolic simulation over these events
    #: reproduces the runtime's accounting decision-for-decision.
    events: list[tuple]

    @property
    def n_ops(self) -> int:
        return len(self.ops)

    def base_cost(self) -> float:
        return sum(o.cost for o in self.ops)

    # -- liveness -----------------------------------------------------------
    def live_interval(self, s: StorageV) -> tuple[int, int]:
        """[first, last] op ordinals during which ``s`` occupies memory.

        Constants are live from op 0; a storage is live *during* its
        producer op (outputs are allocated before the op is charged) and
        until the op after which its refcount hits zero (eager dealloc) —
        or to the end of the trace when it survives to finalize.
        """
        start = 0 if s.producer is None else s.producer
        if s.kept or s.death is None:
            end = self.n_ops - 1
        else:
            end = max(s.death, start)
        return start, end

    def live_bytes(self) -> list[float]:
        """Bytes resident at each op ordinal under unconstrained replay."""
        n = self.n_ops
        delta = [0.0] * (n + 1)
        for s in self.storages:
            if s.size <= 0:
                continue
            a, b = self.live_interval(s)
            delta[a] += s.size
            delta[b + 1] -= s.size
        out, acc = [], 0.0
        for t in range(n):
            acc += delta[t]
            out.append(acc)
        return out


def build_view(log: Log) -> LogView:
    """One symbolic pass over ``log``, mirroring ``graph.replay``.

    Storage/tensor ids are assigned in the exact order ``DTRRuntime``
    assigns them during a replay, so a plan compiled against this view
    addresses runtime storages by sid directly.
    """
    ops: list[OpV] = []
    tensors: list[TensorV] = []
    storages: list[StorageV] = []
    events: list[tuple] = []
    env: dict[str, int] = {}        # log tensor name -> tid
    refs: dict[int, int] = {}       # sid -> external refcount

    def new_tensor(sid: int, oid: Optional[int], is_alias: bool) -> int:
        tid = len(tensors)
        tensors.append(TensorV(tid, sid, oid, is_alias))
        storages[sid].tids.append(tid)
        refs[sid] = refs.get(sid, 0) + 1
        return tid

    def new_storage(size: int, constant: bool = False,
                    producer: Optional[int] = None,
                    producer_cost: float = 0.0) -> int:
        sid = len(storages)
        storages.append(StorageV(sid, int(size), constant=constant,
                                 producer=producer,
                                 producer_cost=producer_cost))
        return sid

    def do_release(tid: int) -> None:
        sid = tensors[tid].sid
        refs[sid] -= 1
        events.append(("rel", tid))
        if refs[sid] <= 0 and not storages[sid].constant:
            storages[sid].death = len(ops) - 1

    def do_call(inputs: Sequence[str], out_specs, cost: float, name: str,
                out_names: Sequence[str]) -> None:
        k = len(ops)
        in_tids = tuple(env[x] for x in inputs)
        out_tids = []
        for (size, alias_of), nm in zip(out_specs, out_names):
            if alias_of is not None:
                sid = tensors[env[alias_of]].sid
            else:
                sid = new_storage(size, producer=k, producer_cost=cost)
            out_tids.append(new_tensor(sid, k, alias_of is not None))
            env[nm] = out_tids[-1]
        ops.append(OpV(k, name, float(cost), in_tids, tuple(out_tids)))
        events.append(("op", k))
        for sid in sorted({tensors[t].sid for t in in_tids}):
            u = storages[sid].uses
            if not u or u[-1] != k:
                u.append(k)

    i, instrs, n = 0, log.instrs, len(log.instrs)
    while i < n:
        ins = instrs[i]
        if isinstance(ins, Constant):
            mem = instrs[i + 1]
            assert isinstance(mem, Memory) and mem.t == ins.t
            sid = new_storage(mem.size, constant=True)
            env[ins.t] = new_tensor(sid, None, False)
            events.append(("const", sid))
            i += 2
            continue
        if isinstance(ins, Call):
            sizes, alias_names, j = parse_call_block(instrs, i)
            do_call(ins.inputs, list(zip(sizes, alias_names)), ins.cost,
                    ins.op, ins.outputs)
            i = j
            continue
        if isinstance(ins, Mutate):
            # Copy-on-write rewrite: fresh non-alias versions sized like the
            # mutated tensors (0 for alias views), then old versions drop.
            old = [env[t] for t in ins.mutated]
            out_sizes = [0 if tensors[t].is_alias
                         else storages[tensors[t].sid].size for t in old]
            do_call(ins.inputs, [(sz, None) for sz in out_sizes], ins.cost,
                    ins.op + "_mut", [t + "'" for t in ins.mutated])
            for t, tid in zip(ins.mutated, old):
                do_release(tid)
                # env already remapped by do_call (name + "'"); restore the
                # original name binding the way replay does.
                env[t] = env[t + "'"]
            i += 1
            continue
        if isinstance(ins, Copy):
            tid = env[ins.t_in]
            env[ins.t_out] = tid
            refs[tensors[tid].sid] += 1
            events.append(("addref", tid))
            i += 1
            continue
        if isinstance(ins, CopyFrom):
            do_release(env[ins.t_out])
            tid = env[ins.t_in]
            refs[tensors[tid].sid] += 1
            events.append(("addref", tid))
            env[ins.t_out] = tid
            i += 1
            continue
        if isinstance(ins, Release):
            do_release(env[ins.t])
            i += 1
            continue
        i += 1  # stray Memory/Alias already consumed

    for s in storages:
        if refs.get(s.sid, 0) > 0 and not s.constant:
            s.kept = True
    return LogView(log.name, ops, tensors, storages, events)


# ---------------------------------------------------------------------------
# Chain extraction
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChainItem:
    """One checkpoint candidate."""
    sid: int
    size: float
    cost: float                     # segment cost x number of far gaps
    producer: int                   # producing op ordinal
    #: op ordinals after which a dropped candidate is force-evicted (the
    #: last touch before each far gap); empty for synthetic chains.
    evict_positions: tuple[int, ...] = ()
    #: live-as-kept interval (floor accounting)
    live: tuple[int, int] = (0, 0)


@dataclass
class Chain:
    """Checkpointing chain: candidates in production order + shared floor."""
    items: list[ChainItem]
    #: bytes resident regardless of the plan (constants, finalize-kept
    #: transients, non-candidate tail) — max over op ordinals of the
    #: non-candidate live profile.
    floor: float
    base_cost: float
    name: str = "chain"
    n_ops: int = 0
    n_candidates_total: int = 0     # before the MAX_CANDIDATES cap
    #: bytes every strategy holds at finalize (constants + all kept
    #: storages, locked simultaneously) — a hard peak floor even for
    #: plans that drop kept candidates mid-trace and remat them at the
    #: end.
    final_bytes: float = 0.0

    def __len__(self) -> int:
        return len(self.items)

    def total_bytes(self) -> float:
        return sum(it.size for it in self.items)


def synthetic_chain(costs: Sequence[float], sizes: Sequence[float],
                    floor: float = 0.0, name: str = "synthetic") -> Chain:
    """Model-level chain for solver tests (no underlying log)."""
    assert len(costs) == len(sizes)
    items = [ChainItem(sid=i, size=float(m), cost=float(c), producer=i)
             for i, (c, m) in enumerate(zip(costs, sizes))]
    return Chain(items, float(floor), base_cost=float(sum(costs)), name=name,
                 n_ops=len(items), n_candidates_total=len(items))


def _far_gaps(s: StorageV, n_ops: int) -> list[tuple[int, int]]:
    """(last touch, far use) pairs: spans crossing >= 1 untouching op.

    A finalize-kept storage is touched once more at ordinal ``n_ops``
    (the runtime rematerializes it in ``finalize()``), so a gap before
    the end counts — dropping it there costs a finalize replay.
    """
    touches = ([s.producer] if s.producer is not None else []) + s.uses
    if s.kept:
        touches = touches + [n_ops]
    return [(a, b) for a, b in zip(touches, touches[1:]) if b - a >= 2]


def _last_touch(s: StorageV) -> int:
    return max([s.producer] + s.uses) if s.producer is not None else 0


def _has_free_tail(s: StorageV) -> bool:
    """True when the storage outlives its last touch (dead zone before its
    RELEASE): evicting there frees bytes at zero recompute cost."""
    if s.kept or s.death is None or s.constant or s.producer is None:
        return False
    return s.death > _last_touch(s)


def trim_touches(view: LogView) -> dict[int, tuple[int, ...]]:
    """sid -> touch ordinals for every free-tail storage.

    Evicting such a storage right after its last touch can never cost a
    remat (no future touch exists), so every static plan applies these
    trims unconditionally — they are the zero-remat evictions the online
    runtime wins on eager-mode traces, and a plan that skipped them
    would be handicapped for no reason.
    """
    out = {}
    for s in view.storages:
        if s.size > 0 and _has_free_tail(s):
            ts = sorted(set(s.uses) | {s.producer})
            out[s.sid] = tuple(ts)
    return out


def extract_chain(log_or_view, max_candidates: int = MAX_CANDIDATES) -> Chain:
    """Chain of checkpoint candidates from a log (or prebuilt ``LogView``).

    Candidates are non-constant storages with at least one far gap
    between touches — dropping one costs a segment replay per gap; a
    finalize-kept storage's last gap ends at the finalize replay.
    Free-tail trims are *not* items: they cost nothing and every plan
    takes them (see ``trim_touches``), so the floor already reflects
    them.  When more than ``max_candidates`` storages qualify, the
    largest by byte size stay chain items and the rest join the floor
    (they are kept by every plan) — the same waist-first truncation a
    cut-enumeration over the liveness profile would make.
    """
    view = log_or_view if isinstance(log_or_view, LogView) \
        else build_view(log_or_view)
    cands: list[tuple[StorageV, list[tuple[int, int]]]] = []
    for s in view.storages:
        if s.constant or s.size <= 0 or s.producer is None:
            continue
        gaps = _far_gaps(s, view.n_ops)
        if gaps:
            cands.append((s, gaps))
    total = len(cands)
    if total > max_candidates:
        cands.sort(key=lambda p: (-p[0].size, p[0].sid))
        cands = cands[:max_candidates]
    cands.sort(key=lambda p: (p[0].producer, p[0].sid))

    # Floor: peak of the liveness profile with candidate intervals
    # removed and free tails trimmed (every plan evicts those for free).
    n = view.n_ops
    delta = [0.0] * (n + 1)
    cand_sids = {s.sid for s, _ in cands}
    for s in view.storages:
        if s.size <= 0 or s.sid in cand_sids:
            continue
        a, b = view.live_interval(s)
        if _has_free_tail(s):
            b = max(_last_touch(s), a)
        delta[a] += s.size
        delta[b + 1] -= s.size
    floor, acc = 0.0, 0.0
    for t in range(n):
        acc += delta[t]
        floor = max(floor, acc)

    # Segment costs: every op since the previous candidate's producer is
    # charged to this candidate (the ops a gap replay re-executes on a
    # chain-shaped trace; an approximation on general DAGs — the evaluator
    # reports the exact numbers for any plan).  A dropped candidate is
    # rebuilt once per far gap under the executor's drop rule, so the
    # model charges the segment once per gap; a free-tail candidate with
    # no gaps is never rebuilt and costs nothing to drop.
    op_cost = [o.cost for o in view.ops]
    prefix = [0.0]
    for c in op_cost:
        prefix.append(prefix[-1] + c)
    items: list[ChainItem] = []
    prev_p = -1
    for s, gaps in cands:
        seg = prefix[s.producer + 1] - prefix[prev_p + 1]
        items.append(ChainItem(
            sid=s.sid, size=float(s.size), cost=seg * len(gaps),
            producer=s.producer,
            evict_positions=tuple(a for a, _ in gaps),
            live=view.live_interval(s)))
        prev_p = s.producer
    final_bytes = float(sum(s.size for s in view.storages
                            if s.size > 0 and (s.constant or s.kept)))
    return Chain(items, floor, base_cost=view.base_cost(), name=view.name,
                 n_ops=n, n_candidates_total=total, final_bytes=final_bytes)
