"""Eager DTR executor — the PyTorch-prototype analogue on JAX eager mode.

JAX without ``jit`` dispatches op-by-op (define-by-run), which is exactly the
setting of the paper's prototype (Sec. 5 / App. E).  This package interposes
on operator calls: ``DTRArray`` wraps a concrete ``jax.Array``; every op goes
through a :class:`DTRContext`, which tracks metadata (size, cost, staleness),
enforces a byte budget by *really deleting* buffers of evicted arrays, and
rematerializes on access by replaying parent-op closures — supporting
arbitrary Python control flow (TreeLSTM etc.).
"""
from .executor import DTRArray, DTRContext, op

__all__ = ["DTRArray", "DTRContext", "op"]
