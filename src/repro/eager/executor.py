"""Op-interposition layer: DTR over concrete JAX arrays in eager mode.

Mirrors the paper's PyTorch prototype (Sec. 5):

  * every operator call is dispatched through :meth:`DTRContext.call`, which
    registers the op + measured cost with the DTR runtime, stores a replay
    closure, and returns :class:`DTRArray` handles;
  * under memory pressure the runtime picks victims via ``h_DTR^eq`` (or any
    heuristic) and the context *actually drops the buffers*;
  * accessing an evicted array triggers recursive rematerialization through
    the stored closures.

Like the prototype, the budget may be exceeded by exactly one allocation
(op outputs are computed before the eviction pass — Appendix E.1 notes the
same slack).
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.heuristics import by_name
from ..core.runtime import DTRRuntime, Operator


class DTRArray:
    """Handle to a (possibly evicted) tensor managed by a DTRContext."""

    __slots__ = ("ctx", "tid", "shape", "dtype")

    def __init__(self, ctx: "DTRContext", tid: int, shape, dtype):
        self.ctx = ctx
        self.tid = tid
        self.shape = tuple(shape)
        self.dtype = dtype

    @property
    def value(self) -> jax.Array:
        """Materialize (rematerializing if evicted) and return the buffer."""
        return self.ctx.fetch(self)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)
                   * jnp.dtype(self.dtype).itemsize)

    def release(self) -> None:
        self.ctx.release_tid(self.tid)

    # Convenience arithmetic (sugar over ctx.call).
    def __add__(self, other):
        return self.ctx.call("add", jnp.add, [self, other])[0]

    def __mul__(self, other):
        return self.ctx.call("mul", jnp.multiply, [self, other])[0]

    def __matmul__(self, other):
        return self.ctx.call("matmul", jnp.matmul, [self, other])[0]

    def __repr__(self):
        s = self.ctx.rt.storages[self.ctx.rt.tensors[self.tid].sid]
        state = "resident" if s.resident else "evicted"
        return f"DTRArray(shape={self.shape}, dtype={self.dtype}, {state})"


class DTRContext:
    """Owns the runtime, the buffers, and the replay closures."""

    def __init__(self, budget_bytes: float, heuristic: str = "h_dtr_eq",
                 dealloc: str = "eager", use_wallclock_cost: bool = True,
                 seed: int = 0, alloc_mode: str | None = None,
                 placement: str = "best_fit", recorder=None,
                 offload=None, faults=None, recovery=None):
        # alloc_mode="pool" maps the real JAX buffers onto simulated pool
        # accounting: every resident storage occupies a contiguous block and
        # memory pressure evicts contiguous windows (repro.alloc), so eager
        # runs report the fragmentation a real device allocator would see.
        #
        # ``offload`` (an enabled repro.offload.OffloadConfig, budgets and
        # bandwidths in bytes / bytes-per-second) adds the host tier: under
        # pressure, storages whose modeled round-trip transfer undercuts
        # their recompute cost have their *actual buffers* moved to host
        # memory (numpy) and brought back on access — contents preserved,
        # no replay.
        from ..core.simulator import make_allocator
        h = by_name(heuristic, seed)
        engine = None
        if offload is not None and offload.enabled:
            from ..offload import OffloadEngine, wrap_heuristic
            engine = OffloadEngine(offload)
            h = wrap_heuristic(h, engine)
        self.rt = DTRRuntime(
            budget=float(budget_bytes), heuristic=h,
            dealloc=dealloc,
            materialize_fn=self._on_perform, free_fn=self._on_free,
            allocator=make_allocator(alloc_mode, placement),
            offload=engine, offload_fn=self._on_offload,
            fetch_fn=self._on_fetch,
            # repro.faults: injected faults perturb the *simulated* memory
            # pressure and clock only — the replay closures still produce
            # exact buffers, so a recovered run's numerics match a
            # fault-free one bit-for-bit (the differential tests pin this).
            faults=faults, recovery=recovery)
        self.buffers: dict[int, jax.Array] = {}     # tid -> concrete array
        self.host_buffers: dict[int, np.ndarray] = {}  # tid -> offloaded copy
        self.closures: dict[int, Callable] = {}     # op_id -> replay fn
        self.use_wallclock_cost = use_wallclock_cost
        self._pending_outputs: list[jax.Array] | None = None
        self.remat_runs = 0
        # Optional repro.trace.TraceRecorder: mirrors every wrap/call/release
        # into a core.graph.Log (first executions only — rematerializations
        # are the runtime's own doing, not part of the operator stream).
        self.recorder = recorder

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------
    def wrap(self, x, constant: bool = True, name: str = "const") -> DTRArray:
        """Lift a concrete array into DTR management ("checkpoint()")."""
        x = jnp.asarray(x)
        tid = self.rt.constant(x.nbytes, name=name)
        self.buffers[tid] = x
        if self.recorder is not None:
            self.recorder.on_constant(tid, name, int(x.nbytes),
                                      shape=tuple(x.shape),
                                      dtype=str(x.dtype))
        return DTRArray(self, tid, x.shape, x.dtype)

    def fetch(self, a: DTRArray) -> jax.Array:
        """"decheckpoint()": rematerialize if needed and return the value."""
        self.rt.get(a.tid)
        return self.buffers[a.tid]

    def call(self, name: str, fn: Callable, args: Sequence,
             n_outputs: int | None = None) -> list[DTRArray]:
        """Dispatch ``fn(*args)`` through DTR.

        ``args`` may mix DTRArrays and plain arrays/scalars; plain values are
        captured in the closure (treated as op attributes, not tensors).
        """
        dtr_args = [a for a in args if isinstance(a, DTRArray)]
        in_tids = [a.tid for a in dtr_args]

        def replay(*concrete):
            it = iter(concrete)
            full = [next(it) if isinstance(a, DTRArray) else a for a in args]
            out = fn(*full)
            return out if isinstance(out, tuple) else (out,)

        # Execute now with materialized inputs (also measures cost).
        concrete_in = [self.fetch(a) for a in dtr_args]
        t0 = time.perf_counter()
        outs = replay(*concrete_in)
        jax.block_until_ready(outs)
        elapsed = time.perf_counter() - t0
        cost = max(elapsed, 1e-7) if self.use_wallclock_cost else 1.0

        self._pending_outputs = list(outs)
        oid = self.rt._next_oid
        self.closures[oid] = replay
        out_sizes = [int(o.nbytes) for o in outs]
        tids = self.rt.call(name, cost, in_tids, out_sizes)
        self._pending_outputs = None
        if self.recorder is not None:
            self.recorder.on_call(name, cost, in_tids, tids, out_sizes,
                                  shapes=[tuple(o.shape) for o in outs])
        return [DTRArray(self, tid, o.shape, o.dtype)
                for tid, o in zip(tids, outs)]

    def release_tid(self, tid: int) -> None:
        """Drop one external reference (recorded when tracing)."""
        if self.recorder is not None:
            self.recorder.on_release(tid)
        self.rt.release(tid)

    def fragmentation(self):
        """Pool telemetry (``repro.alloc.FragStats``); None in counter mode."""
        return self.rt.fragmentation()

    def live_bytes(self) -> int:
        """Actual bytes held in resident buffers (for budget verification)."""
        total = 0
        for tid, buf in self.buffers.items():
            t = self.rt.tensors[tid]
            if t.defined and not t.is_alias:
                total += int(buf.nbytes)
        return total

    # ------------------------------------------------------------------
    # Runtime hooks
    # ------------------------------------------------------------------
    def _on_perform(self, op: Operator, first: bool) -> None:
        if first:
            outs = self._pending_outputs
            assert outs is not None, "first perform without pending outputs"
        else:
            # Rematerialization: replay closure with input buffers (the
            # runtime guarantees inputs are defined here).
            self.remat_runs += 1
            ins = [self.buffers[tid] for tid in op.input_tids]
            outs = list(self.closures[op.op_id](*ins))
        for tid, buf in zip(op.output_tids, outs):
            if self.rt.tensors[tid].defined:
                self.buffers[tid] = buf

    def _on_free(self, storage) -> None:
        for tid in storage.tensor_tids:
            self.buffers.pop(tid, None)
            self.host_buffers.pop(tid, None)

    def _on_offload(self, storage, defined_tids) -> None:
        """Move the storage's defined buffers to host memory (numpy)."""
        for tid in defined_tids:
            buf = self.buffers.pop(tid, None)
            if buf is not None:
                self.host_buffers[tid] = np.asarray(buf)
        for tid in storage.tensor_tids:   # undefined views hold no bytes
            self.buffers.pop(tid, None)

    def _on_fetch(self, storage, defined_tids) -> None:
        """Bring host copies back as device arrays (contents preserved)."""
        for tid in defined_tids:
            host = self.host_buffers.pop(tid, None)
            if host is not None:
                self.buffers[tid] = jnp.asarray(host)

    def host_bytes(self) -> int:
        """Actual bytes currently parked in host copies."""
        return sum(int(b.nbytes) for b in self.host_buffers.values())


def op(ctx: DTRContext, name: str, fn: Callable) -> Callable:
    """Decorator-style helper:  f = op(ctx, "gelu", jax.nn.gelu)."""
    def wrapped(*args):
        outs = ctx.call(name, fn, list(args))
        return outs[0] if len(outs) == 1 else tuple(outs)
    return wrapped
