"""Simulated device memory pool: an address space partitioned into blocks.

The DTR core models memory as a fungible byte counter, but a real accelerator
allocator must return a *contiguous* block, so total-free-bytes is an
optimistic bound (Coop, "Memory is not a Commodity").  ``MemoryPool`` keeps the
whole address space as a doubly-linked, address-ordered list of blocks — each
either free or owned by exactly one storage — with first-class splitting,
coalescing, and fragmentation telemetry:

  * ``alloc(sid, size)`` carves a block under a placement policy
    (``best_fit`` | ``first_fit`` | ``stream``, the latter a bump-pointer
    search from the last placement, echoing stream-ordered pool allocators);
  * ``free(sid)`` returns the block and merges it with free neighbors, so the
    invariant *no two adjacent free blocks* always holds;
  * stats report largest free block, external-fragmentation ratio
    (1 - largest_free/free), and the failed-fit count — the quantities a
    contiguity-aware eviction policy needs.

``capacity`` may be ``float('inf')`` (unconstrained runs): the tail free block
is infinite and every fit succeeds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

PLACEMENTS = ("best_fit", "first_fit", "stream")


class Block:
    """One address range ``[offset, offset+size)``; free iff ``sid is None``."""

    __slots__ = ("offset", "size", "sid", "prev", "next")

    def __init__(self, offset: float, size: float,
                 sid: Optional[int] = None) -> None:
        self.offset = offset
        self.size = size
        self.sid = sid
        self.prev: Optional[Block] = None
        self.next: Optional[Block] = None

    @property
    def free(self) -> bool:
        return self.sid is None

    @property
    def end(self) -> float:
        return self.offset + self.size

    def __repr__(self) -> str:
        who = "free" if self.free else f"sid={self.sid}"
        return f"<Block [{self.offset}, {self.end}) {who}>"


@dataclass
class FragStats:
    """Fragmentation telemetry snapshot (also surfaced by launch monitoring)."""
    capacity: float = 0.0
    used: float = 0.0
    free: float = 0.0
    largest_free: float = 0.0
    frag_ratio: float = 0.0       # 1 - largest_free/free (0 when unfragmented)
    n_blocks: int = 0
    n_free_blocks: int = 0
    failed_fits: int = 0          # allocs that needed eviction to place
    evict_windows: int = 0        # contiguous-window evictions performed
    extra: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "capacity": self.capacity, "used": self.used, "free": self.free,
            "largest_free": self.largest_free, "frag_ratio": self.frag_ratio,
            "n_blocks": self.n_blocks, "n_free_blocks": self.n_free_blocks,
            "failed_fits": self.failed_fits,
            "evict_windows": self.evict_windows,
        }


class MemoryPool:
    """Address-ordered free-list allocator over a fixed-capacity region."""

    def __init__(self, capacity: float, placement: str = "best_fit") -> None:
        assert placement in PLACEMENTS, placement
        # capacity <= 0 (degenerate budget probes) => empty address space:
        # every fit fails, which surfaces as a clean OOM upstream.
        self.capacity = max(capacity, 0.0)
        self.placement = placement
        self._head: Optional[Block] = (
            Block(0, self.capacity) if self.capacity > 0 else None)
        self._by_sid: dict[int, Block] = {}
        self.used: float = 0.0
        self.failed_fits = 0
        self.alloc_calls = 0
        self._cursor: float = 0.0   # stream placement resumes here

    # ------------------------------------------------------------------
    # Core operations
    # ------------------------------------------------------------------
    def blocks(self) -> Iterator[Block]:
        b = self._head
        while b is not None:
            yield b
            b = b.next

    def alloc(self, sid: int, size: float) -> bool:
        """Place ``sid`` into a free block; False when no contiguous fit."""
        assert sid not in self._by_sid, f"sid {sid} already resident"
        if size <= 0:
            return True
        self.alloc_calls += 1
        blk = self._find_fit(size)
        if blk is None:
            self.failed_fits += 1
            return False
        self._place(blk, sid, size)
        return True

    def resident_sids(self) -> set[int]:
        """Sids currently owning a block (zero-sized storages never place).

        Public so observers (``repro.check.sanitizer``) can audit
        pool-vs-runtime residency parity without reaching into the
        free-list internals."""
        return set(self._by_sid)

    def free(self, sid: int) -> None:
        """Release ``sid``'s block and coalesce with free neighbors."""
        blk = self._by_sid.pop(sid, None)
        if blk is None:
            return              # zero-sized storage: nothing was placed
        self.used -= blk.size
        blk.sid = None
        # Merge with a free successor, then a free predecessor.
        nxt = blk.next
        if nxt is not None and nxt.free:
            blk.size += nxt.size
            self._unlink(nxt)
        prv = blk.prev
        if prv is not None and prv.free:
            prv.size += blk.size
            self._unlink(blk)

    def block_of(self, sid: int) -> Optional[Block]:
        return self._by_sid.get(sid)

    def compact(self) -> None:
        """Slide used blocks to the bottom of the address space (defrag).

        Models a moving/compacting allocator; used by the fragmentation-free
        compatibility mode so byte-counter semantics stay exact while block
        telemetry remains live.
        """
        sids = [(b.sid, b.size) for b in self.blocks() if not b.free]
        self._head = Block(0, self.capacity) if self.capacity > 0 else None
        self._by_sid.clear()
        self.used = 0.0
        for sid, size in sids:
            ok = self.alloc(sid, size)      # first free block == lowest addr
            assert ok, "compaction cannot fail"
            self.alloc_calls -= 1           # bookkeeping op, not a request
        self._cursor = 0.0

    # ------------------------------------------------------------------
    # Telemetry
    # ------------------------------------------------------------------
    def free_bytes(self) -> float:
        return self.capacity - self.used

    def largest_free_block(self) -> float:
        return max((b.size for b in self.blocks() if b.free), default=0.0)

    def n_free_blocks(self) -> int:
        return sum(1 for b in self.blocks() if b.free)

    def external_frag(self) -> float:
        free = self.free_bytes()
        if free <= 0 or free == float("inf"):
            return 0.0
        return 1.0 - self.largest_free_block() / free

    def stats(self) -> FragStats:
        free = self.free_bytes()
        return FragStats(
            capacity=self.capacity, used=self.used, free=free,
            largest_free=self.largest_free_block(),
            frag_ratio=self.external_frag(),
            n_blocks=sum(1 for _ in self.blocks()),
            n_free_blocks=self.n_free_blocks(),
            failed_fits=self.failed_fits)

    # ------------------------------------------------------------------
    # Invariant checking (tests)
    # ------------------------------------------------------------------
    def check(self) -> None:
        offset = 0.0
        used = 0.0
        prev: Optional[Block] = None
        seen: set[int] = set()
        for b in self.blocks():
            assert b.offset == offset, (b, offset)
            assert b.size > 0, b
            assert b.prev is prev
            if prev is not None:
                assert prev.next is b
                assert not (prev.free and b.free), "adjacent free blocks"
            if not b.free:
                used += b.size
                assert b.sid not in seen
                seen.add(b.sid)
                assert self._by_sid.get(b.sid) is b
            offset = b.end
            prev = b
        assert offset == self.capacity, (offset, self.capacity)
        assert seen == set(self._by_sid)
        assert used == self.used, (used, self.used)

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _find_fit(self, size: float) -> Optional[Block]:
        if self.placement == "best_fit":
            best = None
            for b in self.blocks():
                if b.free and b.size >= size:
                    if best is None or b.size < best.size:
                        best = b
            return best
        if self.placement == "first_fit":
            for b in self.blocks():
                if b.free and b.size >= size:
                    return b
            return None
        # stream: first fit at/after the cursor, wrapping once.
        wrapped = None
        for b in self.blocks():
            if not (b.free and b.size >= size):
                continue
            if b.end > self._cursor:
                return b
            if wrapped is None:
                wrapped = b
        return wrapped

    def _place(self, blk: Block, sid: int, size: float) -> None:
        assert blk.free and blk.size >= size
        if blk.size > size:
            rest = Block(blk.offset + size, blk.size - size)
            self._link_after(blk, rest)
            blk.size = size
        blk.sid = sid
        self._by_sid[sid] = blk
        self.used += size
        self._cursor = blk.end

    def _link_after(self, blk: Block, new: Block) -> None:
        new.prev = blk
        new.next = blk.next
        if blk.next is not None:
            blk.next.prev = new
        blk.next = new

    def _unlink(self, blk: Block) -> None:
        if blk.prev is not None:
            blk.prev.next = blk.next
        else:
            self._head = blk.next
        if blk.next is not None:
            blk.next.prev = blk.prev
