"""Contiguity-aware allocator backend for the DTR runtime.

``PoolAllocator`` maps every resident storage onto a block of a simulated
:class:`~repro.alloc.pool.MemoryPool`.  Two modes:

  * ``contiguous=True`` — the realistic model: an allocation must find a
    contiguous free block.  When none fits, the allocator plans a
    **contiguous eviction window** (Coop, "Memory is not a Commodity"): a
    sliding window over the address-ordered block list whose blocks are all
    free or evictable, whose span covers the request, and whose summed
    heuristic score (``repro.core.heuristics.window_cost``) is minimal.  The
    whole window is evicted at once, guaranteeing the freed span is a single
    coalesced block that satisfies the request — unlike the byte-counter
    model's globally-cheapest-one-at-a-time loop, which can free many
    scattered bytes while satisfying nothing.

  * ``contiguous=False`` — fragmentation disabled: admission is the exact
    byte-counter check and eviction the runtime's classic loop, so results
    are bit-for-bit identical to pool-less runs; blocks are still placed
    (compacting on fragmented fits) so telemetry stays meaningful.

The allocator is deliberately runtime-agnostic: it only uses the runtime's
public pieces (``storages``, ``heuristic``, ``_pick_victim``/``_evict``,
``memory``/``peak_memory`` accounting), so the eager executor reuses it
unchanged to map real JAX buffers onto pool accounting.
"""
from __future__ import annotations

from typing import Optional

from .pool import FragStats, MemoryPool


class PoolAllocator:
    """Fragmentation-aware allocation policy over a :class:`MemoryPool`."""

    def __init__(self, placement: str = "best_fit", contiguous: bool = True,
                 capacity: Optional[float] = None) -> None:
        from .pool import PLACEMENTS
        if placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r}; "
                             f"expected one of {PLACEMENTS}")
        self.placement = placement
        self.contiguous = contiguous
        self._capacity = capacity
        self.pool: Optional[MemoryPool] = None
        self.evict_windows = 0
        self.window_evictions = 0

    # ------------------------------------------------------------------
    # Runtime hooks
    # ------------------------------------------------------------------
    def attach(self, rt) -> None:
        cap = self._capacity if self._capacity is not None else rt.budget
        self.pool = MemoryPool(cap, placement=self.placement)

    def allocate(self, rt, s, exclude: frozenset = frozenset()) -> None:
        """Place storage ``s`` (contiguous mode), evicting a window if needed.

        Raises the runtime's ``OOMError`` when no window of free + evictable
        blocks can cover the request.
        """
        assert self.contiguous, "use runtime._alloc + place() in nofrag mode"
        size = s.size
        if size <= 0:
            rt.peak_memory = max(rt.peak_memory, rt.memory)
            return
        faults = getattr(rt, "faults", None)
        if faults is not None and faults.alloc_fault():
            # Injected transient failure of the device allocator itself
            # (fragmentation our block model cannot see): recover with a
            # defrag pass — compaction cannot fail — and proceed.
            rt._degrade("alloc_fault", need=size)
            self.pool.compact()
        # Injected budget squeeze (a co-tenant stole device bytes): the
        # pool's address space is fixed, so the squeeze binds as a byte
        # gate ahead of placement.  Dormant unless a squeeze is active —
        # the fault-free victim stream stays purely window-planned.
        if getattr(rt, "_budget_factor", 1.0) != 1.0:
            while rt.memory + size > rt.effective_budget():
                victim = rt._pick_victim(exclude)
                if victim is None:
                    break           # fall through to the window machinery
                rt._evict_or_offload(victim)
        if not self.pool.alloc(s.sid, size):
            window = self.plan_window(rt, size, exclude)
            tried: set = set()
            while window is None:
                # Before declaring OOM, reclaim in-flight prefetch-back
                # reservations (repro.offload): their blocks are neither
                # free nor evictable, so the planner cannot see them.
                # Then walk the runtime's degradation ladder (compaction /
                # forced offload / heuristic escalation — a no-op without
                # a RecoveryConfig).
                off = getattr(rt, "offload", None)
                if ((off is None or not off.cancel_one_prefetch(rt))
                        and not rt._recovery_step(exclude, tried)):
                    from ..core.runtime import OOMError
                    st = self.pool.stats()
                    raise OOMError(
                        f"no contiguous window for {size} bytes "
                        f"(free={st.free}, largest_free={st.largest_free}, "
                        f"frag_ratio={st.frag_ratio:.3f}, "
                        f"capacity={st.capacity})"
                        + rt._memory_diagnostics())
                if self.pool.alloc(s.sid, size):
                    rt.memory += size
                    rt.peak_memory = max(rt.peak_memory, rt.memory)
                    return
                window = self.plan_window(rt, size, exclude)
            self.evict_windows += 1
            self.window_evictions += len(window)
            for victim in window:
                rt._evict_or_offload(victim)
            ok = self.pool.alloc(s.sid, size)
            assert ok, "window eviction must open a large-enough block"
        rt.memory += size
        rt.peak_memory = max(rt.peak_memory, rt.memory)

    def place(self, s) -> None:
        """Place a storage already admitted by byte-counter accounting.

        Compatibility path for ``contiguous=False``: the classic eviction loop
        has guaranteed ``used + size <= capacity``, so a fragmented fit is
        resolved by compaction (a moving allocator), never by extra eviction.
        """
        if s.size <= 0:
            return
        if not self.pool.alloc(s.sid, s.size):
            self.pool.compact()
            ok = self.pool.alloc(s.sid, s.size)
            assert ok, "nofrag mode admitted more bytes than capacity"

    def free(self, s) -> None:
        self.pool.free(s.sid)

    # ------------------------------------------------------------------
    # Window planning (Coop's sliding window, heuristic-cost-minimal)
    # ------------------------------------------------------------------
    def plan_window(self, rt, need: float,
                    exclude: frozenset = frozenset()):
        """Choose the min-cost contiguous window of storages to evict.

        Scans the address-ordered block list with two pointers.  A block may
        join a window iff it is free or owned by an evictable storage not in
        ``exclude``; pinned/locked/constant blocks are barriers that reset
        the window.  Among all minimal windows spanning >= ``need`` bytes,
        returns the storages of the one minimizing summed heuristic score
        (ties: smaller span, then lower address).  ``None`` if no window
        exists.
        """
        from ..core.heuristics import window_cost

        blocks = list(self.pool.blocks())
        storages = []            # parallel: storage rec or None (free block)
        for b in blocks:
            storages.append(None if b.free else rt.storages[b.sid])

        def usable(k: int) -> bool:
            s = storages[k]
            if s is None:
                return True
            return s.evictable() and s.sid not in exclude

        # With an eviction index attached, window_cost reads the index's
        # shared per-storage score memo (same values and meta-access
        # accounting as victim selection); the ad-hoc per-pass dict is only
        # needed for index-less (oracle) runtimes.
        cache: Optional[dict[int, float]] = (
            None if getattr(rt, "index", None) is not None else {})

        def score(k: int) -> float:
            s = storages[k]
            if s is None:
                return 0.0
            return window_cost(rt, rt.heuristic, [s], cache=cache)

        # Running span + cost keep each planning pass O(blocks).
        best: Optional[tuple[int, int]] = None
        best_cost = best_span = 0.0
        i = 0
        span = cost = 0.0
        for j, b in enumerate(blocks):
            if not usable(j):
                i, span, cost = j + 1, 0.0, 0.0
                continue
            span += b.size
            cost += score(j)
            while i < j and span - blocks[i].size >= need:
                span -= blocks[i].size
                cost -= score(i)
                i += 1
            if span < need:
                continue
            if (best is None or cost < best_cost
                    or (cost == best_cost and span < best_span)):
                best, best_cost, best_span = (i, j), cost, span
        if best is None:
            return None
        lo, hi = best
        return [storages[k] for k in range(lo, hi + 1)
                if storages[k] is not None]

    # ------------------------------------------------------------------
    # Telemetry
    # ------------------------------------------------------------------
    def stats(self) -> FragStats:
        st = self.pool.stats() if self.pool is not None else FragStats()
        st.evict_windows = self.evict_windows
        st.extra["window_evictions"] = self.window_evictions
        st.extra["placement"] = self.placement
        st.extra["contiguous"] = self.contiguous
        return st
