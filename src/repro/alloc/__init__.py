"""repro.alloc — simulated device memory pool + contiguity-aware eviction.

Turns the DTR core's fungible byte counter into an address-space-accurate
model: storages occupy contiguous blocks, allocation requires a contiguous
fit, and memory pressure is resolved by evicting a heuristic-cost-minimal
*contiguous window* of storages (Coop) instead of globally-cheapest storages
one at a time.
"""
from .allocator import PoolAllocator
from .pool import Block, FragStats, MemoryPool, PLACEMENTS

__all__ = ["Block", "FragStats", "MemoryPool", "PLACEMENTS", "PoolAllocator"]
