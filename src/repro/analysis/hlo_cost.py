"""Loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a while loop
body (layer scan, grad-accum loop) with known_trip_count=N is undercounted
N×, which breaks roofline math for scanned layer stacks.  This module parses
the HLO module, builds the call graph (fusion calls, while bodies with
``known_trip_count``, conditionals), and rolls up per-instruction costs with
loop multipliers:

  flops   — dot ops: 2·|result|·|contracted|; elementwise: |result|
            (counted inside fusion computations too);
  bytes   — operand + result bytes of *top-level* instructions only (fusion
            internals don't touch HBM — matches "bytes accessed" semantics);
  collective_bytes — per kind, × loop multiplier.

All numbers are per-device (the HLO module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[^\s(])*?)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|condition|body|true_computation|false_computation)"
    r"=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "call", "after-all",
             "partition-id", "replica-id", "iota", "get-dimension-size"}


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    elems = bts = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES.get(dt, 4)
    return elems, bts


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    rest: str          # everything after the '(' of the operand list
    flops: float = 0.0
    bytes_: int = 0
    called: list = field(default_factory=list)
    trip: int = 1
    coll_bytes: int = 0
    coll_kind: str = ""
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    is_fusion: bool = False
    defs: dict = field(default_factory=dict)      # instr name -> opcode
    sym: dict = field(default_factory=dict)       # instr name -> result type
    # parameter index -> effective bytes when the parameter is consumed only
    # through a slicing op inside this computation (the scan-over-stacked-
    # params pattern: a [L, ...] operand is read one slice per iteration).
    param_eff: dict = field(default_factory=dict)
    param_full: dict = field(default_factory=dict)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    transcendentals: float = 0.0

    def as_cost_dict(self) -> dict:
        return {"flops": self.flops, "bytes accessed": self.bytes_accessed,
                "transcendentals": self.transcendentals}


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    symbols: dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        h = _COMP_HDR.match(line) if line and not line[0].isspace() else None
        if h:
            cur = Computation(h.group(1))
            cur.is_fusion = "fused_computation" in cur.name
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            symbols = {}
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        ins = Instr(name, opcode, rtype, rest)
        symbols[name] = rtype
        # called computations
        ins.called = _CALLED_RE.findall(rest)
        br = _BRANCHES_RE.search(rest)
        if br:
            ins.called += [c.strip().lstrip("%") for c in
                           br.group(1).split(",")]
        if opcode == "while":
            t = _TRIP_RE.search(rest)
            ins.trip = int(t.group(1)) if t else 1
        # flops
        relems, rbytes = _shape_elems_bytes(rtype)
        if opcode == "dot":
            cd = _CDIMS_RE.search(rest)
            contracted = 1
            if cd:
                # lhs shape: CPU/GPU HLO inlines operand types in the call
                # ("dot(f32[64,128]{1,0} %a, ...)"), TPU HLO references by
                # name only ("dot(%a, ...)") — try inline first, then the
                # symbol table.
                ops = rest.split(")")[0]
                shapes = _SHAPE_RE.findall(ops)
                if not shapes:
                    first = re.search(r"%?([\w\.\-]+)", ops)
                    lhs_type = symbols.get(first.group(1), "") if first else ""
                    shapes = _SHAPE_RE.findall(lhs_type)
                if shapes:
                    dims = [int(x) for x in shapes[0][1].split(",") if x]
                    for di in cd.group(1).split(","):
                        if di and int(di) < len(dims):
                            contracted *= dims[int(di)]
            ins.flops = 2.0 * relems * contracted
        elif opcode in ("convolution",):
            ins.flops = 2.0 * relems  # underestimate; convs unused here
        elif opcode in ("exponential", "tanh", "logistic", "log", "rsqrt",
                        "sqrt", "power", "sine", "cosine", "erf"):
            ins.flops = relems
        elif opcode in ("add", "multiply", "subtract", "divide", "maximum",
                        "minimum", "select", "compare", "and", "or", "xor",
                        "negate", "abs", "floor", "ceil", "convert",
                        "reduce", "exponential-minus-one"):
            ins.flops = relems
        # bytes: operands + result, top-level ops only (filtered at rollup)
        operand_part = rest.split("), ")[0] if "), " in rest else \
            rest.split(")")[0]
        ins.operands = re.findall(r"%([\w\.\-]+)", operand_part)
        if opcode not in _NO_BYTES:
            if opcode in ("dynamic-slice", "slice", "gather"):
                # traffic = slice read + result write
                ins.bytes_ = 2 * rbytes
            elif opcode in ("dynamic-update-slice", "scatter",
                            "scatter-add"):
                # traffic ~ update read + region write (buffer aliased)
                upd = (symbols.get(ins.operands[1])
                       if len(ins.operands) > 1 else None)
                ub = _shape_elems_bytes(upd)[1] if upd else rbytes
                ins.bytes_ = 2 * ub
            elif opcode == "broadcast":
                ins.bytes_ = rbytes
            else:
                ob = 0
                for nm in ins.operands:
                    t = symbols.get(nm)
                    if t:
                        ob += _shape_elems_bytes(t)[1]
                ins.bytes_ = ob + rbytes
        # collectives
        for kind in _COLLECTIVES:
            if opcode.startswith(kind):
                if opcode.endswith("-done"):
                    break
                _, b = _shape_elems_bytes(rest.split(")")[0])
                if b == 0:
                    b = rbytes
                ins.coll_bytes = b
                ins.coll_kind = kind
                break
        cur.defs[name] = opcode
        cur.sym[name] = rtype
        cur.instrs.append(ins)

    # Effective parameter bytes for fusion computations (slice-only use).
    for comp in comps.values():
        pidx_of = {}
        for ins in comp.instrs:
            if ins.opcode == "parameter":
                m2 = re.match(r"\s*(\d+)", ins.rest)
                if m2:
                    idx = int(m2.group(1))
                    pidx_of[ins.name] = idx
                    comp.param_full[idx] = _shape_elems_bytes(
                        ins.result_type)[1]
        for pname, idx in pidx_of.items():
            consumers = [i for i in comp.instrs if pname in i.operands]
            if len(consumers) == 1 and consumers[0].opcode in (
                    "dynamic-slice", "slice", "gather"):
                comp.param_eff[idx] = _shape_elems_bytes(
                    consumers[0].result_type)[1]
            else:
                comp.param_eff[idx] = comp.param_full.get(idx, 0)
    return comps, entry


def analyze(text: str, flash_tile_threshold: float | None = None
            ) -> HloCost:
    """``flash_tile_threshold``: if set, instructions in loop nests with
    multiplier > threshold count HBM bytes only for dot ops — modelling a
    Pallas flash-attention kernel whose softmax intermediates stay in VMEM
    (the threshold is the layer-scan multiplier; anything hotter is the
    blocked-attention inner loop).  Labeled "analytic" in §Perf."""
    comps, entry = parse_module(text)
    cost = HloCost()
    if entry is None:
        return cost

    def visit(comp_name: str, mult: float, depth: int = 0):
        comp = comps.get(comp_name)
        if comp is None or depth > 50:
            return
        for ins in comp.instrs:
            cost.flops += ins.flops * mult
            if not comp.is_fusion:
                b = ins.bytes_
                if ins.opcode == "fusion" and ins.called:
                    fc = comps.get(ins.called[0])
                    if fc is not None and fc.param_eff:
                        rb = _shape_elems_bytes(ins.result_type)[1]
                        b = rb + sum(
                            fc.param_eff.get(i, 0)
                            for i in range(len(ins.operands)))
                if (flash_tile_threshold is not None
                        and mult > flash_tile_threshold):
                    # Analytic Pallas-kernel HBM model: only tensors that
                    # cross the kernel boundary are charged.  Dots stream
                    # externally-produced operands (q/k/v tiles); results
                    # and in-body intermediates (logits/probs) stay VMEM.
                    if ins.opcode == "dot":
                        b = 0
                        ext = ("parameter", "get-tuple-element",
                               "dynamic-slice", "bitcast", "copy",
                               "transpose", "reshape", "convert")
                        for nm in ins.operands:
                            if comp.defs.get(nm, "parameter") in ext:
                                b += _shape_elems_bytes(
                                    comp.sym.get(nm, ""))[1]
                    elif "dynamic-update-slice" in ins.name:
                        # o-tile write-back: smallest operand approximates
                        # the update slice.
                        obs = [_shape_elems_bytes(comp.sym.get(nm, ""))[1]
                               for nm in ins.operands
                               if comp.sym.get(nm)]
                        b = 2 * min(obs) if obs else 0
                    else:
                        b = 0
                cost.bytes_accessed += b * mult
            if ins.coll_kind:
                cost.collective_bytes += ins.coll_bytes * mult
                cost.coll_by_kind[ins.coll_kind] = (
                    cost.coll_by_kind.get(ins.coll_kind, 0)
                    + ins.coll_bytes * mult)
            if ins.opcode in ("exponential", "tanh", "logistic", "log",
                              "power", "erf"):
                cost.transcendentals += ins.flops * mult
            child_mult = mult * (ins.trip if ins.opcode == "while" else 1)
            for c in ins.called:
                visit(c, child_mult, depth + 1)

    visit(entry, 1.0)
    return cost
