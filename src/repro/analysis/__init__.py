"""Compiled-artifact analysis: HLO collective accounting + roofline terms."""
from .hlo import collective_bytes, parse_collectives, xla_cost_dict
from .roofline import RooflineTerms, roofline

__all__ = ["collective_bytes", "parse_collectives", "xla_cost_dict",
           "RooflineTerms", "roofline"]
