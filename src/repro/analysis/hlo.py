"""Parse collective-communication bytes out of optimized HLO text.

``cost_analysis()`` does not report collective traffic, so we sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction in ``compiled.as_text()`` (per-device program
=> sizes are per-device shard sizes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# dtype[2,3,4]{...} — shape token
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
# "  %name = <result> opcode(<operands>)"
_INSTR_RE = re.compile(
    r"=\s*(.*?)\s+("
    + "|".join(_COLLECTIVES)
    + r")(?:-(?:start|done))?\s*\((.*?)\)\s*,?",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * b


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    count_by_kind: dict[str, int] = field(
        default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "by_kind": {k: int(v) for k, v in self.bytes_by_kind.items()},
            "counts": {k: int(v) for k, v in self.count_by_kind.items()},
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done: set[str] = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        kind = m.group(2)
        # async pairs: count -start, skip -done (same transfer).
        if f"{kind}-done" in line:
            continue
        operands = m.group(3)
        b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(operands))
        if b == 0:
            # Operands referenced by name only (e.g. "%param.3") — fall back
            # to the result shape(s) on the lhs.
            b = sum(_shape_bytes(d, s)
                    for d, s in _SHAPE_RE.findall(m.group(1)))
        stats.bytes_by_kind[kind] += b
        stats.count_by_kind[kind] += 1
    return stats


def collective_bytes(hlo_text: str) -> int:
    return parse_collectives(hlo_text).total_bytes


def xla_cost_dict(cost_analysis) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns ``[dict]`` (one entry per program), newer returns the
    dict directly; either may be None for backends without an implementation.
    """
    if cost_analysis is None:
        return {}
    if isinstance(cost_analysis, dict):
        return cost_analysis
    if isinstance(cost_analysis, (list, tuple)):
        return dict(cost_analysis[0]) if cost_analysis else {}
    return dict(cost_analysis)
