"""Three-term roofline model for the dry-run artifacts (TPU v5e targets).

  compute   = HLO_FLOPs   / (chips × 197 TFLOP/s bf16)
  memory    = HLO_bytes   / (chips × 819 GB/s HBM)
  collective= coll_bytes  / (chips × 50 GB/s per-link ICI)

cost_analysis() on a fully-SPMD-partitioned executable reports *per-device*
flops/bytes in current jax (we detect + normalize either way via the
``per_device`` flag the dry-run sets).  The dominant term is the predicted
bottleneck; MODEL_FLOPS/HLO_FLOPs measures how much compiled compute is
"useful" (catches remat/redundancy waste — and for the paper's technique the
remat recompute shows up here *by design*).
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float             # whole-program HLO flops (all chips)
    bytes_accessed: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound ~ max term (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of peak the *useful* model FLOPs achieve at the predicted
        step time (the score §Perf optimizes)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "step_time_s": self.step_time_s,
            "chips": self.chips,
        }


def roofline(cost: dict, coll_bytes: float, chips: int,
             model_flops: float = 0.0,
             per_device: bool = True) -> RooflineTerms:
    """Build terms from compiled.cost_analysis() + parsed collective bytes.

    per_device: cost_analysis numbers are per-device (current jax SPMD
    behaviour); collective bytes parsed from the per-device HLO module are
    always per-device.
    """
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    if per_device:
        total_flops = flops * chips
        total_bytes = bts * chips
    else:
        total_flops, total_bytes = flops, bts
    per_chip_flops = total_flops / chips
    per_chip_bytes = total_bytes / chips
    return RooflineTerms(
        compute_s=per_chip_flops / PEAK_FLOPS,
        memory_s=per_chip_bytes / HBM_BW,
        collective_s=float(coll_bytes) / ICI_BW,
        flops=total_flops,
        bytes_accessed=total_bytes,
        collective_bytes=float(coll_bytes),
        chips=chips,
        model_flops=model_flops,
    )


def model_flops_train(cfg, tokens: int) -> float:
    """6·N_active·D for a train step (fwd+bwd)."""
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_decode(cfg, batch: int) -> float:
    """2·N_active per generated token (fwd only), × batch."""
    return 2.0 * cfg.active_param_count() * batch


def model_flops_prefill(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_param_count() * tokens
