"""Replay captured traces through the DTR engine: verification + budget curves.

``run_trace`` replays one log at one budget and returns the ``RunResult``
*plus* the full victim sequence (storage ids in eviction order) — the
decision stream the golden-trace tests pin down.

``verify_oracle_equivalence`` replays a trace through the incremental
eviction index and the exhaustive linear-scan oracle for every separable
heuristic, asserting bit-identical decisions (victims, tie-breaks, compute,
peak) — the acceptance gate for captured serving traces.

``replay_budget_curve`` sweeps budget fractions × heuristics through
``simulator.sweep_parallel`` (the PR-2 parallel driver) and shapes the
result for ``BENCH_serving.json``.
"""
from __future__ import annotations

from dataclasses import asdict

from ..check.trace_lint import check_log
from ..core.graph import Log, replay
from ..core.heuristics import ALL_NAMES, by_name
from ..core.runtime import DTRRuntime, OOMError, ThrashError
from ..core.simulator import (RunResult, classify_error, measure_baseline,
                              resolve_budget, result_from_runtime, simulate,
                              sweep_parallel)

#: Heuristics with a key()/staleness decomposition: the eviction index and
#: the linear scan must agree bit-exactly on these (h_rand consumes RNG
#: state per score evaluation, so it is scan-only by design).
SEPARABLE = tuple(h for h in ALL_NAMES + ["h_estar"] if h != "h_rand")

DEFAULT_FRACTIONS = (0.9, 0.7, 0.5, 0.4, 0.3)


def run_trace(log: Log, heuristic: str, budget: float, *,
              dealloc: str = "eager", index: bool = True, seed: int = 0,
              thrash_factor: float = 50.0, offload=None, faults=None,
              recovery=None, lint: bool = True, sanitize=False):
    """Replay ``log`` once; returns (RunResult, victim sid sequence).

    ``offload`` (an enabled ``repro.offload.OffloadConfig``) attaches the
    hybrid host tier; the victim sequence then records *evictions* only
    (offloads preserve contents, so they are not decisions the golden
    digests pin).  ``host_budget=0`` configs are ignored — bit-exact with
    the plain replay.  ``faults`` / ``recovery`` (``repro.faults``)
    attach a replayable chaos schedule and the degradation ladder; the
    golden fault-replay tests pin the victim sequence *and* the structured
    event stream of pinned schedules.

    ``lint`` statically verifies the log before replay (memoized per log
    object, so sweeps pay it once); ``sanitize`` attaches the
    ``repro.check`` shadow sanitizer to the runtime.  Both raise through:
    a ``TraceLintError`` / ``SanitizerViolation`` is a defect, not a
    replay outcome.
    """
    if lint:
        check_log(log, dealloc=dealloc)
    h = by_name(heuristic, seed)
    engine = None
    if offload is not None and offload.enabled:
        from ..offload import OffloadEngine, wrap_heuristic
        engine = OffloadEngine(offload)
        h = wrap_heuristic(h, engine)
    rt = DTRRuntime(budget=budget, heuristic=h,
                    dealloc=dealloc, seed=seed,
                    compute_limit=thrash_factor * log.baseline_cost(),
                    index=index, offload=engine,
                    faults=faults, recovery=recovery, sanitize=sanitize)
    victims: list[int] = []
    inner = rt._evict

    def traced_evict(s):
        victims.append(s.sid)
        inner(s)

    rt._evict = traced_evict
    ok, err, kind = True, "", ""
    try:
        replay(log, rt)
    except (OOMError, ThrashError) as e:
        ok, err, kind = False, str(e), classify_error(rt, e)
    return result_from_runtime(rt, budget, ok=ok, error=err,
                               error_kind=kind), victims


#: RunResult fields that must be identical between the index and the scan
#: oracle (meta_accesses legitimately differs: that is the point of the
#: index).
PARITY_FIELDS = ("ok", "evictions", "remat_ops", "ops_executed",
                 "compute", "base_compute", "peak_memory", "slowdown",
                 "stall_time", "offloads", "fetches", "prefetch_hits",
                 "overhead", "degradations")


def verify_oracle_equivalence(log: Log, *, heuristics=SEPARABLE,
                              fractions=DEFAULT_FRACTIONS,
                              dealloc: str = "eager",
                              budget_mode: str = "activation",
                              thrash_factor: float = 50.0,
                              offload=None) -> dict:
    """Index-vs-scan bit-exactness over a fraction × heuristic grid.

    Budgets default to the activation range (``pinned + f * (peak -
    pinned)``): captured serving traces pin their weights, so total-peak
    fractions below the weight floor would make every cell trivially OOM.
    Returns ``{"ok": bool, "cells": n, "mismatches": [...]}`` where each
    mismatch names the cell and the first diverging field or victim.
    """
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    mismatches = []
    index_results: dict[tuple[str, float], RunResult] = {}
    cells = 0
    for h in heuristics:
        for f in fractions:
            cells += 1
            budget = resolve_budget(f, peak, pinned, budget_mode)
            scan_res, scan_victims = run_trace(
                log, h, budget, dealloc=dealloc, index=False,
                thrash_factor=thrash_factor, offload=offload)
            idx_res, idx_victims = run_trace(
                log, h, budget, dealloc=dealloc, index=True,
                thrash_factor=thrash_factor, offload=offload)
            idx_res.budget = f  # report as fraction (sweep convention)
            index_results[(h, f)] = idx_res
            bad = [fld for fld in PARITY_FIELDS
                   if getattr(scan_res, fld) != getattr(idx_res, fld)]
            if scan_victims != idx_victims:
                div = next((i for i, (a, b) in
                            enumerate(zip(scan_victims, idx_victims))
                            if a != b), min(len(scan_victims),
                                            len(idx_victims)))
                bad.append(f"victims@{div}")
            if bad:
                mismatches.append({"heuristic": h, "fraction": f,
                                   "fields": bad})
    return {"ok": not mismatches, "cells": cells, "mismatches": mismatches,
            "trace": log.name, "baseline_peak": peak,
            "index_results": index_results}


def _finite(x):
    """JSON-safe scalar: non-finite floats become None (strict JSON has no
    Infinity/NaN literals, and downstream plotters choke on the informal
    extensions ``json.dump`` emits by default)."""
    if isinstance(x, float) and (x != x or x in (float("inf"),
                                                 float("-inf"))):
        return None
    return x


def run_to_dict(r: RunResult) -> dict:
    """``asdict`` with non-finite floats nulled (``ok`` already encodes
    failure; an infinite slowdown/overhead carries no extra information)."""
    return {k: _finite(v) for k, v in asdict(r).items()}


def _reject_nonfinite(value: str):
    raise ValueError(
        f"non-finite literal {value!r} in report JSON; regenerate it with "
        f"repro.trace (non-finite fields are serialized as null)")


def load_report(path) -> dict | list:
    """Load a benchmark/report JSON, rejecting Infinity/NaN literals.

    The CI report-validation step loads every committed BENCH_*.json
    through this, so the informal extensions Python's encoder used to leak
    (``Infinity``) can never land in the repo again."""
    import json
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f, parse_constant=_reject_nonfinite)


def replay_budget_curve(logs, *, heuristics=("h_dtr", "h_dtr_eq", "h_lru"),
                        fractions=DEFAULT_FRACTIONS, dealloc: str = "eager",
                        index: bool = True, processes: int | None = None,
                        alloc_mode: str | None = None,
                        budget_mode: str = "activation",
                        thrash_factor: float = 50.0,
                        offload=None) -> list[dict]:
    """Budget curves for captured traces via the parallel sweep driver.

    One entry per (trace, heuristic): budget fraction -> slowdown / remat /
    peak, plus the smallest non-thrashing budget (the number serving uses to
    size per-replica memory).
    """
    logs = [logs] if isinstance(logs, Log) else list(logs)
    sweeps = sweep_parallel(logs, list(heuristics), list(fractions),
                            dealloc=dealloc, index=index,
                            alloc_mode=alloc_mode, processes=processes,
                            budget_mode=budget_mode,
                            thrash_factor=thrash_factor, offload=offload)
    out = []
    for sw in sweeps:
        out.append({
            "trace": sw.log_name,
            "heuristic": sw.heuristic,
            "baseline_peak": sw.baseline_peak,
            "min_feasible_fraction": min(
                (r.budget for r in sw.runs if r.ok), default=None),
            "last_ok_before_thrash": sw.last_ok_before_thrash(),
            "runs": [run_to_dict(r) for r in sw.runs],
        })
    return out


def static_gap_curve(log: Log, *, fractions=(0.9, 0.7, 0.5),
                     heuristics=("h_dtr", "h_dtr_eq"),
                     thrash_factor: float = 10.0,
                     budget_mode: str = "activation",
                     max_candidates: int = 512,
                     execute: bool = False) -> dict:
    """DTR-vs-static-optimal overhead cells for one captured trace.

    The Checkmate-bridge comparison both ``benchmarks.perf_static`` and
    the golden gap gate consume: per budget fraction, the LP recompute
    floor, the model-level solver ladder (heterogeneous DP vs the two
    Chen baselines on the extracted chain), the best *eval-feasible*
    static plan from the ``repro.static`` panel (judged by the exact
    evaluator, so feasibility means the replayed peak truly fits), and
    the online DTR rows at the same budgets with their gap ratios.

    ``execute=True`` additionally replays each winning plan through the
    real runtime and records the evaluator-vs-executor parity booleans
    (plans recur across cells, so executions are cached by keep-set).
    """
    from ..static import (best_static_plan, build_frontier, build_view,
                          chen_greedy, chen_sqrt, compile_plan,
                          execute_plan, extract_chain, lp_lower_bound,
                          optimal_dp)
    peak, base_cost = measure_baseline(log)
    pinned = log.pinned_bytes()
    view = build_view(log)
    chain = extract_chain(view, max_candidates=max_candidates)
    frontier = build_frontier(view, chain)
    exec_cache: dict[frozenset, dict] = {}
    cells = []
    for f in sorted(fractions, reverse=True):
        budget = resolve_budget(f, peak, pinned, budget_mode)
        lp = lp_lower_bound(view, budget)
        dp = optimal_dp(chain, budget)
        cs, cg = chen_sqrt(chain, budget), chen_greedy(chain, budget)
        best = best_static_plan(view, chain, frontier, budget)
        cell = {
            "fraction": f, "budget": budget,
            "lp": {"value": _finite(lp.value), "exact": lp.exact,
                   "solver": lp.solver, "infeasible": lp.infeasible},
            "model": {
                "dp_cost": dp.cost if dp else None,
                "dp_peak": dp.peak if dp else None,
                "dp_via": dp.meta.get("via", "dp") if dp else None,
                "chen_sqrt_cost": cs.cost, "chen_sqrt_peak": cs.peak,
                "chen_greedy_cost": cg.cost, "chen_greedy_peak": cg.peak,
                "dp_le_chen": (dp.cost <= min(cs.cost, cg.cost) + 1e-9
                               if dp else None),
                "lp_le_dp": (lp.value <= dp.cost + 1e-9
                             if dp and lp.value != float("inf") else None),
            },
            "static": None, "dtr": {},
        }
        if best is not None:
            extra = best.compute - best.ev.base_compute
            st = {"source": best.source,
                  "n_drop": len(chain) - len(best.keep),
                  "peak": best.peak, "compute": best.compute,
                  "overhead": round(best.overhead, 6),
                  "remat_ops": best.ev.remat_ops,
                  "evictions": best.ev.evictions,
                  "lp_le_extra": (lp.value <= extra + 1e-9
                                  if lp.value != float("inf") else False)}
            if execute:
                if best.keep not in exec_cache:
                    rr = execute_plan(log, compile_plan(view, chain,
                                                        best.keep))
                    exec_cache[best.keep] = {
                        "remat_match": rr.remat_ops == best.ev.remat_ops,
                        "evict_match": rr.evictions == best.ev.evictions,
                        "compute_match":
                            abs(rr.compute - best.compute) < 1e-9,
                        "peak_match": rr.peak_memory == best.peak}
                st["exec"] = exec_cache[best.keep]
            cell["static"] = st
        for h in heuristics:
            r = simulate(log, h, budget, thrash_factor=thrash_factor)
            row = {"ok": r.ok, "overhead": _finite(round(r.overhead, 6)),
                   "compute": _finite(r.compute), "peak": r.peak_memory,
                   "remat_ops": r.remat_ops,
                   "gap_vs_static": None, "extra_ge_lp": None}
            if r.ok:
                row["extra_ge_lp"] = (r.compute - r.base_compute
                                      >= lp.value - 1e-9)
                if best is not None:
                    row["gap_vs_static"] = round(r.compute / best.compute,
                                                 6)
            cell["dtr"][h] = row
        cells.append(cell)
    return {"trace": log.name, "baseline_peak": peak,
            "baseline_cost": base_cost, "pinned": pinned,
            "n_ops": view.n_ops, "n_candidates": len(chain),
            "frontier_points": len(frontier.points),
            "frontier_min_peak": frontier.min_peak(), "cells": cells}


def smallest_budget(log: Log, heuristic: str = "h_dtr_eq",
                    fractions=DEFAULT_FRACTIONS,
                    budget_mode: str = "activation") -> float | None:
    """Smallest feasible budget fraction (serving memory sizing helper)."""
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    feasible = None
    for f in sorted(fractions, reverse=True):
        r = simulate(log, heuristic,
                     resolve_budget(f, peak, pinned, budget_mode))
        if r.ok:
            feasible = f
    return feasible
