"""CLI: capture, replay, and report on DTR workload traces.

  # Capture a continuous-batching serve trace (smoke scale) and verify that
  # scan and index engines replay it bit-exactly:
  python -m repro.trace capture --smoke --out serve.log --verify

  # Replay an existing trace across budgets/heuristics:
  python -m repro.trace replay serve.log --fractions 0.5 0.3

  # Budget-curve report over the standard smoke trace set:
  python -m repro.trace report --smoke --out BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import sys

from ..core.graph import Log
from . import capture as C
from . import replay as R

SOURCES = ("serve", "serve-step", "train-step", "eager-mlp", "treelstm",
           "random-dag")

#: replay/report heuristic trio when --heuristics is not given (--verify
#: instead defaults to every separable heuristic).
DEFAULT_HEURISTICS = ("h_dtr", "h_dtr_eq", "h_lru")


def _capture(args) -> Log:
    if args.source == "serve":
        model = C.step_model_from_config(args.arch, smoke=args.smoke,
                                         use_jaxpr_cost=args.jaxpr_cost)
        return C.capture_serve_trace(
            model, slots=args.slots, requests=args.requests, gen=args.gen,
            seed=args.seed)
    if args.source == "serve-step":
        return C.capture_serve_step(args.arch, smoke=args.smoke,
                                    slots=args.slots,
                                    cost_model=args.cost_model)
    if args.source == "train-step":
        return C.capture_train_step(args.arch, smoke=args.smoke,
                                    cost_model=args.cost_model)
    if args.source == "eager-mlp":
        return C.capture_eager_mlp(seed=args.seed)
    if args.source == "treelstm":
        from ..core import graphs
        return graphs.treelstm(depth=4, width=32, seed=args.seed)
    if args.source == "random-dag":
        from ..core import graphs
        return graphs.random_dag(120, seed=args.seed)
    raise SystemExit(f"unknown source {args.source}")


def _verify(log: Log, fractions, thrash_factor=50.0,
            heuristics=None) -> int:
    kw = {"heuristics": tuple(heuristics)} if heuristics else {}
    rep = R.verify_oracle_equivalence(log, fractions=fractions,
                                      thrash_factor=thrash_factor, **kw)
    status = "OK" if rep["ok"] else "MISMATCH"
    n_h = rep['cells'] // max(len(fractions), 1)
    print(f"verify[{log.name}]: {status} over {rep['cells']} cells "
          f"({n_h} heuristics x {len(fractions)} fractions)")
    for m in rep["mismatches"]:
        print(f"  MISMATCH {m['heuristic']}@{m['fraction']}: {m['fields']}")
    return 0 if rep["ok"] else 1


def cmd_capture(args) -> int:
    log = _capture(args)
    text = log.dumps()
    with open(args.out, "w") as f:
        f.write(text + "\n")
    print(f"captured {log.name}: {log.op_count()} ops, "
          f"{len(log)} instructions, baseline_cost={log.baseline_cost():.3g} "
          f"-> {args.out}")
    if args.verify:
        return _verify(log, tuple(args.fractions), args.thrash_factor)
    return 0


def cmd_replay(args) -> int:
    with open(args.trace) as f:
        log = Log.loads(f.read())
    if args.verify:
        # --verify honors --heuristics so CI can gate a single heuristic
        # (e.g. h_dtr_eq on the golden corpus) without replaying the full
        # separable family per trace.
        return _verify(log, tuple(args.fractions), args.thrash_factor,
                       heuristics=args.heuristics)
    curves = R.replay_budget_curve(
        log, heuristics=tuple(args.heuristics or DEFAULT_HEURISTICS),
        fractions=tuple(args.fractions), index=not args.scan,
        processes=args.processes, thrash_factor=args.thrash_factor)
    for c in curves:
        print(f"{c['trace']} {c['heuristic']}: "
              f"min_feasible={c['min_feasible_fraction']}")
        for r in c["runs"]:
            state = (f"slowdown={r['slowdown']:.3f}" if r["ok"]
                     else f"FAIL({r['error'][:40]})")
            print(f"  {r['budget']:.2f}: {state} evictions={r['evictions']} "
                  f"remats={r['remat_ops']}")
    return 0


def _smoke_trace_set(args) -> list[Log]:
    """The standard report set: serve at two slot widths + a train step."""
    model = C.step_model_from_config(args.arch, smoke=True)
    logs = [
        C.capture_serve_trace(model, slots=2, requests=8, gen=12,
                              seed=args.seed, name="serve_smoke_s2"),
        C.capture_serve_trace(model, slots=4, requests=12, gen=16,
                              seed=args.seed, name="serve_smoke_s4"),
        C.capture_train_step(args.arch, smoke=True, batch=2, seq=16,
                             cost_model="flops"),
    ]
    return logs


def cmd_report(args) -> int:
    args.heuristics = list(args.heuristics or DEFAULT_HEURISTICS)
    if args.traces:
        logs = []
        for path in args.traces:
            with open(path) as f:
                logs.append(Log.loads(f.read()))
    else:
        logs = _smoke_trace_set(args)
    # Equivalence gate over the *reported* heuristics (capture --verify is
    # the all-separable-heuristics gate; h_dtr/h_msps e*-walks on long
    # train traces are too slow to re-verify on every report).  The verify
    # pass already replayed every index cell, so the budget curves are
    # assembled from its results instead of re-simulating the grid.
    verified = [R.verify_oracle_equivalence(
        log, heuristics=tuple(args.heuristics),
        fractions=tuple(args.fractions),
        thrash_factor=args.thrash_factor) for log in logs]
    curves = []
    for log, rep in zip(logs, verified):
        index_results = rep.pop("index_results")
        for h in args.heuristics:
            runs = [index_results[(h, f)] for f in args.fractions]
            curves.append({
                "trace": log.name,
                "heuristic": h,
                "baseline_peak": rep["baseline_peak"],
                "min_feasible_fraction": min(
                    (r.budget for r in runs if r.ok), default=None),
                "last_ok_before_thrash": min(
                    (r.budget for r in runs if r.ok and r.slowdown < 2.0),
                    default=None),
                "runs": [R.run_to_dict(r) for r in runs],
            })
    report = {
        "traces": [{"name": log.name, "ops": log.op_count(),
                    "instructions": len(log), "meta": log.meta}
                   for log in logs],
        "equivalence": [{k: v for k, v in rep.items()} for rep in verified],
        "equivalence_failures": sum(len(r["mismatches"]) for r in verified),
        "curves": curves,
    }
    with open(args.out, "w") as f:
        # allow_nan=False: strict JSON only.  Failed runs carry ok=False
        # with nulled slowdown/overhead (run_to_dict), never ``Infinity``.
        json.dump(report, f, indent=1, sort_keys=True, allow_nan=False)
    ok = report["equivalence_failures"] == 0
    print(f"report: {len(logs)} traces x {len(args.heuristics)} heuristics "
          f"x {len(args.fractions)} fractions -> {args.out} "
          f"(equivalence {'OK' if ok else 'FAILED'})")
    for c in curves:
        print(f"  {c['trace']} {c['heuristic']}: "
              f"min_feasible={c['min_feasible_fraction']}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.trace")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--arch", default="qwen2-0.5b")
        p.add_argument("--smoke", action="store_true")
        p.add_argument("--seed", type=int, default=0)
        # Default None so --verify can distinguish "user narrowed the
        # family" (gate those heuristics only) from "unset" (gate every
        # separable heuristic); non-verify paths fall back to the report
        # trio below.
        p.add_argument("--heuristics", nargs="+", default=None)
        p.add_argument("--fractions", nargs="+", type=float,
                       default=list(R.DEFAULT_FRACTIONS))
        p.add_argument("--processes", type=int, default=None)
        p.add_argument("--thrash-factor", type=float, default=50.0,
                       help="abort a cell once compute exceeds this multiple "
                            "of the baseline (reports as thrash)")

    cap = sub.add_parser("capture", help="capture a workload trace")
    common(cap)
    cap.add_argument("--source", choices=SOURCES, default="serve")
    cap.add_argument("--slots", type=int, default=4)
    cap.add_argument("--requests", type=int, default=12)
    cap.add_argument("--gen", type=int, default=16)
    cap.add_argument("--cost-model", choices=("hlo", "flops", "unit"),
                     default="hlo")
    cap.add_argument("--jaxpr-cost", action="store_true",
                     help="derive serve-driver decode cost from the traced "
                          "step instead of the analytic 2*params estimate")
    cap.add_argument("--out", default="trace.log")
    cap.add_argument("--verify", action="store_true",
                     help="replay scan-vs-index over all separable "
                          "heuristics and fail on any divergence")
    cap.set_defaults(fn=cmd_capture)

    rep = sub.add_parser("replay", help="replay a captured trace")
    common(rep)
    rep.add_argument("trace")
    rep.add_argument("--scan", action="store_true",
                     help="use the linear-scan oracle instead of the index")
    rep.add_argument("--verify", action="store_true")
    rep.set_defaults(fn=cmd_replay)

    rpt = sub.add_parser("report", help="budget-curve report (JSON)")
    common(rpt)
    rpt.add_argument("--traces", nargs="*", default=None,
                     help="trace files; default: capture the smoke set")
    rpt.add_argument("--out", default="BENCH_serving.json")
    rpt.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
