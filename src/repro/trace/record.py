"""TraceRecorder: mirror an eager DTR execution into a ``core.graph.Log``.

Attach a recorder to a :class:`repro.eager.DTRContext` and every ``wrap`` /
``call`` / ``release`` is re-emitted as ``Constant`` / ``Call`` / ``Release``
instructions with the *real* output sizes and the costs the runtime charged.
Rematerializations are deliberately not recorded — the log is the operator
stream the framework issued, exactly what the paper's instrumented PyTorch
prototype logs (Appendix C.6); replaying it reproduces the runtime's
decisions from scratch.

Use ``use_wallclock_cost=False`` on the context when capturing golden traces:
unit costs make the captured log (and therefore every replay decision)
bit-reproducible across hosts.
"""
from __future__ import annotations

from ..core.graph import Log, LogBuilder, as_meta


class TraceRecorder:
    """Builds a Log from eager-executor events (wrap/call/release)."""

    def __init__(self, name: str = "eager", meta=None) -> None:
        self.builder = LogBuilder(name=name)
        self.builder.log.meta = dict({"source": "eager"}, **(meta or {}))
        self._names: dict[int, str] = {}        # runtime tid -> log tensor
        self._released: set[int] = set()
        self._op_meta: dict | None = None       # one-shot tag for next event

    # ------------------------------------------------------------------
    # Tagging
    # ------------------------------------------------------------------
    def tag(self, **meta) -> "TraceRecorder":
        """Attach metadata to the next recorded instruction (one-shot)."""
        self._op_meta = meta
        return self

    def _take_meta(self, extra: dict | None = None):
        m = dict(self._op_meta or {})
        if extra:
            m.update(extra)
        self._op_meta = None
        return as_meta(m)

    # ------------------------------------------------------------------
    # Event hooks (called by DTRContext)
    # ------------------------------------------------------------------
    def on_constant(self, tid: int, name: str, nbytes: int,
                    shape=None, dtype=None) -> None:
        extra = {}
        if shape is not None:
            extra["shape"] = "x".join(map(str, shape)) or "scalar"
        if dtype is not None:
            extra["dtype"] = str(dtype)
        t = f"{name}.{tid}"
        self.builder.constant(nbytes, name=t, meta=self._take_meta(extra))
        self._names[tid] = t

    def on_call(self, op: str, cost: float, in_tids, out_tids,
                out_sizes, shapes=None) -> None:
        extra = {}
        if shapes is not None:
            extra["shapes"] = ";".join(
                "x".join(map(str, s)) or "scalar" for s in shapes)
        ins = [self._names[t] for t in in_tids]
        outs = [f"{op}.{t}" for t in out_tids]
        self.builder.call(ins, [int(s) for s in out_sizes], float(cost), op,
                          out_names=outs, meta=self._take_meta(extra))
        for t, nm in zip(out_tids, outs):
            self._names[t] = nm

    def on_release(self, tid: int) -> None:
        if tid in self._released:
            return
        self._released.add(tid)
        self.builder.release(self._names[tid], meta=self._take_meta())

    # ------------------------------------------------------------------
    # Finalization
    # ------------------------------------------------------------------
    def finish(self, release_rest: bool = False, keep=()) -> Log:
        """Return the captured Log.

        ``release_rest=True`` appends RELEASE for every tensor the program
        never dropped (except log names in ``keep``), modelling the end of
        the Python scope; by default unreleased tensors stay externally
        referenced, so replay's output condition pins them — matching the
        live eager context.
        """
        if release_rest:
            keep = set(keep)
            for tid, nm in self._names.items():
                if tid not in self._released and nm not in keep:
                    self._released.add(tid)
                    self.builder.release(nm)
        return self.builder.log
