"""Trace capture & replay: serve/train workloads as first-class DTR logs.

The bridge between the ``repro.launch`` production layer and the ``repro.core``
DTR engine: capture operator streams from the eager executor, from jaxpr-
lowered serve/train steps, or from a continuous-batching serve driver — then
replay them through the simulator to verify engine equivalence and size
memory budgets on *real* dynamic traces instead of hand-built DAGs.

CLI: ``python -m repro.trace capture|replay|report``.
"""
from .capture import (ServeStepModel, WorkloadTrace, capture_eager_mlp,
                      capture_eager_treelstm, capture_jaxpr,
                      capture_serve_step, capture_serve_trace,
                      capture_train_step, step_model_from_config)
from .record import TraceRecorder
from .replay import (DEFAULT_FRACTIONS, SEPARABLE, replay_budget_curve,
                     run_trace, smallest_budget, verify_oracle_equivalence)

__all__ = [
    "ServeStepModel", "WorkloadTrace", "TraceRecorder",
    "capture_eager_mlp", "capture_eager_treelstm", "capture_jaxpr",
    "capture_serve_step", "capture_serve_trace", "capture_train_step",
    "step_model_from_config",
    "DEFAULT_FRACTIONS", "SEPARABLE", "replay_budget_curve", "run_trace",
    "smallest_budget", "verify_oracle_equivalence",
]
