"""Capture operator streams from real serve/train workloads as DTR Logs.

Three capture sources (the bridge between ``repro.launch`` and the DTR core):

* :func:`capture_jaxpr` — walk the jaxpr of any step function (per-eqn sizes
  from avals; costs from the analytic FLOPs model, rescaled against the
  loop-aware optimized-HLO analysis ``repro.analysis.hlo_cost`` when the step
  compiles, unit costs as the last resort).
* :func:`capture_serve_step` / :func:`capture_train_step` — the above applied
  to ``launch.steps.make_serve_step`` / ``make_train_step`` over
  ``ShapeDtypeStruct`` trees (no parameter allocation needed).
* :class:`WorkloadTrace` + :func:`capture_serve_trace` — a continuous-batching
  decode driver at the slot level: per-request KV caches grow token by token,
  finished slots retire their storages and are immediately refilled, so the
  captured log exercises the interleaved dynamic lifetimes no synthetic graph
  in ``core.graphs`` produces.  Every instruction is tagged with
  request/slot/position metadata.
"""
from __future__ import annotations

import dataclasses
import random
from collections import deque
from dataclasses import dataclass
from typing import Callable

from ..core.graph import Call, Log, LogBuilder, Mutate
from ..core.planner import trace_to_log


# ---------------------------------------------------------------------------
# jaxpr capture
# ---------------------------------------------------------------------------

def _rewrite_costs(log: Log, fn: Callable[[float], float]) -> Log:
    out = [dataclasses.replace(i, cost=fn(i.cost))
           if isinstance(i, (Call, Mutate)) else i for i in log.instrs]
    return Log(out, name=log.name, meta=dict(log.meta))


def capture_jaxpr(fn, *args, name: str = "step",
                  cost_model: str = "hlo", meta=None,
                  unroll_scans: bool = True, **kwargs) -> Log:
    """Lower ``fn(*args)`` (traceable; args may be ShapeDtypeStructs) to a Log.

    ``cost_model``: ``"hlo"`` rescales per-eqn FLOPs so their total matches
    the loop-aware optimized-HLO analysis (falls back to ``"flops"`` when the
    step does not compile on this host); ``"flops"`` uses the analytic
    per-eqn estimate; ``"unit"`` assigns cost 1.0 per op (bit-reproducible
    across jax versions — used for golden traces).
    """
    assert cost_model in ("hlo", "flops", "unit")
    tg = trace_to_log(fn, *args, name=name, unroll_scans=unroll_scans,
                      **kwargs)
    log = tg.log
    log.meta = dict({"source": "jaxpr", "cost_model": cost_model,
                     "unroll_scans": bool(unroll_scans),
                     "ops": log.op_count()}, **(meta or {}))
    if cost_model == "unit":
        return _rewrite_costs(log, lambda c: 1.0)
    if cost_model == "hlo":
        try:
            import jax
            from ..analysis.hlo_cost import analyze
            hlo = jax.jit(fn).lower(*args, **kwargs).compile().as_text()
            total = analyze(hlo).flops
            if total > 0 and tg.total_flops > 0:
                scale = total / tg.total_flops
                log.meta["cost_model"] = "hlo"
                log.meta["hlo_flops"] = total
                return _rewrite_costs(log, lambda c: c * scale)
        except (ImportError, OSError, RuntimeError, ValueError,
                NotImplementedError):
            # No jax / no XLA backend / an unlowerable or uncompilable fn:
            # fall back to the analytic FLOPs costs.  Anything else (a
            # TypeError from bad args, a KeyError in the HLO parser) is a
            # capture bug and propagates.
            pass
        log.meta["cost_model"] = "flops"  # fallback actually used
    return log


def capture_serve_step(arch: str = "qwen2-0.5b", *, smoke: bool = True,
                       slots: int = 4, max_len: int = 64,
                       cost_model: str = "hlo") -> Log:
    """Log of one continuous-batching decode step (``make_serve_step``)."""
    from ..launch.steps import make_serve_step, serve_step_structs
    cfg, args = serve_step_structs(arch, smoke=smoke, slots=slots,
                                   max_len=max_len)
    return capture_jaxpr(
        make_serve_step(cfg), *args,
        name=f"serve_step_{arch}_s{slots}", cost_model=cost_model,
        meta={"arch": arch, "slots": slots, "max_len": max_len,
              "kind": "serve_step"})


def capture_train_step(arch: str = "qwen2-0.5b", *, smoke: bool = True,
                       batch: int = 2, seq: int = 16,
                       cost_model: str = "hlo") -> Log:
    """Log of one differentiated train step (fwd + bwd lifetimes)."""
    import jax
    import numpy as np
    from .. import configs
    from ..models import model as M
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    params = M.param_structs(cfg)
    tokens = jax.ShapeDtypeStruct(
        (batch, seq) if not cfg.n_codebooks
        else (batch, seq, cfg.n_codebooks), np.dtype("int32"))

    def step(p, t):
        return jax.value_and_grad(lambda pp: M.loss_fn(cfg, pp,
                                                       {"tokens": t}))(p)

    return capture_jaxpr(
        step, params, tokens,
        name=f"train_step_{arch}_b{batch}x{seq}", cost_model=cost_model,
        meta={"arch": arch, "batch": batch, "seq": seq, "kind": "train_step"})


# ---------------------------------------------------------------------------
# Continuous-batching serve driver (slot-level operator stream)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeStepModel:
    """Per-slot size/cost model for one decode step of a given config."""
    weight_bytes: int            # pinned parameter storage
    hidden_bytes: int            # per-slot residual-stream activation
    kv_token_bytes: int          # per-slot KV-cache growth per position
    decode_cost: float           # per-slot per-token step cost (flops)
    attn_token_cost: float       # extra cost per resident KV position
    prefill_token_cost: float    # per prompt token (chunked prefill)


def step_model_from_config(arch: str = "qwen2-0.5b", *, smoke: bool = True,
                           use_jaxpr_cost: bool = False) -> ServeStepModel:
    """Derive the slot-level model from the real architecture config.

    Sizes come from the parameter / KV-cache struct trees the launch layer
    allocates; costs are analytic (2 FLOPs per weight per token — the
    standard decode estimate) unless ``use_jaxpr_cost`` asks for the traced
    step's FLOPs total.  Everything is integer-derived, so the resulting
    traces are bit-reproducible across hosts and jax versions.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from .. import configs
    from ..models import model as M
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    probe_slots, probe_len = 2, 16
    p_leaves = jax.tree.leaves(M.param_structs(cfg))
    weight_bytes = int(sum(int(np.prod(x.shape, dtype=np.int64))
                           * np.dtype(x.dtype).itemsize for x in p_leaves))
    c_leaves = jax.tree.leaves(M.cache_structs(cfg, probe_slots, probe_len))
    cache_bytes = int(sum(int(np.prod(x.shape, dtype=np.int64))
                          * np.dtype(x.dtype).itemsize for x in c_leaves))
    kv_token_bytes = max(cache_bytes // (probe_slots * probe_len), 1)
    act_bytes = 2 if cfg.param_dtype in ("bfloat16", "float16") else 4
    hidden_bytes = int(cfg.d_model) * act_bytes
    # jnp.dtype, not np.dtype: plain numpy does not resolve "bfloat16".
    n_params = weight_bytes // max(
        jnp.dtype(cfg.param_dtype).itemsize, 1)
    decode_cost = 2.0 * n_params
    if use_jaxpr_cost:
        try:
            log = capture_serve_step(arch, smoke=smoke, slots=1,
                                     max_len=probe_len, cost_model="hlo")
            decode_cost = max(log.baseline_cost(), 1.0)
        except (ImportError, OSError, RuntimeError, ValueError,
                NotImplementedError):
            # Capture needs a working jax+backend; without one the
            # analytic 2*params decode cost above stands.
            pass
    kv_token_elems = kv_token_bytes // act_bytes
    return ServeStepModel(
        weight_bytes=weight_bytes, hidden_bytes=hidden_bytes,
        kv_token_bytes=kv_token_bytes, decode_cost=float(decode_cost),
        attn_token_cost=2.0 * kv_token_elems,
        prefill_token_cost=float(decode_cost))


class WorkloadTrace:
    """Emit a serving workload as a Log, one op stream per (request, slot).

    Used by the pure continuous-batching driver below and by
    ``launch/serve.py --capture`` (which mirrors the steps it actually
    executed).  The KV cache is *paged*: every ``kv_chunk`` positions the
    working cache seals into an immutable chunk storage that later decode
    steps read but never replace.  Chunks of idle slots are individually
    evictable, and rematerializing one replays the decode that sealed it —
    whose own inputs (the hidden state of that step, earlier chunks) may
    themselves be evicted — producing the deep, interleaved rematerialization
    chains that static training DAGs never exhibit.
    """

    def __init__(self, model: ServeStepModel, name: str = "serve_trace",
                 meta=None, kv_chunk: int = 4) -> None:
        self.model = model
        self.kv_chunk = max(int(kv_chunk), 1)
        self.b = LogBuilder(name=name)
        self.b.log.meta = dict(
            {"source": "serve_driver", "kv_chunk": self.kv_chunk,
             "step_model": dataclasses.asdict(model)}, **(meta or {}))
        self.params = self.b.constant(model.weight_bytes, name="params")
        # slot -> {"cur": name|None, "cur_len": int, "h": name,
        #          "chunks": [names], "klen": int}
        self._slot: dict[int, dict] = {}

    def _seal_if_full(self, st: dict) -> None:
        if st["cur"] is not None and st["cur_len"] >= self.kv_chunk:
            st["chunks"].append(st["cur"])
            st["cur"] = None
            st["cur_len"] = 0

    def prefill(self, rid: int, slot: int, plen: int) -> None:
        """Chunked prefill: one op per full page + the partial working page."""
        if plen < 1:
            raise ValueError(f"prefill needs plen >= 1, got {plen}")
        m = self.model
        st = {"cur": None, "cur_len": 0, "h": None, "chunks": [],
              "klen": 0, "rid": rid}
        done = 0
        while done < plen:
            take = min(self.kv_chunk, plen - done)
            outs = self.b.call(
                [self.params] + st["chunks"],
                [m.kv_token_bytes * take, m.hidden_bytes],
                m.prefill_token_cost * take + m.attn_token_cost * done,
                "prefill",
                out_names=[f"kv.r{rid}.{done + take}",
                           f"h.r{rid}.p{done + take}"],
                meta={"rid": rid, "slot": slot, "phase": "prefill",
                      "plen": plen, "pos": done})
            if st["h"] is not None:
                self.b.release(st["h"])
            st["cur"], st["h"] = outs
            st["cur_len"] = take
            st["klen"] = done + take
            done += take
            self._seal_if_full(st)
        self._slot[slot] = st

    def decode(self, rid: int, slot: int, pos: int,
               phase: str = "decode") -> None:
        m = self.model
        st = self._slot[slot]
        ins = [self.params, st["h"]] + st["chunks"]
        if st["cur"] is not None:
            ins.append(st["cur"])
        klen = st["klen"]
        kv2, h2 = self.b.call(
            ins,
            [m.kv_token_bytes * (st["cur_len"] + 1), m.hidden_bytes],
            m.decode_cost + m.attn_token_cost * klen, "decode",
            out_names=[f"kv.r{rid}.{klen + 1}", f"h.r{rid}.{klen + 1}"],
            meta={"rid": rid, "slot": slot, "pos": pos, "phase": phase})
        if st["cur"] is not None:
            self.b.release(st["cur"])
        self.b.release(st["h"])
        st["cur"], st["h"] = kv2, h2
        st["cur_len"] += 1
        st["klen"] = klen + 1
        self._seal_if_full(st)

    def retire(self, rid: int, slot: int) -> None:
        st = self._slot.pop(slot)
        first = True
        for c in st["chunks"]:
            self.b.release(c, meta={"rid": rid, "slot": slot,
                                    "phase": "retire"} if first else None)
            first = False
        if st["cur"] is not None:
            self.b.release(st["cur"])
        if st["h"] is not None:
            self.b.release(st["h"])

    def finish(self) -> Log:
        return self.b.log


def capture_serve_trace(model: ServeStepModel, *, slots: int = 4,
                        requests: int = 12, gen: int = 16,
                        prompt_min: int = 4, prompt_max: int = 12,
                        seed: int = 0, kv_chunk: int = 4,
                        name: str | None = None) -> Log:
    """Run the slot-level continuous-batching loop and capture it.

    True continuous batching (unlike the wave-based ``launch/serve.py``
    loop): a finished slot is refilled on the next global step while its
    neighbors keep decoding, so KV lifetimes start and end at arbitrary
    interleaved positions.
    """
    rng = random.Random(seed)
    queue = deque((rid, rng.randint(prompt_min, prompt_max))
                  for rid in range(requests))
    wt = WorkloadTrace(
        model, name=name or f"serve_s{slots}_r{requests}_g{gen}",
        kv_chunk=kv_chunk,
        meta={"slots": slots, "requests": requests, "gen": gen,
              "prompt_min": prompt_min, "prompt_max": prompt_max,
              "seed": seed})
    active: dict[int, dict] = {}
    step = 0
    while queue or active:
        for s in range(slots):
            if s not in active and queue:
                rid, plen = queue.popleft()
                wt.prefill(rid, s, plen)
                active[s] = {"rid": rid, "generated": 0}
        for s in sorted(active):
            st = active[s]
            wt.decode(st["rid"], s, step)
            st["generated"] += 1
            if st["generated"] >= gen:
                wt.retire(st["rid"], s)
                del active[s]
        step += 1
    log = wt.finish()
    log.meta["steps"] = step
    return log


# ---------------------------------------------------------------------------
# Eager-executor captures (TraceRecorder through real JAX buffers)
# ---------------------------------------------------------------------------

def capture_eager_mlp(*, steps: int = 2, din: int = 32, dh: int = 64,
                      batch: int = 16, seed: int = 0) -> Log:
    """Manual-backward MLP training loop through the eager DTR executor.

    Unit costs (``use_wallclock_cost=False``) keep the captured log — and
    every replay decision downstream — bit-reproducible across hosts.
    """
    import jax
    import jax.numpy as jnp
    from ..eager import DTRContext
    from .record import TraceRecorder
    rec = TraceRecorder(name=f"eager_mlp_s{steps}",
                        meta={"kind": "eager_mlp", "steps": steps,
                              "din": din, "dh": dh, "batch": batch})
    ctx = DTRContext(budget_bytes=float("inf"), use_wallclock_cost=False,
                     recorder=rec)
    key = jax.random.PRNGKey(seed)
    w1 = ctx.wrap(jax.random.normal(key, (din, dh)) * 0.05, name="w1")
    w2 = ctx.wrap(jax.random.normal(key, (dh, 1)) * 0.05, name="w2")
    xb = ctx.wrap(jax.random.normal(key, (batch, din)), name="x")
    yb = ctx.wrap(jnp.ones((batch, 1)), name="y")
    lr = 0.05
    for step in range(steps):
        rec.tag(step=step, phase="fwd")
        h = ctx.call("fc1", jnp.matmul, [xb, w1])[0]
        a = ctx.call("relu", jax.nn.relu, [h])[0]
        p = ctx.call("fc2", jnp.matmul, [a, w2])[0]
        e = ctx.call("err", jnp.subtract, [p, yb])[0]
        loss = ctx.call("mse", lambda t: jnp.mean(t * t), [e])[0]
        rec.tag(step=step, phase="bwd")
        gp = ctx.call("d_mse", lambda t: 2 * t / t.size, [e])[0]
        gw2 = ctx.call("d_w2", lambda a_, g: a_.T @ g, [a, gp])[0]
        ga = ctx.call("d_a", lambda g, w: g @ w.T, [gp, w2])[0]
        gh = ctx.call("d_relu", lambda g, h_: g * (h_ > 0), [ga, h])[0]
        gw1 = ctx.call("d_w1", lambda x_, g: x_.T @ g, [xb, gh])[0]
        w1_new = ctx.call("sgd1", lambda w, g: w - lr * g, [w1, gw1])[0]
        w2_new = ctx.call("sgd2", lambda w, g: w - lr * g, [w2, gw2])[0]
        for t in (h, a, p, e, loss, gp, gw2, ga, gh, gw1):
            t.release()
        w1.release()          # superseded weights (step-0: pinned constants)
        w2.release()
        w1, w2 = w1_new, w2_new
    return rec.finish()


def capture_eager_treelstm(*, depth: int = 3, dim: int = 32,
                           seed: int = 0) -> Log:
    """Data-dependent recursion (the paper's dynamic headline) captured live."""
    import jax.numpy as jnp
    from ..eager import DTRContext
    from .record import TraceRecorder
    rec = TraceRecorder(name=f"eager_treelstm_d{depth}",
                        meta={"kind": "eager_treelstm", "depth": depth,
                              "dim": dim})
    ctx = DTRContext(budget_bytes=float("inf"), use_wallclock_cost=False,
                     recorder=rec)
    w = ctx.wrap(jnp.eye(dim) * 0.5 + 0.01, name="w")

    def cell(a, b, d):
        rec.tag(depth=d)
        s = ctx.call("add", jnp.add, [a, b])[0]
        rec.tag(depth=d)
        out = ctx.call("cell", lambda s_, w_: jnp.tanh(s_ @ w_), [s, w])[0]
        s.release()
        a.release()
        b.release()
        return out

    def build(d, leaf_val):
        if d == 0:
            return ctx.wrap(jnp.full((dim,), leaf_val), name="leaf")
        return cell(build(d - 1, leaf_val), build(d - 1, leaf_val + 0.1), d)

    build(depth, 0.05)
    return rec.finish()
