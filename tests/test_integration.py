"""Integration tests: blocked-attention paths through full models, training
convergence on the structured synthetic data, end-to-end resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import layers as L
from repro.models import model as M
from repro.optim import adamw, apply_updates, clip_by_global_norm


class TestBlockedPaths:
    """The blocked (flash-style) attention paths must match the plain path
    through the FULL model, not just the kernel (covers masking, GQA
    grouping, RoPE interaction, MLA concat layout)."""

    def _loss(self, cfg, params, tokens):
        return float(jax.jit(lambda p: M.loss_fn(cfg, p,
                                                 {"tokens": tokens}))(params))

    @pytest.mark.parametrize("arch", ["llama3_2_1b", "gemma3_1b",
                                      "mixtral_8x7b"])
    def test_blocked_attention_matches_plain(self, arch, monkeypatch):
        cfg = configs.get_smoke(arch)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        tokens = jax.random.randint(key, (2, 128), 0, cfg.vocab)
        plain = self._loss(cfg, params, tokens)
        monkeypatch.setattr(L, "BLOCKED_ATTN_THRESHOLD", 64)
        blocked = self._loss(cfg, params, tokens)
        np.testing.assert_allclose(blocked, plain, rtol=1e-5)

    def test_blocked_mla_matches_plain(self, monkeypatch):
        cfg = configs.get_smoke("deepseek_v3_671b").replace(
            capacity_factor=8.0)
        key = jax.random.PRNGKey(1)
        params = M.init_params(cfg, key)
        tokens = jax.random.randint(key, (2, 128), 0, cfg.vocab)
        plain = self._loss(cfg, params, tokens)
        monkeypatch.setattr(L, "BLOCKED_ATTN_THRESHOLD", 64)
        blocked = self._loss(cfg, params, tokens)
        np.testing.assert_allclose(blocked, plain, rtol=1e-5)

    def test_blocked_gradients_match(self, monkeypatch):
        cfg = configs.get_smoke("llama3_2_1b")
        key = jax.random.PRNGKey(2)
        params = M.init_params(cfg, key)
        tokens = jax.random.randint(key, (2, 128), 0, cfg.vocab)
        g = jax.jit(jax.grad(lambda p: M.loss_fn(cfg, p,
                                                 {"tokens": tokens})))
        g_plain = g(params)
        monkeypatch.setattr(L, "BLOCKED_ATTN_THRESHOLD", 64)
        g_block = jax.jit(jax.grad(
            lambda p: M.loss_fn(cfg, p, {"tokens": tokens})))(params)
        for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_block)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-5)


class TestTrainingConverges:
    @pytest.mark.parametrize("arch", ["smollm_135m", "rwkv6_1_6b"])
    def test_loss_decreases(self, arch):
        """The structured synthetic stream (bigram permutation) is
        learnable; 40 steps must visibly reduce loss."""
        cfg = configs.get_smoke(arch).replace(vocab=128, dtype="float32")
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        opt = adamw(lr=3e-3)
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt))
        data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8)
        losses = []
        for i in range(40):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses[::8]

    def test_grad_accum_equivalence(self):
        """grad_accum=4 must match grad_accum=1 on the same global batch."""
        cfg = configs.get_smoke("llama3_2_1b").replace(dtype="float32")
        key = jax.random.PRNGKey(3)
        params = M.init_params(cfg, key)
        opt = adamw(lr=1e-3)
        tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab)
        batch = {"tokens": tokens}

        def one(ga):
            st = opt.init(params)
            step = jax.jit(make_train_step(cfg, opt, grad_accum=ga))
            p2, _, m = step(params, st, batch)
            return m["loss"], p2

        l1, p1 = one(1)
        l4, p4 = one(4)
        np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-6)


class TestResume:
    def test_train_resume_is_deterministic(self, tmp_path):
        """Interrupt-and-resume must land on the same weights as an
        uninterrupted run (checkpoint + seekable data pipeline)."""
        cfg = configs.get_smoke("qwen2_0_5b").replace(dtype="float32")
        key = jax.random.PRNGKey(0)
        opt = adamw(lr=1e-3)
        data = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4)
        step = jax.jit(make_train_step(cfg, opt))

        def run(n_steps, params, state, start=0):
            for i in range(start, n_steps):
                batch = {k: jnp.asarray(v)
                         for k, v in data.batch_at(i).items()}
                params, state, _ = step(params, state, batch)
            return params, state

        p0 = M.init_params(cfg, key)
        s0 = opt.init(p0)
        # Uninterrupted 8 steps.
        p_full, _ = run(8, p0, s0)
        # Interrupted: 4 steps, checkpoint, restore, 4 more.
        p_half, s_half = run(4, p0, s0)
        m = CheckpointManager(str(tmp_path), every_steps=1)
        m.save(3, {"params": p_half, "opt": s_half})
        stp, restored, _ = m.restore({"params": p_half, "opt": s_half})
        p_res, _ = run(8, restored["params"], restored["opt"], start=4)
        for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
