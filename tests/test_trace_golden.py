"""Golden-trace regression tests (`tests/traces/`, see make_golden.py).

Two invariants, for every committed trace:

1. **Engine equivalence** — replaying through the incremental eviction index
   and the linear-scan oracle produces bit-identical eviction decisions
   (full victim sequence, tie-breaks included) and identical RunResult
   counters, across every separable heuristic.
2. **Decision pinning** — replay results match the committed
   ``expected.json`` digests exactly, so any engine change that flips a
   single eviction decision fails here before it ships.

Capture determinism is asserted for the sources that are bit-reproducible
by construction (the serve driver, the eager executor with unit costs, and
the synthetic families); jaxpr-derived traces are pinned as committed files
only, since eqn sets move with jax versions.
"""
import hashlib
import json
import os

import pytest

from repro.core import graphs
from repro.core.graph import Log
from repro.core.simulator import measure_baseline, resolve_budget
from repro.trace import SEPARABLE, run_trace
from repro.trace.replay import PARITY_FIELDS

TRACE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "traces")
TRACES = ["serve_smoke_s2", "serve_smoke_s4", "train_smoke", "eager_mlp",
          "treelstm", "random_dag"]
THRASH = 3.0
# train_smoke is infeasible-by-thrash below ~0.8 (see README); the cells
# still replay deterministically but cost the thrash budget each, so the
# big-grid equivalence test keeps that trace to high fractions.
FRACTIONS = {"train_smoke": (0.9, 0.8)}
DEFAULT_FRACTIONS = (0.8, 0.5)


def load_trace(name: str) -> Log:
    with open(os.path.join(TRACE_DIR, f"{name}.log")) as f:
        return Log.loads(f.read())


@pytest.fixture(scope="module")
def expected():
    with open(os.path.join(TRACE_DIR, "expected.json")) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# 1. scan vs index bit-exactness over every separable heuristic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TRACES)
def test_scan_and_index_replay_bit_exact(name):
    log = load_trace(name)
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    for h in SEPARABLE:
        for f in FRACTIONS.get(name, DEFAULT_FRACTIONS):
            budget = resolve_budget(f, peak, pinned, "activation")
            scan_res, scan_victims = run_trace(
                log, h, budget, index=False, thrash_factor=THRASH)
            idx_res, idx_victims = run_trace(
                log, h, budget, index=True, thrash_factor=THRASH)
            assert scan_victims == idx_victims, (
                f"{name}/{h}@{f}: victim sequences diverge at "
                f"{next(i for i, (a, b) in enumerate(zip(scan_victims, idx_victims)) if a != b)}")  # noqa: E501
            for fld in PARITY_FIELDS:
                assert getattr(scan_res, fld) == getattr(idx_res, fld), (
                    f"{name}/{h}@{f}: {fld} scan={getattr(scan_res, fld)} "
                    f"index={getattr(idx_res, fld)}")


# ---------------------------------------------------------------------------
# 2. replay results match the committed expectations exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TRACES)
def test_replay_matches_expected(name, expected):
    log = load_trace(name)
    exp = expected[name]
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    assert repr(peak) == exp["baseline_peak"]
    assert pinned == exp["pinned"]
    for cell, want in exp["cells"].items():
        h, frac = cell.split("@")
        budget = resolve_budget(float(frac), peak, pinned, "activation")
        res, victims = run_trace(log, h, budget, index=True,
                                 thrash_factor=THRASH)
        got = {
            "ok": res.ok,
            "evictions": res.evictions,
            "remat_ops": res.remat_ops,
            "ops_executed": res.ops_executed,
            "compute": repr(res.compute),
            "peak_memory": repr(res.peak_memory),
            "victims_sha1": hashlib.sha1(
                ",".join(map(str, victims)).encode()).hexdigest(),
            "n_victims": len(victims),
        }
        assert got == want, f"{name}/{cell} drifted from golden"


# ---------------------------------------------------------------------------
# 3. heuristic fidelity: h_dtr_eq must track exact h_dtr on real traces
# ---------------------------------------------------------------------------

# The eq-vs-exact gate: at every pinned activation-budget point, h_dtr_eq's
# total compute must stay within this factor of exact h_dtr's.  Both runs
# are capped at FIDELITY_THRASH x baseline, so a cell where the union-find
# approximation thrashes while the exact walk stays healthy shows up as a
# ratio near the cap (e.g. the pre-fix train trace at 0.9: eq aborted at
# 10x while exact finished at 1.198x).
FIDELITY_RATIO = 1.5
FIDELITY_THRASH = 10.0


@pytest.mark.parametrize("name,fractions", [
    # 0.9/0.95: both heuristics are healthy (~1.05-1.2x) — the ratio is a
    # live tripwire for eq degradation.  0.6-0.8: the accumulated-gradient
    # residency floor saturates *every* heuristic (LRU included); the gate
    # still fails if eq ever does over 1.5x the work exact does.
    ("train_smoke", (0.95, 0.9, 0.8, 0.7, 0.6)),
    # Continuous-batching serve trace: retired-request dead cones are the
    # workload the dead-subgraph pruning targets.
    ("serve_smoke_s4", (0.7, 0.5)),
])
def test_eq_tracks_exact_on_real_traces(name, fractions):
    log = load_trace(name)
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    for f in fractions:
        budget = resolve_budget(f, peak, pinned, "activation")
        exact, _ = run_trace(log, "h_dtr", budget,
                             thrash_factor=FIDELITY_THRASH)
        eq, _ = run_trace(log, "h_dtr_eq", budget,
                          thrash_factor=FIDELITY_THRASH)
        assert eq.compute <= FIDELITY_RATIO * exact.compute, (
            f"{name}@{f}: h_dtr_eq compute {eq.compute:.3g} exceeds "
            f"{FIDELITY_RATIO}x exact h_dtr's {exact.compute:.3g} "
            f"(eq ok={eq.ok}, exact ok={exact.ok})")
        if exact.ok:
            assert eq.ok, (
                f"{name}@{f}: h_dtr_eq thrashes where exact h_dtr "
                f"holds {exact.slowdown:.3f}x")


# ---------------------------------------------------------------------------
# 4. DTR-vs-static-optimal gap gate (the Checkmate bridge, repro.static)
# ---------------------------------------------------------------------------

# The static panel is deterministic (solo screen + greedy frontier + DP
# ladder, all seedless), so the best eval-feasible plan per budget cell —
# and DTR's measured gap against it — are pinned exactly.  The pinned
# story: static-with-full-knowledge wins on train at 0.9 (gap > 1), DTR's
# adaptivity wins on treelstm (gap < 1), serve admits no static plan at
# all, and both fail together on eager_mlp at 0.5.
STATIC_GAP_TRACES = {
    "train_smoke": (0.9,),
    "eager_mlp": (0.9, 0.7, 0.5),
    "treelstm": (0.9, 0.5),
    "serve_smoke_s2": (0.9,),
}


@pytest.mark.parametrize("name", sorted(STATIC_GAP_TRACES))
def test_static_gap_matches_expected(name, expected):
    from repro.trace.replay import static_gap_curve
    exp = expected["static_gap"][name]
    log = load_trace(name)
    cur = static_gap_curve(log, fractions=STATIC_GAP_TRACES[name],
                           heuristics=("h_dtr",))
    assert cur["n_candidates"] == exp["n_candidates"]
    for cell in cur["cells"]:
        want = exp["cells"][repr(cell["fraction"])]
        st, d = cell["static"], cell["dtr"]["h_dtr"]
        got = {"feasible": st is not None, "dtr_ok": d["ok"]}
        if st is not None:
            got.update(n_drop=st["n_drop"], remat_ops=st["remat_ops"],
                       evictions=st["evictions"], peak=repr(st["peak"]),
                       compute=repr(st["compute"]))
        if d["gap_vs_static"] is not None:
            got["gap_h_dtr"] = repr(d["gap_vs_static"])
        assert got == want, (f"{name}@{cell['fraction']} static gap "
                             f"drifted from golden")
        # The LP floor must stay below the static winner's extra compute,
        # and below DTR's whenever DTR finished — the differential
        # validity check, re-proved on every run.
        if st is not None:
            assert st["lp_le_extra"]
        if d["ok"]:
            assert d["extra_ge_lp"]


# ---------------------------------------------------------------------------
# 5. deterministic sources re-capture to the committed bytes
# ---------------------------------------------------------------------------

def test_serve_driver_recapture_is_bit_identical():
    from repro.trace import ServeStepModel, capture_serve_trace
    with open(os.path.join(TRACE_DIR, "serve_smoke_s2.log")) as f:
        text = f.read()
    log = Log.loads(text)
    m = log.meta
    recaptured = capture_serve_trace(
        ServeStepModel(**m["step_model"]), slots=m["slots"],
        requests=m["requests"], gen=m["gen"], prompt_min=m["prompt_min"],
        prompt_max=m["prompt_max"], seed=m["seed"], kv_chunk=m["kv_chunk"],
        name=log.name)
    assert recaptured.dumps() + "\n" == text


def test_eager_mlp_recapture_is_bit_identical():
    from repro.trace import capture_eager_mlp
    with open(os.path.join(TRACE_DIR, "eager_mlp.log")) as f:
        text = f.read()
    assert capture_eager_mlp().dumps() + "\n" == text


@pytest.mark.parametrize("name,build", [
    ("treelstm", lambda: graphs.treelstm(depth=4, width=32, seed=0)),
    ("random_dag", lambda: graphs.random_dag(150, seed=0)),
])
def test_synthetic_recapture_is_bit_identical(name, build):
    with open(os.path.join(TRACE_DIR, f"{name}.log")) as f:
        text = f.read()
    assert build().dumps() + "\n" == text
