"""Skip-marked fallback for the optional ``hypothesis`` dependency.

When hypothesis is absent, ``@given(...)`` replaces the test with a stub that
skips at runtime, so property-based tests are reported as skipped while the
rest of the suite still collects and runs.  Install the real thing with
``pip install -e .[test]``.
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def stub(*_a, **_k):          # may be bound: accepts self
            pytest.skip("hypothesis not installed")
        stub.__name__ = fn.__name__
        stub.__doc__ = fn.__doc__
        return stub
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    """Accepts any ``st.<name>(...)`` call made at decoration time."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _Strategies()
