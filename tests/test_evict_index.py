"""Tests for the incremental eviction index (repro.core.evict_index).

The load-bearing property is *oracle equivalence*: with the index enabled
(default) the runtime must make bit-for-bit the same eviction decisions as
the exhaustive linear scan (``index=False``) — same evictions, same
rematerializations, same compute, same peak memory — across every
heuristic, deallocation policy, memory model, and seed log.  Only
``meta_accesses`` may (and should) differ: that is the point.
"""
import pytest

from repro.core import graphs, simulator
from repro.core.evict_index import EvictIndex, ScopedInvalidator
from repro.core.graph import replay
from repro.core.heuristics import ALL_NAMES, by_name, window_cost
from repro.core.runtime import DTRRuntime

# Every RunResult field except meta_accesses (which legitimately differs).
PARITY_FIELDS = ("budget", "ok", "slowdown", "compute", "base_compute",
                 "evictions", "remat_ops", "ops_executed", "peak_memory",
                 "error", "largest_free", "frag_ratio", "failed_fits",
                 "evict_windows")


def assert_parity(a, b, ctx=""):
    for f in PARITY_FIELDS:
        assert getattr(a, f) == getattr(b, f), f"{ctx}: {f} differs"


def both(log, heuristic, budget, **kw):
    a = simulator.simulate(log, heuristic, budget=budget, index=False, **kw)
    b = simulator.simulate(log, heuristic, budget=budget, index=True, **kw)
    return a, b


# ---------------------------------------------------------------------------
# Oracle equivalence
# ---------------------------------------------------------------------------

LOGS = [
    lambda: graphs.mlp(depth=8),
    lambda: graphs.random_dag(40, seed=3),
    lambda: graphs.linear_network(80),
]


class TestOracleEquivalence:
    @pytest.mark.parametrize("heuristic", ALL_NAMES + ["h_estar"])
    @pytest.mark.parametrize("dealloc", ["ignore", "eager", "banish"])
    def test_counter_mode(self, heuristic, dealloc):
        for mk in LOGS:
            log = mk()
            peak, _ = simulator.measure_baseline(log)
            for frac in (0.8, 0.5):
                a, b = both(log, heuristic, frac * peak, dealloc=dealloc)
                assert_parity(a, b, f"{log.name}/{heuristic}/{dealloc}/{frac}")

    @pytest.mark.parametrize("heuristic",
                             ["h_dtr", "h_dtr_eq", "h_lru", "h_size"])
    @pytest.mark.parametrize("dealloc", ["eager", "banish"])
    def test_pool_mode(self, heuristic, dealloc):
        """Window eviction must pick identical windows through the index's
        shared score cache (alloc_mode=pool)."""
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        for frac in (0.7, 0.5):
            a, b = both(log, heuristic, frac * peak, dealloc=dealloc,
                        alloc_mode="pool")
            assert_parity(a, b, f"pool/{heuristic}/{dealloc}/{frac}")

    def test_pool_nofrag_mode(self):
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        a, b = both(log, "h_dtr_eq", 0.6 * peak, alloc_mode="pool_nofrag")
        assert_parity(a, b, "pool_nofrag")

    def test_meta_accesses_reduced_on_chain(self):
        """The index must do strictly less metadata work than the scan on a
        pressure-heavy chain (the App. C.5/D.3 overhead it exists to cut)."""
        log = graphs.linear_network(300)
        peak, _ = simulator.measure_baseline(log)
        for h in ("h_dtr", "h_dtr_eq", "h_lru"):
            a, b = both(log, h, 0.3 * peak)
            assert_parity(a, b, h)
            assert b.meta_accesses < a.meta_accesses, h

    def test_models_equivalent(self):
        for log in (graphs.resnet(blocks=6),
                    graphs.transformer(layers=2, d=8, seq=4),
                    graphs.treelstm(depth=4)):
            peak, _ = simulator.measure_baseline(log)
            a, b = both(log, "h_dtr", 0.6 * peak)
            assert_parity(a, b, log.name)


# ---------------------------------------------------------------------------
# Index internals
# ---------------------------------------------------------------------------

class TestIndexInternals:
    def test_nonseparable_falls_back_to_scan(self):
        rt = DTRRuntime(budget=100, heuristic=by_name("h_rand"))
        assert rt.index is None

    def test_sampling_modes_fall_back_to_scan(self):
        rt = DTRRuntime(budget=100, heuristic=by_name("h_dtr"),
                        sample_sqrt=True)
        assert rt.index is None
        rt = DTRRuntime(budget=100, heuristic=by_name("h_dtr"),
                        ignore_small_frac=0.1)
        assert rt.index is None

    def test_index_opt_out(self):
        rt = DTRRuntime(budget=100, heuristic=by_name("h_dtr"), index=False)
        assert rt.index is None

    def test_membership_tracks_evictability(self):
        """The live set must equal the scan's candidate filter at any time."""
        log = graphs.mlp(depth=6)
        peak, _ = simulator.measure_baseline(log)
        rt = DTRRuntime(budget=0.6 * peak, heuristic=by_name("h_dtr_eq"))
        orig = EvictIndex.pick
        checked = [0]

        def checking_pick(self, exclude):
            truth = {s.sid for s in rt.storages.values()
                     if s.evictable() and s.size > 0}
            assert truth == self.members
            checked[0] += 1
            return orig(self, exclude)

        EvictIndex.pick = checking_pick
        try:
            replay(log, rt)
        finally:
            EvictIndex.pick = orig
        assert checked[0] > 0

    def test_pick_matches_linear_argmin(self):
        """Direct spot-check: index.pick == scan argmin on a live runtime."""
        log = graphs.random_dag(30, seed=7)
        peak, _ = simulator.measure_baseline(log)
        rt = DTRRuntime(budget=0.5 * peak, heuristic=by_name("h_dtr"))
        orig = EvictIndex.pick

        def checking_pick(self, exclude):
            got = orig(self, exclude)
            pool = rt._candidates(exclude)
            want = min(
                ((rt.heuristic.score(rt, s), s.sid) for s in pool),
                default=None)
            if want is None:
                assert got is None
            else:
                assert got is not None
                assert (rt.heuristic.score(rt, got), got.sid) == want
            return got

        EvictIndex.pick = checking_pick
        try:
            from repro.core.runtime import OOMError
            try:
                replay(log, rt)
            except OOMError:
                pass  # infeasible budget is a legal outcome; checks ran
        finally:
            EvictIndex.pick = orig

    def test_band_floor_is_admissible(self):
        for k in (1e-9, 0.3, 0.5, 0.99, 1.0, 1.5, 2.0, 3.14159, 1e6,
                  2.0 ** -0.75, 2.0 ** -0.5, 7.0 / 3.0):
            b = EvictIndex._band_of(k)
            idx = DTRRuntime(budget=1, heuristic=by_name("h_dtr")).index
            assert idx._floor_of(b) <= k
            assert idx._floor_of(b + 1) > k
        assert EvictIndex._band_of(0.0) == EvictIndex._ZERO_BAND


class TestScopedInvalidation:
    def test_eviction_only_invalidates_its_component(self):
        """Two disconnected chains: evicting in one must keep the other's
        cached e* entries alive (the global-version nuke is gone)."""
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_dtr"))
        c1, c2 = rt.constant(1), rt.constant(1)
        (a1,) = rt.call("a1", 1.0, [c1], [10])
        (b1,) = rt.call("b1", 1.0, [a1], [10])
        (a2,) = rt.call("a2", 1.0, [c2], [10])
        (b2,) = rt.call("b2", 1.0, [a2], [10])
        sa1, sb1 = rt.tensors[a1].sid, rt.tensors[b1].sid
        sa2, sb2 = rt.tensors[a2].sid, rt.tensors[b2].sid
        # Evict a1, then warm both chains' caches.
        rt._evict(rt.storages[sa1])
        for sid in (sb1, sb2):
            rt.evicted_neighborhood_cost(rt.storages[sid])
        assert sb1 in rt._estar_cache and sb2 in rt._estar_cache
        # Evicting a2 (chain 2) must drop b2's entry but keep b1's.
        rt._evict(rt.storages[sa2])
        assert sb1 in rt._estar_cache
        assert sb2 not in rt._estar_cache

    def test_remat_invalidates_component_consumers(self):
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_dtr"))
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 1.0, [a], [10])
        sa, sb = rt.tensors[a].sid, rt.tensors[b].sid
        rt._evict(rt.storages[sa])
        cost = rt.evicted_neighborhood_cost(rt.storages[sb])
        assert cost == pytest.approx(1.0)
        rt.get(a)  # rematerialize -> b's cached cost must drop
        assert sb not in rt._estar_cache
        assert rt.evicted_neighborhood_cost(rt.storages[sb]) == 0.0

    def test_alias_cost_change_invalidates_consumers(self):
        """Registering a view on an *evicted* storage grows its local cost;
        cached closures that summed it must be dropped."""
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_dtr"))
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 1.0, [a], [10])
        sa, sb = rt.tensors[a].sid, rt.tensors[b].sid
        rt._evict(rt.storages[sa])
        assert rt.evicted_neighborhood_cost(
            rt.storages[sb]) == pytest.approx(1.0)
        rt.call("view", 0.5, [b], [0], aliases=[a])
        assert sb not in rt._estar_cache
        assert rt.evicted_neighborhood_cost(
            rt.storages[sb]) == pytest.approx(1.5)

    def test_eq_cache_scoped(self):
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_dtr_eq"))
        c1, c2 = rt.constant(1), rt.constant(1)
        (a1,) = rt.call("a1", 1.0, [c1], [10])
        (b1,) = rt.call("b1", 1.0, [a1], [10])
        (a2,) = rt.call("a2", 1.0, [c2], [10])
        (b2,) = rt.call("b2", 1.0, [a2], [10])
        sa1, sb1 = rt.tensors[a1].sid, rt.tensors[b1].sid
        sa2, sb2 = rt.tensors[a2].sid, rt.tensors[b2].sid
        rt._evict(rt.storages[sa1])
        rt._evict(rt.storages[sa2])
        rt.eq_neighborhood_cost(rt.storages[sb1])
        rt.eq_neighborhood_cost(rt.storages[sb2])
        assert sb1 in rt._eq_cache and sb2 in rt._eq_cache
        rt.get(a2)  # remat in chain 2 only
        assert sb1 in rt._eq_cache
        assert sb2 not in rt._eq_cache

    def test_cached_costs_match_scratch_recomputation(self):
        """Under-invalidation detector.  The linear-scan oracle shares the
        scoped caches, so index-vs-oracle equivalence alone cannot catch a
        missed invalidation — both engines would make the same wrong
        decision.  This check recomputes every cached e*/ẽ* entry from
        scratch at every victim selection and demands bit-equality."""

        def scratch_estar(rt, s):
            # Mirrors the live-walk semantics: dead storages are pruned,
            # their cone cost charged through each member's dead_cost.
            total, seen = 0.0, set()
            stack = [d for d in s.deps if rt._is_evicted(d)]
            while stack:
                x = stack.pop()
                if x in seen:
                    continue
                seen.add(x)
                xs = rt.storages[x]
                total += xs.local_cost + xs.dead_cost
                stack.extend(d for d in xs.deps
                             if rt._is_evicted(d) and d not in seen)
            stack = [c for c in s.children if rt._is_evicted(c)]
            while stack:
                x = stack.pop()
                if x in seen:
                    continue
                seen.add(x)
                xs = rt.storages[x]
                total += xs.local_cost + xs.dead_cost
                stack.extend(c for c in xs.children
                             if rt._is_evicted(c) and c not in seen)
            return total

        def scratch_eq(rt, s):
            # Mirrors eq_neighborhood_cost's full walk: sorted neighbor
            # order (the float-summation contract of the snapshot fast
            # path), dead members included.
            roots, total = set(), 0.0
            for nsid in sorted(s.deps | s.children):
                ns = rt.storages[nsid]
                if not ns.resident and not ns.banished:
                    r = rt.uf.find(ns.uf)
                    if r not in roots:
                        roots.add(r)
                        total += rt.uf._cost[r]
            return total

        orig = EvictIndex.pick
        checked = [0]

        def checking_pick(self, exclude):
            rt = self.rt
            for sid, (val, _n) in list(rt._estar_cache.items()):
                assert val == scratch_estar(rt, rt.storages[sid]), sid
                checked[0] += 1
            if rt.uf is not None:
                for sid, val in list(rt._eq_cache.items()):
                    assert val == scratch_eq(rt, rt.storages[sid]), sid
                    checked[0] += 1
            return orig(self, exclude)

        EvictIndex.pick = checking_pick
        try:
            for log, h in ((graphs.mlp(depth=8), "h_dtr"),
                           (graphs.random_dag(40, seed=3), "h_dtr"),
                           (graphs.mlp(depth=8), "h_dtr_eq")):
                peak, _ = simulator.measure_baseline(log)
                for dealloc in ("eager", "banish"):
                    simulator.simulate(log, h, budget=0.5 * peak,
                                       dealloc=dealloc)
        finally:
            EvictIndex.pick = orig
        assert checked[0] > 0

    def test_invalidator_counts(self):
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_dtr"))
        assert isinstance(rt._invalidator, ScopedInvalidator)
        c = rt.constant(1)
        rt.call("a", 1.0, [c], [10])
        assert rt._invalidator.invalidations > 0


# ---------------------------------------------------------------------------
# Incremental component sums + exact split invalidation
# ---------------------------------------------------------------------------

def brute_component_sum(rt, s):
    """Re-derive s's component sum from member costs (ground truth)."""
    root = rt.uf.find(s.uf)
    return sum(x.local_cost for x in rt.storages.values()
               if x.uf_joined and rt.uf.find(x.uf) == root)


class TestIncrementalComponentSums:
    def _chain_rt(self, n=6):
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_dtr_eq"))
        c = rt.constant(1)
        tids = [c]
        for i in range(n):
            (t,) = rt.call(f"op{i}", float(i + 1), [tids[-1]], [10])
            tids.append(t)
        return rt, tids

    def test_union_and_add_cost_track_members(self):
        """Per-root sums maintained on union/add_cost equal a brute-force
        re-walk over the members."""
        rt, tids = self._chain_rt()
        sids = [rt.tensors[t].sid for t in tids[1:]]
        for t in tids[1:4]:
            rt._evict(rt.storages[rt.tensors[t].sid])
        s1 = rt.storages[sids[0]]
        assert rt.uf._cost[rt.uf.find(s1.uf)] == brute_component_sum(rt, s1)
        # Alias registration on an evicted member grows the sum in place.
        rt.call("view", 2.5, [tids[5]], [0], aliases=[tids[2]])
        assert rt.uf._cost[rt.uf.find(s1.uf)] == brute_component_sum(rt, s1)

    def test_split_approx_subtracts_member(self):
        rt, tids = self._chain_rt()
        # Evict only tids[2], tids[3]: tids[1] stays resident so the remat
        # of tids[2] below detaches exactly one member.
        for t in (tids[2], tids[3]):
            rt._evict(rt.storages[rt.tensors[t].sid])
        s2 = rt.storages[rt.tensors[tids[2]].sid]
        s3 = rt.storages[rt.tensors[tids[3]].sid]
        before = rt.uf._cost[rt.uf.find(s2.uf)]
        assert before == pytest.approx(s2.local_cost + s3.local_cost)
        rt.get(tids[2])  # remat: split_approx detaches tids[2]'s storage
        assert not s2.uf_joined
        after = rt.uf._cost[rt.uf.find(s3.uf)]
        assert after == pytest.approx(before - s2.local_cost)
        assert after == brute_component_sum(rt, s3)

    def test_eq_keys_exact_after_split_remerge(self):
        """Satellite regression: evict, remat, re-evict a shared-neighbor
        chain — every cached eq key must equal a from-scratch recompute
        (stale entries for the detached storage must be dropped on splits,
        not just on merges)."""
        rt, tids = self._chain_rt()
        sids = [rt.tensors[t].sid for t in tids]
        # Evict interior b, c of chain a-b-c-d (a, d stay resident).
        for t in (tids[2], tids[3]):
            rt._evict(rt.storages[rt.tensors[t].sid])

        def assert_eq_cache_exact():
            for sid, val in list(rt._eq_cache.items()):
                s = rt.storages[sid]
                roots, want = set(), 0.0
                for nsid in sorted(s.deps | s.children):
                    ns = rt.storages[nsid]
                    if not ns.resident and not ns.banished:
                        r = rt.uf.find(ns.uf)
                        if r not in roots:
                            roots.add(r)
                            want += rt.uf._cost[r]
                assert val == want, sid
                assert want == pytest.approx(
                    brute_component_sum_of_neighbors(rt, s))

        def brute_component_sum_of_neighbors(rt, s):
            roots, total = set(), 0.0
            for nsid in sorted(s.deps | s.children):
                ns = rt.storages[nsid]
                if not ns.resident and not ns.banished:
                    r = rt.uf.find(ns.uf)
                    if r not in roots:
                        roots.add(r)
                        total += sum(
                            x.local_cost for x in rt.storages.values()
                            if x.uf_joined and rt.uf.find(x.uf) == r)
            return total

        # Warm consumer caches on both shared neighbors.
        for t in (tids[1], tids[4]):
            rt.eq_neighborhood_cost(rt.storages[rt.tensors[t].sid])
        assert_eq_cache_exact()
        rt.get(tids[2])                      # remat: split
        for t in (tids[1], tids[4]):
            rt.eq_neighborhood_cost(rt.storages[rt.tensors[t].sid])
        assert_eq_cache_exact()
        rt._evict(rt.storages[rt.tensors[tids[2]].sid])  # re-evict: merge
        for t in (tids[1], tids[4]):
            rt.eq_neighborhood_cost(rt.storages[rt.tensors[t].sid])
        assert_eq_cache_exact()

    def test_snapshot_fast_path_used(self):
        """A sum-only invalidation rebuilds the eq key from the adjacency
        snapshot (no re-walk: subscription count stays flat)."""
        rt, tids = self._chain_rt()
        rt._evict(rt.storages[rt.tensors[tids[2]].sid])
        s1 = rt.storages[rt.tensors[tids[1]].sid]
        rt.eq_neighborhood_cost(s1)
        assert s1.sid in rt._eq_adj
        subs_before = rt._invalidator.subscribes
        # Evict a storage two hops away: merges tids[2]'s component ->
        # sum-only invalidation for s1 (adjacency unchanged).
        rt._evict(rt.storages[rt.tensors[tids[3]].sid])
        assert s1.sid not in rt._eq_cache       # value dropped
        assert s1.sid in rt._eq_adj             # snapshot survived
        val = rt.eq_neighborhood_cost(s1)       # fast-path rebuild
        assert rt._invalidator.subscribes == subs_before  # no re-walk
        assert val == pytest.approx(brute_component_sum(
            rt, rt.storages[rt.tensors[tids[2]].sid]))

    def test_phantom_rebuild_restores_exact_partition(self):
        """Amortized exact splits: once phantoms outnumber live members,
        the true components are re-derived (no unbounded mega-component)."""
        rt, tids = self._chain_rt(8)
        # Evict the whole interior chain -> one big component.
        for t in tids[1:8]:
            rt._evict(rt.storages[rt.tensors[t].sid])
        # Remat most interior members: phantoms pile up.
        for t in tids[2:7]:
            rt.get(t)
        s1 = rt.storages[rt.tensors[tids[1]].sid]
        # After the rebuild the surviving component holds exactly the
        # still-evicted members connected to tids[1]'s storage.
        assert rt.uf._cost[rt.uf.find(s1.uf)] == brute_component_sum(rt, s1)


# ---------------------------------------------------------------------------
# Dead-subgraph pruning
# ---------------------------------------------------------------------------

class TestDeadSubgraphPruning:
    def _rt(self, heuristic="h_dtr", **kw):
        return DTRRuntime(budget=float("inf"), heuristic=by_name(heuristic),
                          dealloc="eager", **kw)

    def test_release_cascades_death_backward(self):
        """A fully-released subgraph dies child-first back to the frontier."""
        rt = self._rt()
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 2.0, [a], [10])
        (d,) = rt.call("d", 4.0, [b], [10])
        sa, sb, sd = (rt.tensors[t].sid for t in (a, b, d))
        rt.release(d)
        assert rt.storages[sd].dead
        # b still holds an external ref -> alive; a alive through b.
        assert not rt.storages[sb].dead and not rt.storages[sa].dead
        rt.release(b)
        assert rt.storages[sb].dead
        rt.release(a)
        assert rt.storages[sa].dead

    def test_live_child_keeps_parent_alive(self):
        rt = self._rt()
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 2.0, [a], [10])
        rt.release(a)
        assert not rt.storages[rt.tensors[a].sid].dead
        rt.release(b)   # now the whole chain is unreferenced
        assert rt.storages[rt.tensors[a].sid].dead

    def test_dead_pruned_from_estar_walk_with_cone_attached(self):
        """e* walks skip dead members; the cone's cost is charged through
        the live frontier's dead_cost instead."""
        rt = self._rt()
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 2.0, [a], [10])   # b: child of a, will die
        (k,) = rt.call("k", 8.0, [a], [10])   # keeps a alive
        sa = rt.tensors[a].sid
        rt.release(b)                          # leaf dies -> eager evict
        sb = rt.tensors[b].sid
        assert rt.storages[sb].dead and not rt.storages[sb].resident
        # a (live, resident) carries the cone weight ...
        assert rt.storages[sa].dead_cost == pytest.approx(2.0)
        # ... and the e* walk from k's storage never visits the dead b,
        # but still charges it when a is evicted.
        rt._evict(rt.storages[sa])
        sk = rt.tensors[k].sid
        cost = rt.evicted_neighborhood_cost(rt.storages[sk])
        assert cost == pytest.approx(1.0 + 2.0)  # a.local + cone(b)
        assert sb not in {x for x in rt._invalidator._subs.get(
            rt._invalidator._uf.find(rt._invalidator._node.get(sa, 0)),
            set())}

    def test_dead_never_subscribes(self):
        """Dead evictions register no subscriptions and fire no component
        merges — subscriber work stays bounded on retire-heavy traces."""
        rt = self._rt()
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 2.0, [a], [10])
        rt.release(b)
        rt.release(a)
        subs = rt._invalidator.subscribes
        # Scoring any candidate must not walk (or subscribe through) the
        # dead chain.
        rt.constant(1)
        assert rt._invalidator.subscribes == subs

    def test_dead_members_stay_in_eq_components(self):
        """ẽ* keeps dead members as cost ballast (h_dtr_eq accounting)."""
        rt = self._rt("h_dtr_eq")
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 2.0, [a], [10])
        (k,) = rt.call("k", 8.0, [a], [10])
        rt.release(b)                          # dies, evicted, joins
        sb = rt.tensors[b].sid
        assert rt.storages[sb].dead and rt.storages[sb].uf_joined
        rt._evict(rt.storages[rt.tensors[a].sid])
        sk = rt.tensors[k].sid
        # Component of a contains dead b: 1.0 + 2.0.
        assert rt.eq_neighborhood_cost(
            rt.storages[sk]) == pytest.approx(3.0)

    def test_addref_revives_dead_chain(self):
        rt = self._rt()
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 2.0, [a], [10])
        rt.release(b)
        rt.release(a)
        sa, sb = rt.tensors[a].sid, rt.tensors[b].sid
        assert rt.storages[sa].dead and rt.storages[sb].dead
        rt.addref(b)
        assert not rt.storages[sb].dead
        assert not rt.storages[sa].dead        # ancestors revive too

    def test_dead_children_do_not_block_banish(self):
        """A dead evicted child never rematerializes, so it must not leave
        its parent pending-banish forever."""
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_dtr"),
                        dealloc="banish")
        c = rt.constant(1)
        (a,) = rt.call("a", 1.0, [c], [10])
        (b,) = rt.call("b", 2.0, [a], [10])
        sa, sb = rt.tensors[a].sid, rt.tensors[b].sid
        # Kill b while evicted: release drops it to dead (banish policy
        # banishes it instead unless blocked; force the evicted-dead shape
        # via eager-style evict first).
        rt.storages[sb].locks += 1             # block banish of b
        rt.release(b)
        rt.storages[sb].locks -= 1
        rt.release(a)
        assert rt.storages[sa].banished
        assert sa not in rt._pending_banish

    def test_oracle_equivalence_with_deaths(self):
        """Scan and index engines agree bit-exactly on a log whose replay
        produces dead subgraphs (eager releases of leaf outputs)."""
        for heuristic in ("h_dtr", "h_dtr_eq", "h_msps", "h_estar"):
            log = graphs.random_dag(60, seed=11)
            peak, _ = simulator.measure_baseline(log)
            a, b = both(log, heuristic, 0.5 * peak, dealloc="eager")
            assert_parity(a, b, f"dead/{heuristic}")


# ---------------------------------------------------------------------------
# window_cost / score-cache sharing
# ---------------------------------------------------------------------------

class TestWindowCostSharing:
    def test_window_cost_uses_index_memo(self):
        rt = DTRRuntime(budget=1000, heuristic=by_name("h_lru"))
        assert rt.index is not None
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [20])
        (b,) = rt.call("g", 1.0, [c], [20])
        sa = rt.storages[rt.tensors[a].sid]
        sb = rt.storages[rt.tensors[b].sid]
        before = rt.meta_accesses
        c1 = window_cost(rt, rt.heuristic, [sa, sb])
        assert rt.meta_accesses == before + 2    # two fresh evaluations
        c2 = window_cost(rt, rt.heuristic, [sa, sb])
        assert rt.meta_accesses == before + 2    # memo hits: no new accesses
        assert c1 == c2

    def test_window_cost_matches_pick_accounting(self):
        """A storage scored by the window planner and then verified by
        victim selection at the same instant costs one access total."""
        rt = DTRRuntime(budget=1000, heuristic=by_name("h_lru"))
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [20])
        sa = rt.storages[rt.tensors[a].sid]
        before = rt.meta_accesses
        sc1 = window_cost(rt, rt.heuristic, [sa])
        sc2 = rt.index.cached_score(sa)
        assert sc1 == sc2
        assert rt.meta_accesses == before + 1

    def test_explicit_cache_dict_still_honored(self):
        rt = DTRRuntime(budget=1000, heuristic=by_name("h_lru"))
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [20])
        sa = rt.storages[rt.tensors[a].sid]
        cache = {}
        c1 = window_cost(rt, rt.heuristic, [sa], cache=cache)
        assert cache[sa.sid] == c1


# ---------------------------------------------------------------------------
# Parallel sweep driver
# ---------------------------------------------------------------------------

class TestSweepParallel:
    FR = [0.9, 0.6]

    def _flat(self, sweeps):
        out = []
        for sw in sweeps:
            for r in sw.runs:
                out.append((sw.log_name, sw.heuristic, r.budget, r.ok,
                            r.compute, r.evictions, r.peak_memory))
        return out

    def test_matches_serial_sweep(self):
        logs = [graphs.mlp(depth=6), graphs.linear_network(40)]
        hs = ["h_dtr_eq", "h_lru"]
        serial = [simulator.sweep(log, h, self.FR) for log in logs for h in hs]
        par = simulator.sweep_parallel(logs, hs, self.FR, processes=2)
        assert self._flat(par) == self._flat(serial)

    def test_serial_fallback_path(self):
        logs = [graphs.mlp(depth=4)]
        par = simulator.sweep_parallel(logs, ["h_lru"], self.FR, processes=0)
        serial = [simulator.sweep(logs[0], "h_lru", self.FR)]
        assert self._flat(par) == self._flat(serial)

    def test_single_log_and_heuristic_convenience(self):
        log = graphs.mlp(depth=4)
        out = simulator.sweep_parallel(log, "h_lru", [0.8], processes=0)
        assert len(out) == 1 and out[0].heuristic == "h_lru"
        assert len(out[0].runs) == 1
