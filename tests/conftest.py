"""Shared pytest configuration.

Registers the ``requires_accel`` marker: tests that exercise real TPU/GPU
compilation paths (non-interpret Pallas lowering, full-slice meshes) carry it
and are skipped on CPU-only hosts, so the full suite collects green anywhere
while hardware CI still runs them.
"""
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "requires_accel: needs a real TPU/GPU device; skipped on CPU-only "
        "hosts (interpret-mode equivalents still run everywhere)")


def _accel_present() -> bool:
    try:
        import jax
        return jax.devices()[0].platform != "cpu"
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    if _accel_present():
        return
    skip = pytest.mark.skip(
        reason="requires a TPU/GPU accelerator; CPU-only host")
    for item in items:
        if "requires_accel" in item.keywords:
            item.add_marker(skip)
