"""Tests for the eager DTR executor: real buffers, real eviction, real remat."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.eager import DTRContext, DTRArray, op


def test_basic_chain_correctness():
    ctx = DTRContext(budget_bytes=float("inf"))
    x = ctx.wrap(jnp.arange(16.0))
    y = ctx.call("sin", jnp.sin, [x])[0]
    z = ctx.call("sum", jnp.sum, [y])[0]
    np.testing.assert_allclose(z.value, np.sin(np.arange(16.0)).sum(),
                               rtol=1e-6)


def test_eviction_and_remat_preserve_values():
    """Run a chain under a tight budget; every value must still be exact."""
    n = 64 * 1024 // 4  # 64 KiB fp32 tensors
    budget = 5 * 64 * 1024  # room for ~5 tensors
    ctx = DTRContext(budget_bytes=budget)
    x = ctx.wrap(jnp.linspace(0, 1, n))
    vals = [x]
    for i in range(20):
        vals.append(ctx.call(f"f{i}", lambda a: jnp.cos(a) * 1.01, [vals[-1]])[0])
    assert ctx.rt.evictions > 0, "budget should have forced evictions"
    # Access an early intermediate: must rematerialize correctly.
    expect = np.linspace(0, 1, n)
    for i in range(1, 6):
        expect = np.cos(expect) * 1.01
    np.testing.assert_allclose(np.asarray(vals[5].value), expect, rtol=1e-5)
    assert ctx.remat_runs > 0


def test_budget_respected_in_real_bytes():
    n = 32 * 1024 // 4
    budget = 6 * 32 * 1024
    ctx = DTRContext(budget_bytes=budget)
    x = ctx.wrap(jnp.ones(n))
    h = x
    for i in range(30):
        h = ctx.call(f"g{i}", lambda a: a * 1.0001, [h])[0]
        # One-allocation slack allowed (paper App. E.1).
        assert ctx.live_bytes() <= budget + 32 * 1024
    assert jnp.isfinite(h.value).all()


def test_dynamic_control_flow_treelstm_style():
    """Data-dependent recursion (the paper's dynamic-model headline)."""
    dim = 256
    # Budget: pinned constants (weight matrix + 16 leaves) + ~10 activation
    # slots; the ~30 internal activations must be evicted/rematerialized.
    budget = (dim * dim + 16 * dim + 10 * dim) * 4
    ctx = DTRContext(budget_bytes=budget)
    w = ctx.wrap(jnp.eye(dim) * 0.5 + 0.01, name="w")

    def cell(a: DTRArray, b: DTRArray) -> DTRArray:
        s = ctx.call("add", jnp.add, [a, b])[0]
        return ctx.call("cell", lambda s_, w_: jnp.tanh(s_ @ w_), [s, w])[0]

    def build(depth: int, leaf_val: float) -> DTRArray:
        if depth == 0:
            return ctx.wrap(jnp.full((dim,), leaf_val), name="leaf")
        left = build(depth - 1, leaf_val)
        right = build(depth - 1, leaf_val + 0.1)
        return cell(left, right)

    root = build(4, 0.05)
    v = root.value
    assert v.shape == (dim,)
    assert bool(jnp.isfinite(v).all())
    assert ctx.rt.evictions > 0


def test_multi_output_ops():
    ctx = DTRContext(budget_bytes=float("inf"))
    x = ctx.wrap(jnp.arange(8.0))
    outs = ctx.call("split", lambda a: (a[:4], a[4:]), [x])
    assert len(outs) == 2
    np.testing.assert_allclose(outs[1].value, np.arange(4.0) + 4)


def test_op_helper_and_arith_sugar():
    ctx = DTRContext(budget_bytes=float("inf"))
    gelu = op(ctx, "gelu", jax.nn.gelu)
    x = ctx.wrap(jnp.ones((4, 4)))
    y = gelu(x + x)
    z = y @ x
    assert z.value.shape == (4, 4)


def test_training_loop_under_budget():
    """A tiny MLP training step with manual backward passes through DTR."""
    key = jax.random.PRNGKey(0)
    din, dh, n = 64, 256, 32
    budget = 40 * n * dh * 4
    ctx = DTRContext(budget_bytes=budget)
    w1 = ctx.wrap(jax.random.normal(key, (din, dh)) * 0.05, name="w1")
    w2 = ctx.wrap(jax.random.normal(key, (dh, 1)) * 0.05, name="w2")
    xb = ctx.wrap(jax.random.normal(key, (n, din)), name="x")
    yb = ctx.wrap(jnp.ones((n, 1)), name="y")

    losses = []
    lr = 0.05
    for step in range(4):
        h = ctx.call("fc1", jnp.matmul, [xb, w1])[0]
        a = ctx.call("relu", jax.nn.relu, [h])[0]
        p = ctx.call("fc2", jnp.matmul, [a, w2])[0]
        e = ctx.call("err", jnp.subtract, [p, yb])[0]
        loss = ctx.call("mse", lambda t: jnp.mean(t * t), [e])[0]
        # Manual backward (each op goes through DTR as well).
        gp = ctx.call("d_mse", lambda t: 2 * t / t.size, [e])[0]
        gw2 = ctx.call("d_w2", lambda a_, g: a_.T @ g, [a, gp])[0]
        ga = ctx.call("d_a", lambda g, w: g @ w.T, [gp, w2])[0]
        gh = ctx.call("d_relu", lambda g, h_: g * (h_ > 0), [ga, h])[0]
        gw1 = ctx.call("d_w1", lambda x_, g: x_.T @ g, [xb, gh])[0]
        w1 = ctx.call("sgd1", lambda w, g: w - lr * g, [w1, gw1])[0]
        w2 = ctx.call("sgd2", lambda w, g: w - lr * g, [w2, gw2])[0]
        losses.append(float(loss.value))
    assert losses[-1] < losses[0], f"no learning: {losses}"
