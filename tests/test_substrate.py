"""Substrate tests: checkpointing, fault tolerance, data, collectives,
sharding rules, optimizers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_latest, save_checkpoint
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.collectives import (
    compressed_psum, dequantize_int8, quantize_int8)
from repro.distributed.monitor import DivergenceGuard, StragglerMonitor
from repro.distributed.sharding import (
    LOGICAL_RULES, ParamInfo, mesh_context, param_pspec, pspec)
from repro.launch.mesh import make_host_mesh
from repro.optim import (adafactor, adamw, apply_updates,
                         clip_by_global_norm, cosine_schedule, sgdm)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def tree(self, scale=1.0):
        return {"a": jnp.arange(8.0) * scale,
                "b": {"w": jnp.ones((4, 4)) * scale,
                      "s": jnp.zeros((), jnp.int32) + int(scale)}}

    def test_save_restore_roundtrip(self, tmp_path):
        t = self.tree(2.0)
        save_checkpoint(str(tmp_path), 7, t, extra={"cursor": 7})
        step, restored, extra = restore_latest(str(tmp_path), self.tree(0.0))
        assert step == 7
        assert extra["cursor"] == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_wins_and_retention(self, tmp_path):
        m = CheckpointManager(str(tmp_path), every_steps=1, keep=2)
        for s in range(5):
            m.save(s, self.tree(float(s)))
        dirs = sorted(os.listdir(tmp_path))
        assert len(dirs) == 2  # retention
        step, restored, _ = m.restore(self.tree(0.0))
        assert step == 4
        assert float(restored["a"][1]) == 4.0

    def test_atomicity_no_partial_dirs(self, tmp_path):
        m = CheckpointManager(str(tmp_path), every_steps=1, keep=3)
        m.save(0, self.tree())
        # temp dirs must never remain
        assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]

    def test_elastic_restore_different_sharding(self, tmp_path):
        """Restore works regardless of device layout (arrays are logical)."""
        t = self.tree(3.0)
        save_checkpoint(str(tmp_path), 1, t)
        # Simulate a different-device-count job: plain restore + device_put.
        step, restored, _ = restore_latest(str(tmp_path), self.tree(0.0))
        out = jax.device_put(restored["b"]["w"], jax.devices()[0])
        np.testing.assert_array_equal(np.asarray(out), np.ones((4, 4)) * 3)


# ---------------------------------------------------------------------------
# Fault tolerance monitors
# ---------------------------------------------------------------------------

class TestMonitors:
    def test_straggler_flags_outlier(self):
        fired = []
        mon = StragglerMonitor(threshold=2.0, patience=2,
                               on_straggler=fired.append)
        for i in range(10):
            mon.record(i, 0.1)
        mon.record(10, 0.5)
        mon.record(11, 0.5)
        assert any(s.flagged for s in mon.history)
        assert fired, "straggler callback should fire after patience"

    def test_straggler_ewma_robust(self):
        mon = StragglerMonitor()
        for i in range(5):
            mon.record(i, 0.1)
        mon.record(5, 10.0)  # outlier not folded into ewma
        assert mon.ewma < 0.2

    def test_divergence_guard(self):
        g = DivergenceGuard(spike_factor=10.0, max_skips=2)
        assert g.check(1.0, 1.0) == "ok"
        assert g.check(1.1, 1.0) == "ok"
        assert g.check(float("nan"), 1.0) == "skip"
        assert g.check(float("nan"), 1.0) == "skip"
        assert g.check(float("nan"), 1.0) == "restore"
        assert g.check(1.0, 1.0) == "ok"  # recovers


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic_and_seekable(self):
        d = SyntheticLM(vocab=512, seq_len=32, batch=4, seed=3)
        b1 = d.batch_at(10)
        b2 = d.batch_at(10)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(d.batch_at(11)["tokens"], b1["tokens"])

    def test_learnable_structure(self):
        """Even positions determine odd positions via a fixed permutation."""
        d = SyntheticLM(vocab=128, seq_len=16, batch=8, seed=0)
        b = d.batch_at(0)["tokens"]
        perm_rng = np.random.default_rng(0)
        perm = perm_rng.permutation(128)
        np.testing.assert_array_equal(b[:, 1::2], perm[b[:, 0::2]])

    def test_prefetcher(self):
        d = SyntheticLM(vocab=64, seq_len=8, batch=2)
        pf = Prefetcher(d, start_step=5)
        s, b = pf.next()
        assert s == 5
        np.testing.assert_array_equal(b["tokens"], d.batch_at(5)["tokens"])
        pf.stop()

    def test_codebook_shape(self):
        d = SyntheticLM(vocab=64, seq_len=8, batch=2, n_codebooks=4)
        assert d.batch_at(0)["tokens"].shape == (2, 8, 4)


# ---------------------------------------------------------------------------
# Collectives / compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_quantize_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
        q, s = quantize_int8(x)
        err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
        assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6

    def test_compressed_psum_single_device(self):
        """Degenerate 1-device psum must be ~identity (quantization only)."""
        mesh = make_host_mesh()

        try:
            from jax import shard_map
        except ImportError:  # older jax keeps it in experimental
            from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        x = jax.random.normal(jax.random.PRNGKey(1), (64,))

        def f(v):
            return compressed_psum({"g": v}, ("data",))["g"]

        out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                                   atol=float(jnp.max(jnp.abs(x))) / 100)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

class TestSharding:
    def test_pspec_resolution_and_dedup(self):
        mesh = make_host_mesh()
        with mesh_context(mesh):
            s = pspec("batch", None, "mlp")
            assert len(s) == 3

    def test_divisibility_fit(self):
        mesh = make_host_mesh()  # 1 device -> (1,1) mesh
        with mesh_context(mesh, overrides={"heads": "model"}):
            # heads=3 over model axis size 1 -> trivially ok; shape-aware
            s = pspec("heads", shape=(3,))
            assert s is not None

    def test_param_pspec_fsdp(self):
        mesh = make_host_mesh()
        info = ParamInfo((8, 4), "float32", (None, "mlp"), fsdp_dim=0)
        with mesh_context(mesh, fsdp=True):
            s = param_pspec(info)
            assert len(s) == 2

    def test_no_mesh_noop(self):
        x = jnp.ones((4, 4))
        from repro.distributed.sharding import shard
        assert shard(x, "batch", "embed") is x


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

class TestOptim:
    def quad(self, opt, steps=60):
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(steps):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        return float(loss(params))

    def test_adamw_converges(self):
        assert self.quad(adamw(lr=0.1, weight_decay=0.0)) < 0.1

    def test_adafactor_converges(self):
        assert self.quad(adafactor(lr=0.3)) < 0.5

    def test_sgdm_converges(self):
        assert self.quad(sgdm(lr=0.05)) < 0.1

    def test_adafactor_factored_state_is_small(self):
        p = {"w": jnp.ones((64, 32))}
        st = adafactor().init(p)
        sizes = [np.prod(x.shape) for x in jax.tree.leaves(st.inner)]
        assert max(sizes) <= 64  # factored: no [64,32] second moment

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert float(gn) > 1.0
        total = jnp.sqrt(sum(jnp.sum(x ** 2)
                             for x in jax.tree.leaves(clipped)))
        assert float(total) <= 1.0 + 1e-5

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100)
        assert float(lr(jnp.int32(0))) == 0.0
        assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
        assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=0.01)
