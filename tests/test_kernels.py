"""Per-kernel allclose vs pure-jnp oracle, swept over shapes & dtypes.

All Pallas kernels run in interpret mode on CPU (the TPU is the target, not
the runtime — the kernel bodies execute in Python for validation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is optional: property tests skip, rest run
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_grouped_gemm
from repro.kernels.rwkv6_chunk import rwkv6_chunk

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,causal,window",
    [
        (1, 2, 2, 128, 128, 64, True, 0),      # MHA causal
        (2, 4, 2, 128, 128, 64, True, 0),      # GQA
        (1, 8, 1, 256, 256, 64, True, 0),      # MQA
        (2, 2, 2, 128, 128, 64, False, 0),     # bidirectional
        (1, 2, 2, 256, 256, 64, True, 64),     # sliding window
        (1, 2, 2, 64, 256, 64, True, 0),       # kv longer than q (prefix)
        (1, 2, 2, 96, 96, 32, True, 0),        # non-multiple of block
        (1, 2, 2, 128, 128, 128, True, 0),     # wide head
    ])
def test_flash_vs_reference(b, hq, hkv, sq, skv, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (b, hq, sq, d), dtype)
    k = rand(ks[1], (b, hkv, skv, d), dtype)
    v = rand(ks[2], (b, hkv, skv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.flash_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **TOL[dtype])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000),
       sq=st.sampled_from([64, 128, 192]),
       d=st.sampled_from([32, 64]),
       hq=st.sampled_from([1, 2, 4]))
def test_flash_property(seed, sq, d, hq):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(ks[0], (1, hq, sq, d), jnp.float32)
    k = rand(ks[1], (1, hq, sq, d), jnp.float32)
    v = rand(ks[2], (1, hq, sq, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    expect = ref.flash_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)
    # Softmax convexity: outputs lie within [min, max] of values.
    assert float(out.max()) <= float(v.max()) + 1e-4
    assert float(out.min()) >= float(v.min()) - 1e-4


# ---------------------------------------------------------------------------
# RWKV6 chunked recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,d,chunk", [
    (2, 128, 32, 32),
    (1, 256, 64, 64),
    (4, 64, 16, 16),
    (1, 128, 64, 64),    # max supported chunk
])
def test_rwkv6_vs_reference(bh, s, d, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = rand(ks[0], (bh, s, d), dtype)
    k = rand(ks[1], (bh, s, d), dtype)
    v = rand(ks[2], (bh, s, d), dtype)
    # log-decay in [-4, -0.02] (realistic RWKV6 range, exp(w0+lora) bounded)
    wl = -jnp.exp(jax.random.uniform(ks[3], (bh, s, d),
                                     minval=-4.0, maxval=1.2))
    wl = wl.astype(dtype)
    u = rand(ks[4], (bh, d), dtype) * 0.3
    out = rwkv6_chunk(r, k, v, wl, u, chunk=chunk, interpret=True)
    expect = ref.rwkv6_reference(r, k, v, wl, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=4e-2 if dtype == jnp.bfloat16 else 3e-4,
                               atol=4e-2 if dtype == jnp.bfloat16 else 3e-4)


def test_rwkv6_matches_model_layer():
    """Kernel agrees with the model's own chunked formulation."""
    from repro.models.rwkv import _chunked_wkv
    b, s, h, d = 2, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    lw = -jnp.exp(jax.random.uniform(ks[3], (b, s, h, d), minval=-3,
                                     maxval=1))
    u = jax.random.normal(ks[4], (h, d)) * 0.3
    model_out = _chunked_wkv(r, k, v, lw, u)
    kern = rwkv6_chunk(
        r.swapaxes(1, 2).reshape(b * h, s, d),
        k.swapaxes(1, 2).reshape(b * h, s, d),
        v.swapaxes(1, 2).reshape(b * h, s, d),
        lw.swapaxes(1, 2).reshape(b * h, s, d),
        jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, d),
        chunk=16, interpret=True)
    kern = kern.reshape(b, h, s, d).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model_out),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE grouped GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f,bc,bf,bd", [
    (4, 128, 256, 128, 64, 64, 128),
    (8, 64, 128, 256, 64, 128, 64),
    (2, 256, 512, 64, 128, 64, 256),
    (1, 128, 128, 128, 128, 128, 128),
])
def test_moe_gemm_vs_reference(e, c, d, f, bc, bf, bd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = rand(ks[0], (e, c, d), dtype)
    w = rand(ks[1], (e, d, f), dtype)
    out = moe_grouped_gemm(x, w, block_c=bc, block_f=bf, block_d=bd,
                           interpret=True)
    expect = ref.moe_gemm_reference(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ops_adapters():
    from repro.kernels import ops
    b, s, h, kv, d = 2, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    a1 = ops.attention(q, k, v, use_kernel=True)
    a2 = ops.attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=3e-5, atol=3e-5)


def test_rwkv6_rejects_overlong_chunk():
    """Chunks beyond the separable-decay overflow bound must be rejected."""
    r = jnp.ones((1, 128, 16))
    with pytest.raises(AssertionError, match="overflows"):
        rwkv6_chunk(r, r, r, -r, jnp.ones((1, 16)), chunk=128,
                    interpret=True)


# ---------------------------------------------------------------------------
# Compiled (non-interpret) lowering — accelerator-only
# ---------------------------------------------------------------------------

@pytest.mark.requires_accel
@pytest.mark.parametrize("kernel", ["flash", "rwkv6", "moe"])
def test_kernels_compiled_on_accelerator(kernel):
    """Mosaic-compiled kernels must match the same references as interpret.

    Skipped on CPU-only hosts (conftest ``requires_accel``); interpret-mode
    equivalence above covers the kernel bodies everywhere.
    """
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    if kernel == "flash":
        q = rand(ks[0], (1, 2, 128, 64), jnp.float32)
        k = rand(ks[1], (1, 2, 128, 64), jnp.float32)
        v = rand(ks[2], (1, 2, 128, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=False)
        expect = ref.flash_reference(q, k, v, causal=True)
    elif kernel == "rwkv6":
        r = rand(ks[0], (2, 128, 32), jnp.float32)
        k = rand(ks[1], (2, 128, 32), jnp.float32)
        v = rand(ks[2], (2, 128, 32), jnp.float32)
        wl = -jnp.exp(jax.random.uniform(ks[3], (2, 128, 32),
                                         minval=-4.0, maxval=1.2))
        u = rand(ks[4], (2, 32), jnp.float32) * 0.3
        out = rwkv6_chunk(r, k, v, wl, u, chunk=32, interpret=False)
        expect = ref.rwkv6_reference(r, k, v, wl, u)
    else:
        x = rand(ks[0], (4, 128, 256), jnp.float32)
        w = rand(ks[1], (4, 256, 128), jnp.float32) * 0.05
        out = moe_grouped_gemm(x, w, block_c=64, block_f=64, block_d=128,
                               interpret=False)
        expect = ref.moe_gemm_reference(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-4, atol=2e-4)
