"""Edge-case model tests: windowed ring-buffer wraparound, MoE capacity
semantics, RG-LRU/RWKV state behaviour over long horizons."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models import moe as MOE


def test_ring_buffer_wraps_correctly():
    """Decode far past the window: ring-buffer cache must equal a full-cache
    decode restricted to the window."""
    cfg = configs.get_smoke("mixtral_8x7b").replace(
        capacity_factor=8.0, window=8)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    b, s = 2, 40  # 5x window
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    full = M.forward(cfg, params, tokens)           # train path (windowed)
    cache = M.init_cache(cfg, b, s)                 # ring: length = window
    # Cache length for attn_local layers should be the window, not s.
    k_shapes = [x.shape for x in jax.tree.leaves(cache)
                if hasattr(x, "shape") and x.ndim == 4]
    assert all(sh[1] == cfg.window for sh in k_shapes), k_shapes
    step = jax.jit(lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))
    outs = []
    for i in range(s):
        lg, cache = step(params, tokens[:, i:i + 1], cache, jnp.int32(i))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 2e-2, f"ring-buffer mismatch after wrap: {rel}"


def test_decode_per_slot_positions_match_scalar_clock():
    """A [B] pos vector with equal entries must reproduce the scalar-pos
    decode bit-for-bit, and staggered per-slot clocks must match running
    each slot alone at its own position (continuous batching)."""
    cfg = configs.get_smoke("qwen2-0.5b")
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    b, s = 3, 10
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    step = jax.jit(lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))

    # 1. uniform vector == scalar
    c_scalar = M.init_cache(cfg, b, s)
    c_vec = M.init_cache(cfg, b, s)
    for i in range(4):
        lg_s, c_scalar = step(params, tokens[:, i:i + 1], c_scalar,
                              jnp.int32(i))
        lg_v, c_vec = step(params, tokens[:, i:i + 1], c_vec,
                           jnp.full((b,), i, jnp.int32))
        np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))

    # 2. staggered clocks: decode slots at positions (4, 2, 0) in one
    # batched call; each row must equal the same-position decode of a
    # batch whose rows all sit at that position.
    offsets = [4, 2, 0]
    caches = {off: M.init_cache(cfg, b, s) for off in set(offsets)}
    for off in set(offsets):
        for i in range(off):
            _, caches[off] = step(params, tokens[:, i:i + 1], caches[off],
                                  jnp.int32(i))
    # Build a mixed cache: row j from caches[offsets[j]].
    leaves = [jax.tree.leaves(caches[off]) for off in offsets]
    treedef = jax.tree.structure(caches[offsets[0]])
    mixed_leaves = []
    for parts in zip(*leaves):
        x = parts[0]
        if x.ndim >= 2 and x.shape[1] == b:      # group-stacked leaf
            x = jnp.stack([parts[j][:, j] for j in range(b)], axis=1)
        elif x.ndim >= 1 and x.shape[0] == b:    # flat per-slot leaf
            x = jnp.stack([parts[j][j] for j in range(b)], axis=0)
        mixed_leaves.append(x)
    mixed = jax.tree.unflatten(treedef, mixed_leaves)
    tok_mixed = jnp.stack([tokens[j, offsets[j]:offsets[j] + 1]
                           for j in range(b)], axis=0)
    lg_mixed, _ = step(params, tok_mixed, mixed,
                       jnp.asarray(offsets, jnp.int32))
    for j, off in enumerate(offsets):
        lg_ref, _ = step(params, tokens[:, off:off + 1], caches[off],
                         jnp.int32(off))
        np.testing.assert_allclose(np.asarray(lg_mixed)[j],
                                   np.asarray(lg_ref)[j],
                                   rtol=2e-5, atol=2e-5)


def test_moe_capacity_drops_are_graceful():
    """Lower capacity drops tokens (outputs differ) but never NaNs, and
    capacity >= S*k/E * big is drop-free deterministic."""
    cfg = configs.get_smoke("mixtral_8x7b")
    key = jax.random.PRNGKey(1)
    d = cfg.d_model
    p = {k: v for k, v in zip(
        ["router", "wi", "wg", "wo"],
        [jax.random.normal(key, (d, cfg.n_experts)),
         jax.random.normal(key, (cfg.n_experts, d, cfg.moe_d_ff)) * 0.05,
         jax.random.normal(key, (cfg.n_experts, d, cfg.moe_d_ff)) * 0.05,
         jax.random.normal(key, (cfg.n_experts, cfg.moe_d_ff, d)) * 0.05])}
    x = jax.random.normal(key, (2, 64, d)) * 0.3
    tight = MOE.moe_apply(cfg.replace(capacity_factor=0.5), p, x)
    loose = MOE.moe_apply(cfg.replace(capacity_factor=16.0), p, x)
    assert bool(jnp.isfinite(tight).all())
    assert bool(jnp.isfinite(loose).all())
    # Tight capacity must actually drop something.
    assert float(jnp.max(jnp.abs(tight - loose))) > 1e-6


def test_moe_combine_weights_sum_effects():
    """With capacity ample, MoE output is a convex combination of expert
    outputs: scaling all expert weights scales the output."""
    cfg = configs.get_smoke("mixtral_8x7b").replace(capacity_factor=16.0)
    key = jax.random.PRNGKey(2)
    d = cfg.d_model
    p = {"router": jax.random.normal(key, (d, cfg.n_experts)),
         "wi": jax.random.normal(key, (cfg.n_experts, d, cfg.moe_d_ff)) * .05,
         "wg": jax.random.normal(key, (cfg.n_experts, d, cfg.moe_d_ff)) * .05,
         "wo": jax.random.normal(key, (cfg.n_experts, cfg.moe_d_ff, d)) * .05}
    x = jax.random.normal(key, (1, 32, d)) * 0.3
    y1 = MOE.moe_apply(cfg, p, x)
    p2 = dict(p, wo=p["wo"] * 2.0)
    y2 = MOE.moe_apply(cfg, p2, x)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_recurrent_state_long_horizon_stability():
    """RG-LRU / RWKV decode for 200 steps stays finite and bounded."""
    for arch in ("recurrentgemma_2b", "rwkv6_1_6b"):
        cfg = configs.get_smoke(arch)
        key = jax.random.PRNGKey(3)
        params = M.init_params(cfg, key)
        b = 1
        cache = M.init_cache(cfg, b, 256)
        step = jax.jit(lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos))
        tok = jnp.zeros((b, 1), jnp.int32)
        mx = 0.0
        for i in range(200):
            lg, cache = step(params, tok, cache, jnp.int32(i))
            tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
            mx = max(mx, float(jnp.max(jnp.abs(lg))))
        assert np.isfinite(mx) and mx < 1e4, (arch, mx)


def test_param_structs_match_init_shapes():
    """ShapeDtypeStruct tree (dry-run input) must exactly mirror real
    init_params shapes/dtypes for every arch."""
    key = jax.random.PRNGKey(0)
    for arch in configs.ARCHS:
        cfg = configs.get_smoke(arch)
        structs = M.param_structs(cfg)
        params = M.init_params(cfg, key)
        s_leaves = jax.tree.leaves(structs)
        p_leaves = jax.tree.leaves(params)
        assert len(s_leaves) == len(p_leaves)
        for s, p in zip(s_leaves, p_leaves):
            assert s.shape == p.shape, (arch, s.shape, p.shape)
            assert s.dtype == p.dtype, (arch, s.dtype, p.dtype)
