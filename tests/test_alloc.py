"""Tests for repro.alloc: pool invariants, window eviction, model parity."""
import random

import pytest

from repro.alloc import FragStats, MemoryPool, PoolAllocator
from repro.core import graphs, simulator
from repro.core.heuristics import by_name, window_cost
from repro.core.runtime import DTRRuntime, OOMError
from repro.distributed.monitor import MemoryMonitor


# ---------------------------------------------------------------------------
# MemoryPool: split / coalesce / placement invariants
# ---------------------------------------------------------------------------

class TestPool:
    def test_split_and_coalesce(self):
        p = MemoryPool(100)
        assert p.alloc(1, 30) and p.alloc(2, 30) and p.alloc(3, 30)
        p.check()
        assert p.free_bytes() == 10 and p.largest_free_block() == 10
        p.free(2)                      # hole between 1 and 3
        p.check()
        assert p.free_bytes() == 40
        assert p.largest_free_block() == 30
        assert p.n_free_blocks() == 2
        p.free(1)                      # coalesces with the hole
        p.check()
        assert p.largest_free_block() == 60
        p.free(3)                      # back to a single free block
        p.check()
        assert p.n_free_blocks() == 1 and p.largest_free_block() == 100

    def test_contiguity_denied_despite_free_bytes(self):
        """The defining gap vs a byte counter: 40 free, no 40-fit."""
        p = MemoryPool(100)
        for sid in (1, 2, 3, 4, 5):
            assert p.alloc(sid, 20)
        p.free(2)
        p.free(4)                      # two scattered 20-byte holes
        assert p.free_bytes() == 40
        assert not p.alloc(9, 40)      # counter model would say yes
        assert p.stats().failed_fits == 1
        assert p.alloc(9, 20)          # a hole-sized fit works
        p.check()

    def test_best_fit_prefers_tightest_hole(self):
        p = MemoryPool(100, placement="best_fit")
        assert p.alloc(1, 40) and p.alloc(2, 10) and p.alloc(3, 30)
        p.free(1)                      # 40-hole at 0, 20-hole at end
        assert p.alloc(4, 15)
        assert p.block_of(4).offset == 80   # tail hole is the tighter fit
        p.check()

    def test_first_fit_prefers_lowest_address(self):
        p = MemoryPool(100, placement="first_fit")
        assert p.alloc(1, 40) and p.alloc(2, 10) and p.alloc(3, 30)
        p.free(1)
        assert p.alloc(4, 15)
        assert p.block_of(4).offset == 0
        p.check()

    def test_stream_placement_resumes_after_cursor(self):
        p = MemoryPool(100, placement="stream")
        assert p.alloc(1, 30) and p.alloc(2, 30)
        p.free(1)                      # hole at the bottom
        assert p.alloc(3, 10)          # cursor at 60: skips the bottom hole
        assert p.block_of(3).offset == 60
        assert p.alloc(4, 25)          # keeps streaming upward
        assert p.block_of(4).offset == 70
        assert p.alloc(5, 25)          # tail too small now: wraps to bottom
        assert p.block_of(5).offset == 0
        p.check()

    def test_infinite_capacity(self):
        p = MemoryPool(float("inf"))
        for sid in range(50):
            assert p.alloc(sid, 1000)
        p.check()
        assert p.largest_free_block() == float("inf")
        assert p.external_frag() == 0.0

    def test_compact_repacks_preserving_order(self):
        p = MemoryPool(100)
        for sid in (1, 2, 3):
            assert p.alloc(sid, 25)
        p.free(2)
        p.compact()
        p.check()
        assert p.block_of(1).offset == 0
        assert p.block_of(3).offset == 25
        assert p.n_free_blocks() == 1 and p.largest_free_block() == 50

    def test_randomized_invariants(self):
        """Random alloc/free churn holds every structural invariant."""
        rng = random.Random(1234)
        p = MemoryPool(10_000)
        live: dict[int, int] = {}
        next_sid = 0
        for _ in range(2000):
            if live and rng.random() < 0.45:
                sid = rng.choice(list(live))
                p.free(sid)
                del live[sid]
            else:
                size = rng.randint(1, 400)
                if p.alloc(next_sid, size):
                    live[next_sid] = size
                next_sid += 1
            p.check()
        assert p.used == sum(live.values())

    def test_stats_snapshot(self):
        p = MemoryPool(100)
        p.alloc(1, 50)
        p.alloc(2, 20)
        p.free(1)
        st = p.stats()
        assert isinstance(st, FragStats)
        assert st.used == 20 and st.free == 80
        assert st.largest_free == 50
        assert st.frag_ratio == pytest.approx(1 - 50 / 80)
        assert set(st.as_dict()) >= {"largest_free", "frag_ratio",
                                     "failed_fits"}


# ---------------------------------------------------------------------------
# Contiguous-window eviction through the runtime
# ---------------------------------------------------------------------------

def pool_rt(budget, heuristic="h_lru", placement="first_fit", **kw):
    return DTRRuntime(budget=budget, heuristic=by_name(heuristic),
                      allocator=PoolAllocator(placement=placement), **kw)


class TestWindowEviction:
    def test_window_is_contiguous_and_cheapest(self):
        """Address layout [c|a|b|d]; a 40-byte alloc must take an adjacent
        pair, and LRU cost picks the stalest pair {a, b}."""
        rt = pool_rt(100, heuristic="h_lru")
        c = rt.constant(10)                    # [0, 10) pinned
        (a,) = rt.call("f", 1.0, [c], [30])    # [10, 40)
        (b,) = rt.call("g", 1.0, [c], [30])    # [40, 70)
        (d,) = rt.call("h", 1.0, [c], [30])    # [70, 100)
        (e,) = rt.call("k", 1.0, [c], [40])    # needs a 2-storage window
        assert not rt.tensors[a].defined
        assert not rt.tensors[b].defined
        assert rt.tensors[d].defined           # freshest neighbor survives
        assert rt.tensors[e].defined
        blk = rt.allocator.pool.block_of(rt.tensors[e].sid)
        assert (blk.offset, blk.size) == (10, 40)
        assert rt.allocator.evict_windows == 1
        rt.allocator.pool.check()

    def test_fragmentation_oom_where_counter_succeeds(self):
        """Pinned constants between evictables cap the largest window below
        the request; the byte counter would have admitted it."""
        def build(rt):
            first = None
            for i in range(3):                 # layout: c a c a c a
                cc = rt.constant(10)
                first = first if first is not None else cc
                rt.call(f"f{i}", 1.0, [cc], [20])
            return first

        rt = pool_rt(100)
        src = build(rt)
        with pytest.raises(OOMError, match="contiguous"):
            rt.call("big", 1.0, [src], [40])

        rt2 = DTRRuntime(budget=100, heuristic=by_name("h_lru"))
        src2 = build(rt2)
        rt2.call("big", 1.0, [src2], [40])     # counter model: no problem

    def test_failed_alloc_with_no_window_reports_frag(self):
        rt = pool_rt(50)
        c = rt.constant(40)
        with pytest.raises(OOMError, match="largest_free"):
            rt.call("f", 1.0, [c], [20])

    def test_window_cost_helper_caches_and_counts(self):
        rt = pool_rt(1000)
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [20])
        (b,) = rt.call("g", 1.0, [c], [20])
        sa = rt.storages[rt.tensors[a].sid]
        sb = rt.storages[rt.tensors[b].sid]
        cache = {}
        before = rt.meta_accesses
        c1 = window_cost(rt, rt.heuristic, [sa, sb], cache=cache)
        assert rt.meta_accesses == before + 2
        c2 = window_cost(rt, rt.heuristic, [sa, sb], cache=cache)
        assert rt.meta_accesses == before + 2   # cache hit: no new accesses
        assert c1 == c2 == pytest.approx(
            cache[sa.sid] + cache[sb.sid])

    def test_multi_output_oom_rolls_back_placed_siblings(self):
        """If output N of a multi-output op cannot be placed, outputs placed
        earlier in the batch must be released — they are not resident yet,
        so nothing else would ever free their blocks."""
        rt = pool_rt(100)
        c = rt.constant(90)
        with pytest.raises(OOMError):
            rt.call("two", 1.0, [c], [10, 40])
        assert rt.memory == 90
        assert rt.allocator.pool.used == 90
        rt.allocator.pool.check()
        # Retrying the access fails cleanly again (no leaked placement).
        out1 = rt.ops[0].output_tids[0]
        with pytest.raises(OOMError):
            rt.get(out1)

    def test_locked_storages_break_windows(self):
        """Op inputs are locked during allocation; the window planner must
        treat them as barriers, never evicting what the op is reading."""
        rt = pool_rt(100, heuristic="h_size")
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [45])
        (b,) = rt.call("g", 1.0, [c], [45])
        # g2 reads a (locked during perform); only b is evictable.
        (d,) = rt.call("g2", 1.0, [a], [45])
        assert rt.tensors[a].defined
        assert not rt.tensors[b].defined
        rt.allocator.pool.check()


# ---------------------------------------------------------------------------
# Counter-model parity and model-graph sweeps
# ---------------------------------------------------------------------------

PARITY_FIELDS = ("ok", "compute", "base_compute", "evictions", "remat_ops",
                 "ops_executed", "meta_accesses", "peak_memory")


class TestParityAndSweeps:
    @pytest.mark.parametrize("mk", [
        lambda: graphs.linear_network(60),
        lambda: graphs.mlp(depth=8),
    ])
    @pytest.mark.parametrize("frac", [0.9, 0.6, 0.4])
    def test_nofrag_pool_bitexact_with_counter(self, mk, frac):
        log = mk()
        peak, _ = simulator.measure_baseline(log)
        a = simulator.simulate(log, "h_dtr_eq", budget=frac * peak)
        b = simulator.simulate(log, "h_dtr_eq", budget=frac * peak,
                               alloc_mode="pool_nofrag")
        for f in PARITY_FIELDS:
            assert getattr(a, f) == getattr(b, f), f

    def test_pool_never_beats_counter_feasibility(self):
        """Contiguity is a strictly harder constraint: any budget feasible
        under the pool model is feasible under the counter model."""
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        for frac in (0.8, 0.5, 0.35):
            pool = simulator.simulate(log, "h_dtr_eq", budget=frac * peak,
                                      alloc_mode="pool")
            counter = simulator.simulate(log, "h_dtr_eq",
                                         budget=frac * peak)
            if pool.ok:
                assert counter.ok
                assert counter.compute <= pool.compute + 1e-9

    @pytest.mark.parametrize("placement", ["best_fit", "first_fit", "stream"])
    def test_pool_sweep_models_complete(self, placement):
        log = graphs.resnet(blocks=6)
        sw = simulator.sweep(log, "h_dtr_eq", [1.0, 0.7, 0.5],
                             alloc_mode="pool", placement=placement)
        assert sw.alloc_mode == "pool"
        assert any(r.ok for r in sw.runs)
        tight = [r for r in sw.runs if r.ok and r.evict_windows > 0]
        assert tight, "pressure run should use window eviction"
        for r in tight:
            assert 0.0 <= r.frag_ratio <= 1.0

    def test_budget_respected_under_pool(self):
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        r = simulator.simulate(log, "h_dtr_eq", budget=0.6 * peak,
                               alloc_mode="pool")
        assert r.ok and r.peak_memory <= 0.6 * peak + 1e-6

    @pytest.mark.parametrize("mode", ["counter", "pool", "pool_nofrag"])
    def test_zero_budget_fails_gracefully(self, mode):
        """Budget probes down to 0 must report OOM, not crash (all modes)."""
        log = graphs.mlp(depth=4)
        r = simulator.simulate(log, "h_dtr_eq", budget=0.0, alloc_mode=mode)
        assert not r.ok and r.error

    def test_unknown_alloc_mode_rejected(self):
        with pytest.raises(ValueError, match="alloc_mode"):
            simulator.make_allocator("arena")


# ---------------------------------------------------------------------------
# Eager executor over the pool + monitoring surface
# ---------------------------------------------------------------------------

class TestEagerPool:
    def test_eager_pool_remats_and_reports_frag(self):
        jnp = pytest.importorskip("jax.numpy")
        from repro.eager.executor import DTRContext

        ctx = DTRContext(budget_bytes=6 * 4 * 64, heuristic="h_dtr_eq",
                         use_wallclock_cost=False, alloc_mode="pool")
        x = ctx.wrap(jnp.ones(64, jnp.float32))
        h = x
        outs = []
        for _ in range(10):
            h = ctx.call("mul", jnp.multiply, [h, h])[0]
            outs.append(h)
        assert ctx.rt.evictions > 0
        v = outs[0].value              # rematerializes through the pool
        assert float(v[0]) == 1.0
        frag = ctx.fragmentation()
        assert frag is not None and frag.capacity == 6 * 4 * 64
        ctx.rt.allocator.pool.check()

    def test_memory_monitor_surfaces_frag(self):
        mon = MemoryMonitor()
        mon.record(0, peak_bytes=100.0)
        st = FragStats(capacity=100, used=60, free=40, largest_free=10,
                       frag_ratio=0.75, failed_fits=2, evict_windows=1)
        s = mon.record(1, peak_bytes=90.0, frag=st)
        assert s.largest_free == 10 and s.frag_ratio == 0.75
        summary = mon.summary()
        assert summary["peak_bytes"] == 100.0
        assert summary["max_frag_ratio"] == 0.75
        # Telemetry-less (counter-mode) samples must not drag frag
        # aggregates to zero — that would read as largest-free collapse.
        assert summary["min_largest_free"] == 10
        assert summary["failed_fits"] == 2

    def test_memory_monitor_without_telemetry(self):
        mon = MemoryMonitor()
        mon.record(0, peak_bytes=50.0)
        s = mon.summary()
        assert s["peak_bytes"] == 50.0
        assert s["min_largest_free"] is None
        assert s["max_frag_ratio"] is None
