"""Tests for the trace subsystem: Log schema v2, recorder, capture, replay."""
import json

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is optional: property tests skip, rest run
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core import graphs
from repro.core.graph import (SCHEMA_VERSION, Alias, Call, Constant, Log,
                              LogBuilder, Memory, Release, as_meta)
from repro.core.simulator import (measure_baseline, resolve_budget, simulate,
                                  sweep_parallel)


# ---------------------------------------------------------------------------
# Log schema v2: round-trip + versioning + malformed rejection
# ---------------------------------------------------------------------------

class TestLogSerialization:
    def test_roundtrip_with_meta(self):
        b = LogBuilder(name="t")
        b.log.meta = {"source": "test", "slots": 2}
        c = b.constant(64, meta={"rid": 0, "phase": "prefill"})
        (o,) = b.call([c], [32], 2.5, "op",
                      meta={"rid": 0, "slot": 1, "pos": 3})
        b.release(o, meta={"phase": "retire"})
        text = b.log.dumps()
        log2 = Log.loads(text)
        assert log2.name == "t"
        assert log2.meta == {"source": "test", "slots": 2}
        assert log2.instrs == b.log.instrs
        calls = [i for i in log2.instrs if isinstance(i, Call)]
        assert calls[0].meta == (("rid", 0), ("slot", 1), ("pos", 3))

    def test_header_carries_version(self):
        text = Log([Constant("a"), Memory("a", 4)], name="x").dumps()
        head = json.loads(text.splitlines()[0])
        assert head == {"kind": "LogHeader", "version": SCHEMA_VERSION,
                        "name": "x"}

    def test_loads_accepts_headerless_v1(self):
        v1 = ('{"kind": "Constant", "t": "a"}\n'
              '{"kind": "Memory", "t": "a", "size": 8}')
        log = Log.loads(v1, name="old")
        assert log.name == "old"
        assert log.version == 1        # loaded version is preserved
        assert log.instrs == [Constant("a"), Memory("a", 8)]

    def test_explicit_name_overrides_header(self):
        text = Log([], name="from_header").dumps()
        assert Log.loads(text).name == "from_header"
        assert Log.loads(text, name="override").name == "override"

    def test_rejects_future_version(self):
        with pytest.raises(ValueError, match="newer"):
            Log.loads('{"kind": "LogHeader", "version": 99}')

    def test_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown instruction"):
            Log.loads('{"kind": "Frobnicate", "t": "a"}')

    def test_rejects_bad_fields(self):
        with pytest.raises(ValueError, match="bad fields"):
            Log.loads('{"kind": "Constant", "nope": 1}')

    def test_rejects_non_json_and_non_object(self):
        with pytest.raises(ValueError, match="malformed"):
            Log.loads("CONSTANT t0")
        with pytest.raises(ValueError, match="malformed"):
            Log.loads("[1, 2, 3]")

    def test_as_meta_normalizes(self):
        assert as_meta(None) == ()
        assert as_meta({"a": 1}) == (("a", 1),)
        assert as_meta([("b", "x")]) == (("b", "x"),)

    @settings(max_examples=30, deadline=None)
    @given(data=st.data())
    def test_roundtrip_property(self, data):
        """Random instruction streams survive dumps/loads bit-for-bit."""
        names = st.text(
            alphabet="abcdefgh0123456789_.", min_size=1, max_size=8)
        metas = st.one_of(
            st.just(()),
            st.lists(st.tuples(names, st.one_of(
                st.integers(-100, 100), names)),
                max_size=3).map(lambda p: as_meta(p)))
        instrs = []
        made = data.draw(st.lists(names, min_size=1, max_size=12,
                                  unique=True))
        for i, t in enumerate(made):
            m = data.draw(metas)
            if i == 0 or data.draw(st.booleans()):
                instrs.append(Constant(t, meta=m))
                instrs.append(Memory(t, data.draw(st.integers(0, 2**40))))
            else:
                src = data.draw(st.sampled_from(made[:i]))
                cost = data.draw(st.floats(
                    0, 1e12, allow_nan=False, allow_infinity=False))
                instrs.append(Call((src,), (t,), cost, f"op{i}", meta=m))
                instrs.append(Memory(t, data.draw(st.integers(0, 2**30))))
                instrs.append(Alias(t, None))
            if data.draw(st.booleans()):
                instrs.append(Release(t, meta=data.draw(metas)))
        log = Log(instrs, name=data.draw(names),
                  meta={"k": data.draw(st.integers(0, 10))})
        log2 = Log.loads(log.dumps())
        assert log2.instrs == log.instrs
        assert log2.name == log.name
        assert log2.meta == log.meta


# ---------------------------------------------------------------------------
# Eager TraceRecorder
# ---------------------------------------------------------------------------

class TestTraceRecorder:
    def _capture_chain(self, budget=float("inf")):
        jnp = pytest.importorskip("jax.numpy")
        from repro.eager import DTRContext
        from repro.trace import TraceRecorder
        rec = TraceRecorder(name="chain")
        ctx = DTRContext(budget_bytes=budget, use_wallclock_cost=False,
                         recorder=rec)
        x = ctx.wrap(jnp.ones(1024), name="x")
        h = x
        for i in range(6):
            h = ctx.call(f"f{i}", lambda a: a * 1.5, [h])[0]
        h.release()
        return rec.finish(), ctx

    def test_records_ops_constants_releases(self):
        log, ctx = self._capture_chain()
        assert log.op_count() == 6
        consts = [i for i in log.instrs if isinstance(i, Constant)]
        assert len(consts) == 1
        rels = [i for i in log.instrs if isinstance(i, Release)]
        assert len(rels) == 1
        assert log.meta["source"] == "eager"

    def test_remats_not_recorded(self):
        """Evictions/remats under pressure must not pollute the stream."""
        log_inf, _ = self._capture_chain()
        log_tight, ctx = self._capture_chain(budget=3 * 4096)
        assert ctx.rt.evictions > 0
        assert log_tight.op_count() == log_inf.op_count()

    def test_captured_log_replays(self):
        log, ctx = self._capture_chain()
        r = simulate(log, "h_dtr_eq", budget=float("inf"))
        assert r.ok
        assert r.ops_executed == 6

    def test_release_via_context_dedupes(self):
        from repro.trace import TraceRecorder
        rec = TraceRecorder()
        rec.on_constant(0, "c", 16)
        rec.on_release(0)
        rec.on_release(0)
        rels = [i for i in rec.finish().instrs if isinstance(i, Release)]
        assert len(rels) == 1


# ---------------------------------------------------------------------------
# Continuous-batching serve driver
# ---------------------------------------------------------------------------

def tiny_model():
    from repro.trace import ServeStepModel
    return ServeStepModel(weight_bytes=10_000, hidden_bytes=32,
                          kv_token_bytes=64, decode_cost=100.0,
                          attn_token_cost=2.0, prefill_token_cost=100.0)


class TestServeDriver:
    def test_deterministic(self):
        from repro.trace import capture_serve_trace
        a = capture_serve_trace(tiny_model(), slots=3, requests=7, gen=5,
                                seed=3)
        b = capture_serve_trace(tiny_model(), slots=3, requests=7, gen=5,
                                seed=3)
        assert a.dumps() == b.dumps()
        c = capture_serve_trace(tiny_model(), slots=3, requests=7, gen=5,
                                seed=4)
        assert a.dumps() != c.dumps()

    def test_interleaved_lifetimes(self):
        """Requests retire while neighbors are mid-flight (continuous
        batching), which no synthetic builder in core.graphs produces."""
        from repro.trace import capture_serve_trace
        log = capture_serve_trace(tiny_model(), slots=2, requests=5, gen=4,
                                  seed=0)
        retire_meta = [dict(i.meta) for i in log.instrs
                       if isinstance(i, Release) and
                       dict(i.meta).get("phase") == "retire"]
        assert len(retire_meta) == 5
        decodes = [dict(i.meta) for i in log.instrs
                   if isinstance(i, Call) and
                   dict(i.meta).get("phase") == "decode"]
        first_retire = next(
            n for n, i in enumerate(log.instrs)
            if isinstance(i, Release)
            and dict(i.meta).get("phase") == "retire")
        later_decodes = [
            dict(i.meta) for i in log.instrs[first_retire:]
            if isinstance(i, Call) and dict(i.meta).get("phase") == "decode"]
        assert later_decodes, "a retire must interleave with live decodes"

    def test_kv_chunking_bounds_storage_count(self):
        from repro.trace import capture_serve_trace
        log = capture_serve_trace(tiny_model(), slots=1, requests=1, gen=9,
                                  prompt_min=4, prompt_max=4, seed=0,
                                  kv_chunk=4)
        # 13 positions at chunk 4 -> prefill page + sealed pages + partial.
        calls = [i for i in log.instrs if isinstance(i, Call)]
        assert all(len(c.inputs) <= 2 + 13 // 4 + 1 for c in calls)

    def test_replays_under_pressure(self):
        from repro.trace import capture_serve_trace
        log = capture_serve_trace(tiny_model(), slots=2, requests=4, gen=6,
                                  seed=0)
        peak, _ = measure_baseline(log)
        pinned = log.pinned_bytes()
        r = simulate(log, "h_dtr",
                     resolve_budget(0.6, peak, pinned, "activation"))
        assert r.ok and r.evictions > 0 and r.remat_ops > 0

    def test_pinned_bytes(self):
        from repro.trace import capture_serve_trace
        log = capture_serve_trace(tiny_model(), slots=2, requests=3, gen=4,
                                  seed=0)
        assert log.pinned_bytes() == 10_000


# ---------------------------------------------------------------------------
# Activation-budget sweeps
# ---------------------------------------------------------------------------

class TestActivationBudget:
    def test_resolve_budget(self):
        assert resolve_budget(0.5, 100.0, 0.0, "peak") == 50.0
        assert resolve_budget(0.5, 100.0, 60.0, "activation") == 80.0
        with pytest.raises(ValueError):
            resolve_budget(0.5, 100.0, 0.0, "nope")

    def test_sweep_parallel_activation_mode(self):
        from repro.trace import capture_serve_trace
        log = capture_serve_trace(tiny_model(), slots=2, requests=3, gen=4,
                                  seed=0)
        peak, _ = measure_baseline(log)
        pinned = log.pinned_bytes()
        (sw,) = sweep_parallel(log, "h_lru", [0.7], processes=0,
                               budget_mode="activation")
        direct = simulate(log, "h_lru",
                          resolve_budget(0.7, peak, pinned, "activation"))
        got = sw.runs[0]
        assert (got.ok, got.evictions, got.compute) == (
            direct.ok, direct.evictions, direct.compute)


# ---------------------------------------------------------------------------
# jaxpr capture
# ---------------------------------------------------------------------------

class TestJaxprCapture:
    def test_unit_and_flops_cost_models(self):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp
        from repro.trace import capture_jaxpr

        def f(a, b):
            return jnp.tanh(a @ b).sum()

        a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((16, 4), jnp.float32)
        unit = capture_jaxpr(f, a, b, cost_model="unit")
        assert unit.baseline_cost() == unit.op_count()
        flops = capture_jaxpr(f, a, b, cost_model="flops")
        assert flops.baseline_cost() > unit.baseline_cost()
        assert flops.meta["source"] == "jaxpr"

    def test_scan_unroll_exposes_layers(self):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp
        from repro.trace import capture_jaxpr

        def stack(x):
            def body(c, _):
                return jnp.tanh(c) * 1.1, c.sum()
            out, ys = jax.lax.scan(body, x, None, length=8)
            return out, ys

        x = jax.ShapeDtypeStruct((16,), jnp.float32)
        rolled = capture_jaxpr(stack, x, cost_model="flops",
                               unroll_scans=False)
        unrolled = capture_jaxpr(stack, x, cost_model="flops",
                                 unroll_scans=True)
        assert unrolled.op_count() > rolled.op_count() + 8
        r = simulate(unrolled, "h_dtr_eq", budget=float("inf"))
        assert r.ok

    def test_train_step_capture_replays(self):
        pytest.importorskip("jax")
        from repro.trace import capture_train_step
        log = capture_train_step("qwen2-0.5b", smoke=True, batch=1, seq=4,
                                 cost_model="flops")
        assert log.op_count() > 100
        r = simulate(log, "h_lru", budget=float("inf"))
        assert r.ok


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCLI:
    def test_capture_and_replay_roundtrip(self, tmp_path):
        from repro.trace.__main__ import main
        out = tmp_path / "dag.log"
        assert main(["capture", "--source", "random-dag",
                     "--out", str(out)]) == 0
        assert main(["replay", str(out), "--heuristics", "h_lru",
                     "--fractions", "0.8", "--processes", "0",
                     "--thrash-factor", "3"]) == 0

    def test_capture_verify_gate(self, tmp_path):
        from repro.trace.__main__ import main
        out = tmp_path / "dag.log"
        assert main(["capture", "--source", "treelstm", "--out", str(out),
                     "--verify", "--fractions", "0.8", "0.5",
                     "--thrash-factor", "3"]) == 0
