"""Checkpoint manager: per-host sharded layout + atomic-rename crash safety."""
import os

import numpy as np
import pytest

from repro.ckpt.manager import (CheckpointManager, restore_latest,
                                save_checkpoint)


def tree():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones(3, dtype=np.float32)}


class TestPerHostSharding:
    def test_host_suffix_in_filename(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 7, tree(), host=3)
        assert os.path.exists(os.path.join(path, "arrays.3.npz"))
        assert not os.path.exists(os.path.join(path, "arrays.0.npz"))

    def test_roundtrip_per_host(self, tmp_path):
        t = tree()
        save_checkpoint(str(tmp_path), 12, t, extra={"cursor": 5}, host=1)
        step, restored, extra = restore_latest(str(tmp_path), t, host=1)
        assert step == 12
        assert extra == {"cursor": 5}
        np.testing.assert_array_equal(restored["w"], t["w"])
        np.testing.assert_array_equal(restored["b"], t["b"])

    def test_missing_host_shard_fails_loudly(self, tmp_path):
        t = tree()
        save_checkpoint(str(tmp_path), 3, t, host=0)
        with pytest.raises(FileNotFoundError):
            restore_latest(str(tmp_path), t, host=2)


class TestAtomicity:
    def test_crash_mid_save_leaves_no_step_dir(self, tmp_path, monkeypatch):
        t = tree()

        def boom(*a, **k):
            raise RuntimeError("disk full")

        monkeypatch.setattr(np, "savez", boom)
        with pytest.raises(RuntimeError):
            save_checkpoint(str(tmp_path), 5, t)
        # No step dir and no leftover temp dir after the failed save.
        assert [d for d in os.listdir(tmp_path)] == []

    def test_stale_temp_dir_never_shadows_latest(self, tmp_path):
        t = tree()
        save_checkpoint(str(tmp_path), 10, t)
        # Simulate a crash from another process: orphaned temp dir with a
        # half-written payload.  Restore must ignore it.
        stale = tmp_path / ".tmp_ckpt_stale"
        stale.mkdir()
        (stale / "manifest.json").write_text("{corrupt")
        step, restored, _ = restore_latest(str(tmp_path), t)
        assert step == 10
        np.testing.assert_array_equal(restored["w"], t["w"])

    def test_overwrite_same_step_is_atomic(self, tmp_path):
        t = tree()
        save_checkpoint(str(tmp_path), 4, t, extra={"v": 1})
        t2 = {"w": t["w"] * 2, "b": t["b"] * 2}
        save_checkpoint(str(tmp_path), 4, t2, extra={"v": 2})
        step, restored, extra = restore_latest(str(tmp_path), t)
        assert step == 4 and extra == {"v": 2}
        np.testing.assert_array_equal(restored["w"], t2["w"])


class TestManagerPolicy:
    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), every_steps=1, keep=2)
        t = tree()
        for s in (1, 2, 3, 4):
            mgr.save(s, t)
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert kept == ["step_0000000003", "step_0000000004"]

    def test_maybe_save_cadence(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), every_steps=10, keep=5)
        t = tree()
        assert mgr.maybe_save(7, t) is None
        assert mgr.maybe_save(10, t) is not None

    def test_restore_empty_dir(self, tmp_path):
        t = tree()
        step, restored, extra = restore_latest(str(tmp_path / "none"), t)
        assert step is None and restored is t and extra == {}
