"""Dry-run smoke: one real (arch × shape × mesh) cell compiles end-to-end.

Runs in a subprocess because the dry-run must own jax's device-count
initialization (512 forced host devices) — the test process has 1 device.
The full 68-cell sweep is exercised by `repro.launch.dryrun --all`
(artifacts in experiments/dryrun/); this keeps one representative cell in
the always-on test suite.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_single_cell_compiles(tmp_path, mesh):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen2-0.5b", "--shape", "decode_32k",
         "--mesh", mesh, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "all cells OK" in out.stdout
    tag = f"qwen2-0.5b_decode_32k_{mesh}.json"
    with open(tmp_path / tag) as f:
        res = json.load(f)
    assert res["chips"] == (512 if mesh == "multi" else 256)
    assert res["memory"]["peak_bytes_per_device"] < 16 * 2**30
    r = res["roofline"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
