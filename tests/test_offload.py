"""Tests for the hybrid rematerialize-or-offload tier (``repro.offload``).

Covers, per the subsystem's contracts:

* transfer-model / host-tier units (channel serialization, round-trip cost,
  capacity accounting);
* the two-choice crossover — cheap-to-recompute storages evict, expensive
  ones offload, at the exact key comparison the policy advertises;
* offload -> prefetch/fetch -> use round trips preserve contents with no
  rematerialization, in the pure simulator and through the eager executor's
  real JAX buffers;
* ``host_budget=0`` is bit-exact with the pre-offload engine: golden-trace
  victim digests (``tests/traces/expected.json``) are reproduced unchanged;
* scan-vs-index equivalence holds with the offload key family active, for
  every cost-aware base heuristic and for the offload-only policy;
* the EWMA reuse predictor is validated against the exact trace oracle.
"""
import hashlib
import json
import os

import pytest

from repro.core import graphs
from repro.core.graph import Log
from repro.core.heuristics import by_name
from repro.core.runtime import DTRRuntime
from repro.core.simulator import measure_baseline, resolve_budget, simulate
from repro.offload import (HybridHeuristic, OffloadConfig, OffloadEngine,
                           ReusePredictor, TransferModel, reuse_oracle,
                           trace_access_stream, wrap_heuristic)
from repro.trace.replay import PARITY_FIELDS, run_trace

TRACE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "traces")

#: Heuristics whose key prices recomputation (valid hybrid bases).
COST_AWARE = ("h_dtr", "h_dtr_eq", "h_dtr_local", "h_msps", "h_estar")


def load_trace(name: str) -> Log:
    with open(os.path.join(TRACE_DIR, f"{name}.log")) as f:
        return Log.loads(f.read())


# ---------------------------------------------------------------------------
# Transfer model / host tier units
# ---------------------------------------------------------------------------

class TestTransferModel:
    def test_duration_and_roundtrip(self):
        m = TransferModel(OffloadConfig(host_budget=100.0, h2d_bandwidth=2.0,
                                        d2h_bandwidth=4.0, latency=0.25))
        assert m.h2d.duration(8.0) == 0.25 + 4.0
        assert m.d2h.duration(8.0) == 0.25 + 2.0
        # Round trip = both fixed latencies + both per-byte terms, and is
        # contention-free by construction (it prices keys, not schedules).
        assert m.roundtrip(8.0) == 0.5 + 2.0 + 4.0

    def test_channel_serializes_transfers(self):
        m = TransferModel(OffloadConfig(host_budget=100.0, h2d_bandwidth=1.0,
                                        d2h_bandwidth=1.0))
        t1 = m.d2h.transfer(0.0, 4.0)     # lands at 4
        t2 = m.d2h.transfer(1.0, 4.0)     # queued behind t1: lands at 8
        assert (t1, t2) == (4.0, 8.0)
        # Independent channel: no cross-direction contention.
        assert m.h2d.transfer(1.0, 4.0) == 5.0
        assert m.d2h.transfers == 2 and m.d2h.bytes == 8.0

    def test_host_tier_accounting(self):
        eng = OffloadEngine(OffloadConfig(host_budget=10.0))
        host = eng.host
        assert host.can_fit(10.0) and not host.can_fit(10.5)
        host.put(1, 6.0)
        host.put(2, 4.0)
        assert host.used == 10.0 and host.peak == 10.0
        assert not host.can_fit(0.5)
        assert host.take(1) == 6.0
        assert host.used == 4.0 and host.peak == 10.0
        assert 2 in host and 1 not in host

    def test_disabled_config_rejected_by_engine(self):
        assert not OffloadConfig(host_budget=0.0).enabled
        with pytest.raises(AssertionError):
            OffloadEngine(OffloadConfig(host_budget=0.0))

    def test_hybrid_requires_cost_aware_base(self):
        eng = OffloadEngine(OffloadConfig(host_budget=10.0))
        with pytest.raises(ValueError):
            HybridHeuristic(by_name("h_lru"), eng)


# ---------------------------------------------------------------------------
# Two-choice crossover
# ---------------------------------------------------------------------------

class TestTwoChoiceCrossover:
    def _runtime(self, policy="hybrid"):
        # Unit bandwidths => transfer key = roundtrip(size)/size = 2.0
        # exactly; h_dtr_local's key is local_cost/size, so the crossover
        # sits at local_cost == 2.0 per byte.
        eng = OffloadEngine(OffloadConfig(host_budget=1000.0, policy=policy,
                                          prefetch=False))
        h = wrap_heuristic(by_name("h_dtr_local"), eng)
        rt = DTRRuntime(budget=1000.0, heuristic=h, offload=eng)
        return rt, eng

    def test_cheap_recompute_evicts_expensive_offloads(self):
        rt, eng = self._runtime()
        c = rt.constant(10)
        (cheap,) = rt.call("cheap", 0.5, [c], [40])    # key 0.0125 < 2.0
        (dear,) = rt.call("dear", 200.0, [c], [40])    # key 5.0 > 2.0
        s_cheap = rt.storages[rt.tensors[cheap].sid]
        s_dear = rt.storages[rt.tensors[dear].sid]
        assert not eng.wants_offload(rt, s_cheap)
        assert eng.wants_offload(rt, s_dear)
        rt._evict_or_offload(s_cheap)
        rt._evict_or_offload(s_dear)
        assert rt.evictions == 1 and rt.offloads == 1
        assert not s_cheap.offloaded and s_dear.offloaded
        assert eng.host.used == 40.0

    def test_exact_crossover_point(self):
        rt, eng = self._runtime()
        c = rt.constant(10)
        # key == transfer key exactly: strict < means "prefer recompute on
        # ties" (eviction is free of host capacity).
        (t_at,) = rt.call("at", 80.0, [c], [40])       # key 2.0 == 2.0
        (t_just,) = rt.call("just", 80.2, [c], [40])   # key 2.005 > 2.0
        assert not eng.wants_offload(rt, rt.storages[rt.tensors[t_at].sid])
        assert eng.wants_offload(rt, rt.storages[rt.tensors[t_just].sid])

    def test_offload_policy_ignores_recompute_cost(self):
        rt, eng = self._runtime(policy="offload")
        c = rt.constant(10)
        (cheap,) = rt.call("cheap", 0.5, [c], [40])
        assert eng.wants_offload(rt, rt.storages[rt.tensors[cheap].sid])

    def test_host_capacity_forces_eviction(self):
        eng = OffloadEngine(OffloadConfig(host_budget=50.0, policy="offload",
                                          prefetch=False))
        h = wrap_heuristic(by_name("h_dtr_local"), eng)
        rt = DTRRuntime(budget=1000.0, heuristic=h, offload=eng)
        c = rt.constant(10)
        (a,) = rt.call("a", 1.0, [c], [40])
        (b,) = rt.call("b", 1.0, [c], [40])
        sa = rt.storages[rt.tensors[a].sid]
        sb = rt.storages[rt.tensors[b].sid]
        rt._evict_or_offload(sa)
        assert sa.offloaded
        rt._evict_or_offload(sb)           # host full (40 of 50): evict
        assert not sb.offloaded and rt.evictions == 1


# ---------------------------------------------------------------------------
# Offload -> fetch round trip
# ---------------------------------------------------------------------------

class TestRoundTrip:
    def test_simulated_offload_only_run_never_remats(self):
        log = graphs.linear_network(32)
        peak, cost = measure_baseline(log)
        cfg = OffloadConfig(host_budget=10 * peak, h2d_bandwidth=peak / cost,
                            d2h_bandwidth=peak / cost, policy="offload")
        r = simulate(log, "h_dtr_eq", budget=0.3 * peak, offload=cfg)
        assert r.ok
        assert r.offloads > 0 and r.fetches > 0
        # Dead storages still evict eagerly (offloading a never-again-used
        # storage would waste bandwidth) — but nothing live ever remats:
        assert r.remat_ops == 0
        assert r.compute == r.base_compute          # no recompute at all
        assert r.stall_time > 0                     # transfers aren't free
        assert r.host_peak > 0

    def test_fetch_restores_defined_views_and_membership(self):
        eng = OffloadEngine(OffloadConfig(host_budget=100.0,
                                          policy="offload", prefetch=False))
        h = wrap_heuristic(by_name("h_dtr_local"), eng)
        rt = DTRRuntime(budget=1000.0, heuristic=h, offload=eng)
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [40])
        s = rt.storages[rt.tensors[a].sid]
        rt._evict_or_offload(s)
        assert s.offloaded and not s.resident
        assert not rt.tensors[a].defined
        rt.get(a)                                  # access: fetch-back
        assert s.resident and not s.offloaded
        assert rt.tensors[a].defined
        assert rt.fetches == 1 and rt.remat_ops == 0
        assert s.sid not in eng._recs and eng.host.used == 0.0

    def test_eager_round_trip_preserves_contents(self):
        jnp = pytest.importorskip("jax.numpy")
        import numpy as np
        from repro.eager.executor import DTRContext
        cfg = OffloadConfig(host_budget=1 << 20, h2d_bandwidth=1e9,
                            d2h_bandwidth=1e9)
        ctx = DTRContext(budget_bytes=4096, heuristic="h_dtr_eq",
                         use_wallclock_cost=False, offload=cfg)
        base = ctx.wrap(np.random.RandomState(0).randn(16, 16)
                        .astype(np.float32))
        outs = [ctx.call("mul", jnp.multiply, [base, float(i + 1)])[0]
                for i in range(12)]
        assert ctx.rt.offloads > 0           # pressure moved bytes to host
        ref = np.asarray(base.value)
        for i, o in enumerate(outs):         # touching fetches them back
            np.testing.assert_allclose(np.asarray(o.value), ref * (i + 1))
        assert ctx.rt.fetches > 0
        assert ctx.remat_runs == 0           # contents came back, not replays
        assert ctx.host_bytes() <= cfg.host_budget

    def test_prefetch_hits_fire_and_never_change_compute(self):
        # After the EWMA warms up on the recurrent reuse pattern, the pump
        # issues copy-backs early: accesses land on in-flight prefetches
        # (hits) instead of paying the full synchronous transfer.  Prefetch
        # is a latency-hiding knob only — recompute totals are identical
        # with it on or off.
        log = graphs.lstm(steps=24, width=8, batch=4)
        peak, cost = measure_baseline(log)
        bw = 8.0 * peak / cost
        on = OffloadConfig(host_budget=peak, h2d_bandwidth=bw,
                           d2h_bandwidth=bw, policy="offload", prefetch=True)
        off = OffloadConfig(host_budget=peak, h2d_bandwidth=bw,
                            d2h_bandwidth=bw, policy="offload",
                            prefetch=False)
        r_on = simulate(log, "h_dtr_eq", budget=0.5 * peak, offload=on)
        r_off = simulate(log, "h_dtr_eq", budget=0.5 * peak, offload=off)
        assert r_on.ok and r_off.ok
        assert r_on.prefetch_hits > 0
        assert r_off.prefetch_hits == 0 and r_off.prefetch_cancelled == 0
        assert r_on.compute == r_off.compute == r_on.base_compute


    def test_pool_host_alloc_mode(self):
        # Contiguous pool + host tier together: window eviction routes
        # victims through the two-choice policy, and prefetch reservations
        # are reclaimed before the allocator declares OOM.
        log = graphs.random_dag(60, seed=3)
        peak, cost = measure_baseline(log)
        bw = 2 * peak / cost
        cfg = OffloadConfig(host_budget=peak, h2d_bandwidth=bw,
                            d2h_bandwidth=bw)
        r = simulate(log, "h_dtr_eq", budget=0.5 * peak, offload=cfg,
                     alloc_mode="pool+host", thrash_factor=20.0)
        assert r.ok and r.offloads > 0 and r.fetches > 0
        with pytest.raises(ValueError):
            simulate(log, "h_dtr_eq", budget=0.5 * peak,
                     alloc_mode="pool+host")


# ---------------------------------------------------------------------------
# host_budget=0 bit-exactness against the golden corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["treelstm", "random_dag"])
def test_disabled_tier_reproduces_golden_digests(name):
    with open(os.path.join(TRACE_DIR, "expected.json")) as f:
        exp = json.load(f)[name]
    log = load_trace(name)
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    null_cfg = OffloadConfig(host_budget=0.0)
    for cell, want in exp["cells"].items():
        h, frac = cell.split("@")
        budget = resolve_budget(float(frac), peak, pinned, "activation")
        res, victims = run_trace(log, h, budget, index=True,
                                 thrash_factor=3.0, offload=null_cfg)
        assert res.offloads == 0 and res.stall_time == 0.0
        got_digest = hashlib.sha1(
            ",".join(map(str, victims)).encode()).hexdigest()
        assert got_digest == want["victims_sha1"], (
            f"{name}/{cell}: host_budget=0 flipped an eviction decision")
        assert res.evictions == want["evictions"]
        assert repr(res.compute) == want["compute"]


# ---------------------------------------------------------------------------
# Scan-vs-index equivalence with the offload key family active
# ---------------------------------------------------------------------------

def _assert_parity(log, heuristic, budget, cfg):
    scan_res, scan_victims = run_trace(log, heuristic, budget, index=False,
                                       thrash_factor=10.0, offload=cfg)
    idx_res, idx_victims = run_trace(log, heuristic, budget, index=True,
                                     thrash_factor=10.0, offload=cfg)
    assert scan_victims == idx_victims
    for fld in PARITY_FIELDS:
        assert getattr(scan_res, fld) == getattr(idx_res, fld), (
            f"{heuristic}: {fld} scan={getattr(scan_res, fld)} "
            f"index={getattr(idx_res, fld)}")


@pytest.mark.parametrize("heuristic", COST_AWARE)
def test_scan_vs_index_with_hybrid_keys(heuristic):
    log = graphs.random_dag(80, seed=1)
    peak, cost = measure_baseline(log)
    for bw_rel in (0.5, 4.0):
        bw = bw_rel * peak / cost
        cfg = OffloadConfig(host_budget=peak, h2d_bandwidth=bw,
                            d2h_bandwidth=bw)
        for f in (0.6, 0.4):
            _assert_parity(log, heuristic, f * peak, cfg)


@pytest.mark.parametrize("heuristic", ["h_lru", "h_size", "h_dtr_eq"])
def test_scan_vs_index_with_offload_only_policy(heuristic):
    # The offload-only TransferHeuristic replaces the base entirely, so
    # non-cost-aware heuristics are valid here.
    log = graphs.random_dag(80, seed=1)
    peak, cost = measure_baseline(log)
    cfg = OffloadConfig(host_budget=peak, h2d_bandwidth=2 * peak / cost,
                        d2h_bandwidth=2 * peak / cost, policy="offload")
    for f in (0.6, 0.4):
        _assert_parity(log, heuristic, f * peak, cfg)


# ---------------------------------------------------------------------------
# Reuse predictor vs the exact trace oracle
# ---------------------------------------------------------------------------

class TestPredictor:
    def test_converges_exactly_on_periodic_stream(self):
        p = ReusePredictor()
        for i in range(10):
            p.observe(7, i * 3.0)
        assert p.predict_next(7, 27.5) == 30.0

    def test_no_history_no_prediction(self):
        p = ReusePredictor()
        assert p.predict_next(1, 0.0) is None
        p.observe(1, 5.0)                    # single sighting: still no gap
        assert p.predict_next(1, 6.0) is None

    def test_overdue_prediction_clamps_to_now(self):
        p = ReusePredictor()
        p.observe(3, 0.0)
        p.observe(3, 2.0)
        assert p.predict_next(3, 10.0) == 10.0

    @pytest.mark.parametrize("name", ["random_dag", "treelstm"])
    def test_ewma_stays_within_oracle_bounds_on_golden_traces(self, name):
        # The EWMA is a convex combination of observed gaps, so for every
        # storage the learned gap must lie inside the oracle's exact
        # [min, max] gap envelope — the validation the prefetch lead check
        # relies on.  (Feeding op indices as the clock makes the two
        # streams directly comparable.)
        log = load_trace(name)
        oracle = reuse_oracle(log)
        pred = ReusePredictor()
        for opi, key in trace_access_stream(log):
            pred.observe(key, float(opi))
        checked = 0
        for key, gaps in oracle.items():
            learned = pred._gap.get(key)
            if learned is None:
                continue
            assert min(gaps) <= learned <= max(gaps), (
                f"{name}/{key}: EWMA {learned} outside oracle "
                f"[{min(gaps)}, {max(gaps)}]")
            checked += 1
        assert checked > 10           # the traces genuinely exercise reuse

    def test_oracle_collapses_aliases_to_root_storage(self):
        from repro.core.graph import Alias, Call, Constant, Memory
        log = graphs.linear_network(4)
        stream = trace_access_stream(log)
        assert stream, "chain trace has input accesses"
        # Every event names a root tensor (no alias output names leak).
        roots = {t for _, t in stream}
        alias_outs = {i.t_out for i in log.instrs
                      if isinstance(i, Alias) and i.t_in is not None}
        assert not (roots & alias_outs)
