"""Per-architecture smoke tests (reduced configs, CPU).

For each of the 10 assigned archs: instantiate the reduced config, run one
forward + one train step, assert output shapes and no NaNs; verify decode-
with-cache matches the train-mode forward exactly (KV ring buffers, RG-LRU /
RWKV states, MLA absorbed decode all covered by that single invariant).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.optim import adamw, apply_updates, clip_by_global_norm


def _batch(cfg, key, b=2, s=32):
    if cfg.n_codebooks:
        tokens = jax.random.randint(key, (b, s, cfg.n_codebooks), 0,
                                    cfg.vocab)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.cross_attn_dim:
        batch["img_embed"] = jax.random.normal(
            key, (b, cfg.cross_attn_tokens, cfg.cross_attn_dim)) * 0.1
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits = M.forward(cfg, params, batch["tokens"],
                       batch.get("img_embed"))
    b, s = batch["tokens"].shape[:2]
    if cfg.n_codebooks:
        assert logits.shape == (b, s, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step_no_nans(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    opt = adamw(lr=1e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        grads, gn = clip_by_global_norm(grads, 1.0)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss, gn

    params2, state, loss, gn = step(params, state, batch)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.isfinite(gn))
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.isfinite(leaf).all())
    # Params actually moved.
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.get_smoke(arch)
    if cfg.moe:
        # Drop-free capacity so train == decode (capacity drops are train-
        # time routing semantics, not a cache bug).
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    b, s = 2, 32
    batch = _batch(cfg, key, b, s)
    tokens = batch["tokens"]
    img = batch.get("img_embed")
    full = M.forward(cfg, params, tokens, img_embed=img)
    cache = M.init_cache(cfg, b, s)
    step = jax.jit(
        lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos, img_embed=img))
    outs = []
    for i in range(s):
        lg, cache = step(params, tokens[:, i:i + 1], cache, jnp.int32(i))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    rel = float(jnp.max(jnp.abs(dec - full))) / scale
    assert rel < 2e-2, f"{arch}: decode/train rel err {rel}"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_instantiates(arch):
    """Full configs must construct + count params (no allocation)."""
    cfg = configs.get(arch)
    n = cfg.param_count()
    assert n > 1e8 or arch == "smollm_135m"
    structs = M.param_structs(cfg)
    total = sum(np.prod(s.shape) for s in jax.tree.leaves(structs))
    # ShapeDtypeStruct-derived count should be same order as the analytic one.
    assert 0.4 < total / n < 2.6, (total, n)


def test_remat_variants_match():
    """The paper's technique must not change numerics: remat none/full/dtr."""
    cfg = configs.get_smoke("llama3_2_1b")
    key = jax.random.PRNGKey(2)
    batch = _batch(cfg, key)
    losses = {}
    for mode in ("none", "full", "dtr", "names:attn_out"):
        c = cfg.replace(remat=mode)
        params = M.init_params(c, key)
        losses[mode] = float(jax.jit(
            lambda p: M.loss_fn(c, p, batch))(params))
    base = losses["none"]
    for mode, v in losses.items():
        np.testing.assert_allclose(v, base, rtol=1e-5)


def test_remat_reduces_saved_residuals():
    """remat=full must lower compiled peak memory vs remat=none."""
    cfg = configs.get_smoke("llama3_2_1b").replace(n_layers=8)
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key, b=4, s=128)

    def peak(mode):
        c = cfg.replace(remat=mode)
        params = M.init_params(c, key)
        f = jax.jit(jax.grad(lambda p: M.loss_fn(c, p, batch)))
        comp = f.lower(params).compile()
        mem = comp.memory_analysis()
        return mem.temp_size_in_bytes

    assert peak("full") < peak("none")
