"""Differential tests for ``repro.static`` (the Checkmate bridge).

Four layers of validation:

1. **Solver differential** — the heterogeneous DP must match the
   exhaustive subset oracle exactly on small random chains, dominate
   both Chen baselines structurally, and be monotone in the budget.
2. **LP floor** — the LP relaxation must lower-bound the executed extra
   compute of every feasible plan and every successful DTR run, the
   dual-greedy fallback must never exceed the scipy optimum, and
   structural infeasibility must coincide with real infeasibility.
3. **Executor parity** — the pure evaluator and the real-runtime replay
   must agree bit-for-bit on every counter (remats, evictions, compute,
   peak), with the plan respecting its byte budget under the
   fragmentation-tracking allocator.
4. **fig3 regression** — the benchmark must propagate programming errors
   (only OOM/Thrash mean infeasible) and report Chen-√n feasibility
   honestly.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is optional: property tests skip, rest run
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core import baselines, graphs
from repro.core.simulator import measure_baseline, simulate
from repro.static import (build_frontier, build_view, best_static_plan,
                          chen_greedy, chen_sqrt, compile_plan,
                          enumerate_optimal, evaluate_plan, execute_plan,
                          extract_chain, lp_lower_bound, optimal_dp,
                          plan_cost, plan_peak, synthetic_chain,
                          trim_touches)

# ---------------------------------------------------------------------------
# shared fixtures: heterogeneous chains, model-level and trace-level
# ---------------------------------------------------------------------------

COSTS = [1.0, 2.0, 3.0, 5.0, 8.0]
SIZES = [1, 2, 4, 8, 16]


def _random_chain(rng, n):
    return synthetic_chain([rng.choice(COSTS) for _ in range(n)],
                           [rng.choice(SIZES) for _ in range(n)],
                           floor=rng.choice([0.0, 3.0, 10.0]))


def _het_log(rng, n):
    return graphs.linear_network(n, costs=[rng.choice(COSTS)
                                           for _ in range(n)],
                                 sizes=[rng.choice(SIZES)
                                        for _ in range(n)])


def _budgets(chain):
    """A sweep from just-infeasible to fully slack, model-level."""
    total = sum(it.size for it in chain.items)
    lo = max(chain.floor, chain.final_bytes)
    return [lo - 1.0, lo + 1.0,
            lo + 0.25 * total, lo + 0.5 * total, lo + total]


# ---------------------------------------------------------------------------
# 1. solver differential: DP == enumeration oracle on small chains
# ---------------------------------------------------------------------------

class TestSolverDifferential:
    def test_dp_matches_enumeration_on_random_chains(self):
        rng = random.Random(1234)
        agree = 0
        for trial in range(40):
            chain = _random_chain(rng, rng.randint(1, 10))
            for budget in _budgets(chain):
                oracle = enumerate_optimal(chain, budget)
                dp = optimal_dp(chain, budget)
                if oracle is None:
                    assert dp is None, (
                        f"trial {trial}: DP claims feasibility at "
                        f"{budget} where enumeration finds none")
                    continue
                assert dp is not None, (
                    f"trial {trial}: DP misses feasible budget {budget}")
                assert abs(dp.cost - oracle.cost) < 1e-9, (
                    f"trial {trial}@{budget}: DP cost {dp.cost} != "
                    f"oracle {oracle.cost}")
                assert dp.peak <= budget + 1e-9
                agree += 1
        assert agree > 30          # the sweep must actually exercise cells

    def test_dp_dominates_chen_structurally(self):
        rng = random.Random(99)
        for _ in range(20):
            chain = _random_chain(rng, rng.randint(2, 30))
            for budget in _budgets(chain):
                dp = optimal_dp(chain, budget)
                if dp is None:
                    continue
                for p in (chen_sqrt(chain, budget),
                          chen_greedy(chain, budget)):
                    if p.feasible:
                        assert dp.cost <= p.cost + 1e-9

    def test_dp_cost_monotone_in_budget(self):
        rng = random.Random(5)
        for _ in range(15):
            chain = _random_chain(rng, rng.randint(2, 25))
            prev = None
            for budget in sorted(_budgets(chain)):
                p = optimal_dp(chain, budget)
                if p is None:
                    assert prev is None, "feasibility lost as budget grew"
                    continue
                if prev is not None:
                    assert p.cost <= prev + 1e-9, (
                        f"cost rose from {prev} to {p.cost} as the "
                        f"budget grew to {budget}")
                prev = p.cost

    def test_chen_greedy_honest_feasibility(self):
        rng = random.Random(17)
        for _ in range(20):
            chain = _random_chain(rng, rng.randint(1, 20))
            for budget in _budgets(chain):
                p = chen_greedy(chain, budget)
                assert p.feasible == (p.peak <= budget)
                assert abs(p.peak - plan_peak(chain, p.keep)) < 1e-9
                assert abs(p.cost - plan_cost(chain, p.keep)) < 1e-9

    def test_dp_below_every_feasible_plan(self):
        rng = random.Random(31)
        for _ in range(10):
            chain = _random_chain(rng, rng.randint(2, 12))
            n = len(chain)
            budget = _budgets(chain)[3]
            dp = optimal_dp(chain, budget)
            for _ in range(50):
                keep = frozenset(i for i in range(n) if rng.random() < 0.5)
                if plan_peak(chain, keep) <= budget:
                    assert dp is not None
                    assert dp.cost <= plan_cost(chain, keep) + 1e-9

    @given(st.data())
    @settings(max_examples=60, deadline=None)
    def test_dp_matches_enumeration_property(self, data):
        n = data.draw(st.integers(1, 10))
        costs = data.draw(st.lists(st.floats(0.5, 16.0), min_size=n,
                                   max_size=n))
        sizes = data.draw(st.lists(st.integers(1, 32), min_size=n,
                                   max_size=n))
        chain = synthetic_chain(costs, sizes)
        budget = data.draw(st.floats(0.0, float(sum(sizes)) + 4.0))
        oracle = enumerate_optimal(chain, budget)
        dp = optimal_dp(chain, budget)
        assert (oracle is None) == (dp is None)
        if oracle is not None:
            assert abs(dp.cost - oracle.cost) < 1e-9


# ---------------------------------------------------------------------------
# 2. LP floor: valid against executed plans, DTR runs, and its own fallback
# ---------------------------------------------------------------------------

class TestLPBound:
    def test_lp_floors_executed_plans_and_dtr(self):
        rng = random.Random(42)
        checked = 0
        for _ in range(8):
            n = rng.randint(8, 16)
            log = _het_log(rng, n)
            peak, base = measure_baseline(log)
            view = build_view(log)
            chain = extract_chain(view)
            for f in (0.9, 0.7, 0.5):
                budget = f * peak
                lp = lp_lower_bound(view, budget)
                dp = optimal_dp(chain, budget)
                if dp is not None:
                    ev = evaluate_plan(view,
                                       compile_plan(view, chain, dp.keep))
                    if ev.peak_memory <= budget:
                        extra = ev.compute - ev.base_compute
                        assert lp.value <= extra + 1e-9, (
                            f"LP {lp.value} above executed extra {extra}")
                        checked += 1
                r = simulate(log, "h_dtr", budget, thrash_factor=20.0)
                if r.ok:
                    assert lp.value <= (r.compute - r.base_compute) + 1e-9
                    checked += 1
        assert checked > 10

    def test_lp_zero_when_unconstrained_inf_when_hopeless(self):
        log = graphs.linear_network(10, costs=[2.0] * 10, sizes=[4] * 10)
        peak, _ = measure_baseline(log)
        view = build_view(log)
        assert lp_lower_bound(view, peak).value == 0.0
        hopeless = lp_lower_bound(view, 0.0)
        assert hopeless.infeasible
        assert hopeless.value == float("inf")

    def test_dual_greedy_never_exceeds_exact_lp(self, monkeypatch):
        import sys
        rng = random.Random(11)
        log = _het_log(rng, 14)
        peak, _ = measure_baseline(log)
        view = build_view(log)
        for f in (0.8, 0.6, 0.45):
            exact = lp_lower_bound(view, f * peak)
            if exact.solver != "scipy":
                pytest.skip("scipy unavailable; fallback is the only path")
            # blocking the import forces the dual-greedy fallback
            monkeypatch.setitem(sys.modules, "scipy.optimize", None)
            dual = lp_lower_bound(view, f * peak)
            monkeypatch.undo()
            assert dual.solver == "dual_greedy"
            assert not dual.exact
            assert dual.value <= exact.value + 1e-9


# ---------------------------------------------------------------------------
# 3. executor parity: evaluator == real runtime, budget respected
# ---------------------------------------------------------------------------

def _assert_parity(rr, ev):
    assert rr.remat_ops == ev.remat_ops
    assert rr.evictions == ev.evictions
    assert rr.ops_executed == ev.ops_executed
    assert abs(rr.compute - ev.compute) < 1e-9
    assert rr.peak_memory == ev.peak_memory


class TestExecutorParity:
    def test_evaluator_matches_runtime_on_het_chains(self):
        rng = random.Random(77)
        cells = 0
        for _ in range(6):
            log = _het_log(rng, rng.randint(8, 16))
            peak, _ = measure_baseline(log)
            view = build_view(log)
            chain = extract_chain(view)
            plans = [frozenset(range(len(chain)))]          # trim-only
            for f in (0.9, 0.6):
                p = optimal_dp(chain, f * peak)
                if p is not None:
                    plans.append(p.keep)
            for keep in plans:
                plan = compile_plan(view, chain, keep)
                ev = evaluate_plan(view, plan)
                rr = execute_plan(log, plan)
                _assert_parity(rr, ev)
                cells += 1
        assert cells >= 10

    def test_plan_respects_budget_under_pool_nofrag(self):
        rng = random.Random(13)
        log = _het_log(rng, 12)
        peak, _ = measure_baseline(log)
        view = build_view(log)
        chain = extract_chain(view)
        budget = 0.8 * peak
        frontier = build_frontier(view, chain)
        best = best_static_plan(view, chain, frontier, budget)
        assert best is not None
        plan = compile_plan(view, chain, best.keep)
        rr = execute_plan(log, plan, alloc_mode="pool_nofrag")
        assert rr.peak_memory <= budget     # byte budget honored for real
        _assert_parity(rr, best.ev)         # pool keeps counter semantics

    def test_trim_only_plan_is_free_and_below_baseline_peak(self):
        # The dead-zone rule: every storage past its last touch is evicted
        # in every plan, for zero recompute — DTR's "free" wins on eager
        # traces must be matched by the static baseline to keep the
        # comparison fair.  The captured eager trace has real dead zones
        # (framework releases lag last uses); the synthetic chain releases
        # eagerly and must have none.
        import os
        from repro.core.graph import Log
        assert not trim_touches(build_view(graphs.linear_network(10)))
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "traces", "eager_mlp.log")
        with open(path) as f:
            log = Log.loads(f.read(), name="eager_mlp")
        peak, base = measure_baseline(log)
        view = build_view(log)
        chain = extract_chain(view)
        assert trim_touches(view)           # the trace has free tails
        ev = evaluate_plan(view,
                           compile_plan(view, chain,
                                        range(len(chain))))
        assert ev.remat_ops == 0
        assert abs(ev.compute - base) < 1e-9
        assert ev.peak_memory < peak

    def test_panel_static_cost_monotone_in_budget(self):
        rng = random.Random(3)
        log = _het_log(rng, 16)
        peak, _ = measure_baseline(log)
        view = build_view(log)
        chain = extract_chain(view)
        frontier = build_frontier(view, chain)
        prev = None
        for f in (0.95, 0.85, 0.75, 0.65, 0.55):
            best = best_static_plan(view, chain, frontier, f * peak)
            if best is None:
                continue
            assert best.peak <= f * peak
            if prev is not None:
                assert prev <= best.compute + 1e-9, (
                    "shrinking the budget made the plan cheaper: "
                    f"{prev} -> {best.compute}")
            prev = best.compute

    @given(st.data())
    @settings(max_examples=20, deadline=None)
    def test_executor_parity_property(self, data):
        n = data.draw(st.integers(4, 12))
        costs = [data.draw(st.sampled_from(COSTS)) for _ in range(n)]
        sizes = [data.draw(st.sampled_from(SIZES)) for _ in range(n)]
        log = graphs.linear_network(n, costs=costs, sizes=sizes)
        peak, _ = measure_baseline(log)
        view = build_view(log)
        chain = extract_chain(view)
        f = data.draw(st.floats(0.4, 1.0))
        p = optimal_dp(chain, f * peak)
        keep = p.keep if p is not None else frozenset(range(len(chain)))
        plan = compile_plan(view, chain, keep)
        ev = evaluate_plan(view, plan)
        rr = execute_plan(log, plan)
        _assert_parity(rr, ev)


# ---------------------------------------------------------------------------
# 4. fig3 regression: error propagation + honest Chen-√n feasibility
# ---------------------------------------------------------------------------

class TestFig3Regression:
    def test_programming_errors_propagate(self, monkeypatch):
        # The old handler caught bare Exception, so a typo'd heuristic or
        # a broken runtime silently became "infeasible" rows.
        from benchmarks import fig3_static

        def boom(log, rt):
            raise ValueError("not an OOM")

        monkeypatch.setattr(fig3_static, "replay", boom)
        with pytest.raises(ValueError):
            fig3_static.run(ns=(8,), budget_fracs=(0.5,))

    def test_chen_sqrt_feasibility_reported_honestly(self):
        from benchmarks import fig3_static
        rows = fig3_static.run(ns=(16,), budget_fracs=(0.5,))
        budget = max(int(16 * 0.5), 6)
        _, sqrt_peak = baselines.chen_sqrt(16)
        srows = [r for r in rows if r["planner"] == "chen_sqrt"]
        assert srows and all(r["ok"] == (sqrt_peak <= budget)
                             for r in srows)
        assert not srows[0]["ok"]       # ⌈√16⌉ schedule needs 10 > 8 slots
        # while the budget-aware planners at the same cell stay honest too
        for r in rows:
            if r["planner"] == "chen_greedy":
                _, p = baselines.chen_greedy(16, budget)
                assert r["ok"] == (p <= budget)
