"""Tests for the trace-time DTR planner (jaxpr -> plan -> checkpoint policy)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.ad_checkpoint import checkpoint_name

from repro.core import planner


D = 64
L = 6


def make_params(key):
    ks = jax.random.split(key, L)
    return [dict(w1=jax.random.normal(k, (D, 4 * D)) * 0.02,
                 w2=jax.random.normal(k, (4 * D, D)) * 0.02) for k in ks]


def mlp_fwd(params, x):
    h = x
    for i, p in enumerate(params):
        a = checkpoint_name(jax.nn.gelu(h @ p["w1"]), f"act{i}")
        h = h + checkpoint_name(a @ p["w2"], f"proj{i}")
    return h


def loss_fn(params, x):
    return jnp.mean(mlp_fwd(params, x) ** 2)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = make_params(key)
    # Large batch => activation-dominated graph (realistic training regime).
    x = jax.random.normal(key, (512, D))
    return params, x


def test_trace_to_log_shapes(setup):
    params, x = setup
    tg = planner.trace_to_log(jax.grad(loss_fn), params, x)
    assert tg.log.op_count() > 10
    assert len(tg.named) == 2 * L
    assert tg.total_flops > 0


def test_plan_budget_monotonicity(setup):
    """Lower budgets must evict more named tensors."""
    params, x = setup
    g = jax.grad(loss_fn)
    big = planner.plan(g, params, x, budget_bytes=1e12)
    assert big.feasible and not big.remat_names
    tg = planner.trace_to_log(g, params, x)
    peak = 0  # measure actual sim peak via unconstrained plan
    from repro.core import simulator
    peak, _ = simulator.measure_baseline(tg.log)
    mid = planner.plan(g, params, x, budget_bytes=0.6 * peak)
    low = planner.plan(g, params, x, budget_bytes=0.45 * peak)
    assert mid.feasible
    assert low.feasible
    assert len(low.save_names) <= len(mid.save_names) <= len(big.save_names)
    assert len(low.remat_names) > 0, "tight budget must force remat"
    assert low.est_slowdown >= 1.0


def test_policy_preserves_gradients(setup):
    """jax.checkpoint with the DTR policy must not change numerics."""
    params, x = setup
    g = jax.grad(loss_fn)
    tg = planner.trace_to_log(g, params, x)
    from repro.core import simulator
    peak, _ = simulator.measure_baseline(tg.log)
    p = planner.plan(g, params, x, budget_bytes=0.5 * peak)
    ck_fwd = jax.checkpoint(mlp_fwd, policy=p.policy())

    def ck_loss(params, x):
        return jnp.mean(ck_fwd(params, x) ** 2)

    g_ref = jax.jit(jax.grad(loss_fn))(params, x)
    g_ck = jax.jit(jax.grad(ck_loss))(params, x)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_ck)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


def test_policy_actually_remats(setup):
    """A tight policy should increase compiled FLOPs (recompute visible)."""
    params, x = setup

    def loss_plain(params, x):
        return jnp.mean(mlp_fwd(params, x) ** 2)

    def compiled_flops(policy):
        fwd = jax.checkpoint(mlp_fwd, policy=policy)

        def loss(params, x):
            return jnp.mean(fwd(params, x) ** 2)

        c = jax.jit(jax.grad(loss)).lower(params, x).compile()
        from repro.analysis.hlo import xla_cost_dict
        fa = xla_cost_dict(c.cost_analysis())
        return fa.get("flops", 0.0)

    f_save = compiled_flops(jax.checkpoint_policies.everything_saveable)
    f_none = compiled_flops(jax.checkpoint_policies.nothing_saveable)
    assert f_none > f_save * 1.2, (f_save, f_none)


def test_dtr_checkpoint_end_to_end(setup):
    params, x = setup
    ck, p = planner.dtr_checkpoint(
        lambda pp, xx: mlp_fwd(pp, xx), params, x, budget_bytes=2e5)
    out = jax.jit(ck)(params, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()


def test_block_size_planner():
    assert planner.sqrt_block_size(16) == 4
    assert planner.plan_layer_blocks(32, 100.0, 400.0) == 8
    assert planner.plan_layer_blocks(32, 100.0, 1e9) == 1
    assert planner.plan_layer_blocks(32, 100.0, 0.0) == 1


def test_autotune_picks_feasible_budget(setup):
    from repro.core.autotune import autotune
    params, x = setup
    g = jax.grad(loss_fn)
    tuned = autotune(g, params, x, fracs=(0.9, 0.6, 0.45))
    assert tuned.plan.feasible
    assert tuned.est_step_s > 0
    assert 0.4 < tuned.budget_frac <= 0.9
