"""Tests for ``repro.check``: trace verifier, shadow sanitizer, repo lint.

Four layers:

1. **Trace lint** — hand-built malformed logs trigger every error code the
   static verifier defines; the whole golden corpus lints clean under both
   ``eager`` and ``banish``; ``run_trace`` refuses malformed logs *before*
   any replay runs.
2. **Sanitizer transparency** — a sanitized replay is bit-exact with an
   unsanitized one (parity counters and victim sequences), and the golden
   corpus replays sanitized with zero violations (no false positives).
3. **Seeded mutations** — deliberately corrupted runtime state (double
   free, evict-pinned, index desync, broken union-find root sum, illegal
   offload transitions, byte-counter drift, ...) raises a structured
   :class:`SanitizerViolation` with the expected ``.code``.
4. **Repo lint rules + satellite regressions** — each AST rule fires on a
   minimal snippet and respects the suppression comment; the tightened
   ``except`` blocks in ``trace.capture`` / ``core.planner`` now propagate
   unexpected errors; the ``offload.engine.drop`` write goes through the
   ``StorageRec`` notification hook.
"""
import json
import os

import pytest

from repro.check import (SanitizerViolation, TraceLintError, lint_paths,
                         lint_source)
from repro.check.sanitizer import ShadowSanitizer
from repro.check.trace_lint import check_log, lint_log, verify_log
from repro.core import graphs
from repro.core.graph import (Alias, Call, Constant, Log, LogBuilder, Memory,
                              Mutate, Release)
from repro.core.heuristics import by_name
from repro.core.runtime import DTRRuntime, StorageRec
from repro.core.simulator import measure_baseline, resolve_budget
from repro.offload import OffloadConfig, OffloadEngine, wrap_heuristic
from repro.trace.replay import PARITY_FIELDS, run_trace

TRACE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "traces")
TRACES = ["serve_smoke_s2", "serve_smoke_s4", "train_smoke", "eager_mlp",
          "treelstm", "random_dag"]


def load_trace(name: str) -> Log:
    with open(os.path.join(TRACE_DIR, f"{name}.log")) as f:
        return Log.loads(f.read())


def errors_of(log: Log, dealloc: str = "eager") -> set[str]:
    return {i.code for i in lint_log(log, dealloc=dealloc)
            if i.severity == "error"}


# ---------------------------------------------------------------------------
# 1. Trace lint
# ---------------------------------------------------------------------------

class TestTraceLint:
    def test_clean_synthetic_log(self):
        log = graphs.mlp(depth=6, width=8, batch=4)
        for dealloc in ("eager", "banish"):
            assert not errors_of(log, dealloc)

    def test_use_after_release(self):
        b = LogBuilder("bad")
        c = b.constant(4)
        (x,) = b.call([c], [8], 1.0, "f")
        b.release(x)
        b.call([x], [8], 1.0, "g")          # x's refcount already zero
        assert "use-after-release" in errors_of(b.log)

    def test_use_after_banish(self):
        b = LogBuilder("bad")
        c = b.constant(4)
        (x,) = b.call([c], [8], 1.0, "f")
        b.release(x)
        b.call([x], [8], 1.0, "g")
        codes = errors_of(b.log, dealloc="banish")
        assert "use-after-banish" in codes
        assert "use-after-release" not in codes

    def test_undefined_tensor(self):
        b = LogBuilder("bad")
        b.call(["ghost"], [8], 1.0, "f")
        assert "undefined-tensor" in errors_of(b.log)

    def test_release_underflow(self):
        b = LogBuilder("bad")
        c = b.constant(4)
        b.release(c)
        b.release(c)
        assert "release-underflow" in errors_of(b.log)

    def test_malformed_call_block(self):
        # CALL whose MEMORY/ALIAS block is missing entirely.
        log = Log([Constant("c"), Memory("c", 4),
                   Call(("c",), ("x",), 1.0, "f")], name="bad")
        assert "malformed-call-block" in errors_of(log)

    def test_malformed_constant(self):
        log = Log([Constant("c"),
                   Call((), ("x",), 1.0, "f"),
                   Memory("x", 4), Alias("x", None)], name="bad")
        assert "malformed-constant" in errors_of(log)

    def test_alias_with_nonzero_size(self):
        log = Log([Constant("c"), Memory("c", 4),
                   Call(("c",), ("x",), 1.0, "f"),
                   Memory("x", 16), Alias("x", "c")], name="bad")
        assert "alias-size" in errors_of(log)

    def test_mutate_target_not_input(self):
        b = LogBuilder("bad")
        c = b.constant(4)
        (x,) = b.call([c], [8], 1.0, "f")
        b.log.instrs.append(Mutate((c,), (x,), 1.0, "mut"))
        assert "mutate-not-input" in errors_of(b.log)

    def test_nan_cost_rejected(self):
        b = LogBuilder("bad")
        c = b.constant(4)
        b.call([c], [8], float("nan"), "f")
        assert "bad-cost" in errors_of(b.log)

    def test_negative_size_rejected(self):
        log = Log([Constant("c"), Memory("c", -4)], name="bad")
        assert "bad-size" in errors_of(log)

    def test_stray_metadata_warns(self):
        log = Log([Constant("c"), Memory("c", 4), Memory("c", 4)],
                  name="odd")
        issues = lint_log(log)
        assert any(i.code == "stray-metadata" and i.severity == "warning"
                   for i in issues)
        assert not errors_of(log)

    def test_banish_pinning_shields_children(self):
        # y is x's child when x banishes, so the banish path pins y: an
        # evicted y needs no recompute.  Well-formed logs stay clean.
        b = LogBuilder("ok")
        c = b.constant(4)
        (x,) = b.call([c], [8], 1.0, "f")
        (y,) = b.call([x], [8], 1.0, "g")
        b.release(x)
        b.call([y, y], [8], 1.0, "h")
        issues = lint_log(b.log, dealloc="banish")
        assert all(i.severity != "error" for i in issues)

    def test_unreachable_recompute_under_banish(self):
        # A hand-edited log that defines y *from* an already-banished x:
        # y's recompute closure crosses the banished storage with no
        # pinned shield, so an evicted y could never be rematerialized.
        b = LogBuilder("bad")
        c = b.constant(4)
        (x,) = b.call([c], [8], 1.0, "f")
        b.release(x)                        # refcount 0 => banished
        (y,) = b.call([x], [8], 1.0, "g")   # use-after-banish ...
        b.call([y], [8], 1.0, "h")          # ... and y is unrecomputable
        codes = errors_of(b.log, dealloc="banish")
        assert "use-after-banish" in codes
        assert "unreachable-recompute" in codes
        # The same log replayed under "eager" never banishes: the second
        # error degrades to plain use-after-release and y stays safe.
        eager = errors_of(b.log, dealloc="eager")
        assert "unreachable-recompute" not in eager

    def test_verify_log_raises_with_issues(self):
        b = LogBuilder("bad")
        b.call(["ghost"], [8], 1.0, "f")
        with pytest.raises(TraceLintError) as ei:
            verify_log(b.log)
        assert any(i.code == "undefined-tensor" for i in ei.value.issues)
        assert "bad" in str(ei.value)

    def test_check_log_memoizes_verdict(self):
        log = graphs.mlp(depth=4, width=8, batch=4)
        check_log(log)
        assert log._lint_verdict["eager"] is True
        b = LogBuilder("bad")
        b.call(["ghost"], [8], 1.0, "f")
        with pytest.raises(TraceLintError) as first:
            check_log(b.log)
        with pytest.raises(TraceLintError) as second:
            check_log(b.log)
        assert second.value is first.value      # cached exception object

    def test_run_trace_lints_before_replay(self):
        b = LogBuilder("bad")
        c = b.constant(4)
        (x,) = b.call([c], [8], 1.0, "f")
        b.release(x)
        b.call([x], [8], 1.0, "g")
        with pytest.raises(TraceLintError):
            run_trace(b.log, "h_dtr", budget=1e9)
        # Opt-out for callers that replay known-odd logs deliberately.
        res, _ = run_trace(b.log, "h_dtr", budget=1e9, lint=False)
        assert res.ok

    @pytest.mark.parametrize("name", TRACES)
    def test_golden_corpus_lints_clean(self, name):
        log = load_trace(name)
        for dealloc in ("eager", "banish"):
            issues = lint_log(log, dealloc=dealloc)
            assert not [i for i in issues if i.severity == "error"], \
                [str(i) for i in issues]


# ---------------------------------------------------------------------------
# 2. Sanitizer transparency (no false positives, bit-exactness)
# ---------------------------------------------------------------------------

class TestSanitizerTransparency:
    @pytest.mark.parametrize("name", TRACES)
    def test_golden_corpus_sanitized_replay_is_clean_and_bit_exact(
            self, name):
        log = load_trace(name)
        peak, _ = measure_baseline(log)
        frac = 0.9 if name == "train_smoke" else 0.7
        budget = resolve_budget(frac, peak, log.pinned_bytes(), "activation")
        plain, v_plain = run_trace(log, "h_dtr_eq", budget, thrash_factor=3.0)
        san, v_san = run_trace(log, "h_dtr_eq", budget, thrash_factor=3.0,
                               sanitize=True)
        assert v_plain == v_san
        for f in PARITY_FIELDS:
            assert getattr(plain, f) == getattr(san, f), f

    def test_sanitized_offload_replay_is_clean(self):
        log = graphs.mlp(depth=12, width=32, batch=8)
        peak, _ = measure_baseline(log)
        cfg = OffloadConfig(host_budget=0.5 * peak, h2d_bandwidth=peak,
                            d2h_bandwidth=peak)
        budget = resolve_budget(0.5, peak, log.pinned_bytes(), "activation")
        res, _ = run_trace(log, "h_dtr", budget, thrash_factor=10.0,
                           offload=cfg, sanitize=True)
        assert res.error_kind != "violation"

    @pytest.mark.parametrize("alloc_mode", ["pool", "pool_nofrag"])
    def test_sanitized_pool_replay_is_clean_and_bit_exact(self, alloc_mode):
        from repro.core.simulator import simulate
        log = graphs.mlp(depth=10, width=16, batch=8)
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.7, peak, log.pinned_bytes(), "activation")
        plain = simulate(log, "h_dtr", budget, thrash_factor=10.0,
                         alloc_mode=alloc_mode)
        san = simulate(log, "h_dtr", budget, thrash_factor=10.0,
                       alloc_mode=alloc_mode, sanitize=True)
        for f in PARITY_FIELDS:
            assert getattr(plain, f) == getattr(san, f), f

    def test_audit_cadence(self):
        log = graphs.mlp(depth=8, width=16, batch=4)
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.7, peak, log.pinned_bytes(), "activation")
        run_trace(log, "h_dtr", budget, sanitize=True)
        # sanitize=N audits every N ops; transition hooks stay on.
        _, _ = run_trace(log, "h_dtr", budget, sanitize=1000)


# ---------------------------------------------------------------------------
# 3. Seeded mutations: every corruption is detected
# ---------------------------------------------------------------------------

def _sanitized_runtime(heuristic="h_dtr_eq", offload=False, budget=1e9):
    """Small live runtime: constant + chain, one evicted storage."""
    eng = None
    h = by_name(heuristic)
    if offload:
        eng = OffloadEngine(OffloadConfig(host_budget=1000.0,
                                          prefetch=False))
        h = wrap_heuristic(by_name("h_dtr_local"), eng)
    rt = DTRRuntime(budget=budget, heuristic=h, offload=eng, sanitize=True)
    c = rt.constant(10)
    (a,) = rt.call("a", 1.0, [c], [40])
    (bb,) = rt.call("b", 2.0, [a], [40])
    (d,) = rt.call("d", 4.0, [bb], [40])
    return rt, (c, a, bb, d)


class TestSeededMutations:
    """Each test corrupts one invariant and expects its violation code."""

    def _storage(self, rt, tid):
        return rt.storages[rt.tensors[tid].sid]

    def test_double_free(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        s = self._storage(rt, a)
        rt._evict(s)
        with pytest.raises(SanitizerViolation) as ei:
            rt._evict(s)                     # second evict = double free
        assert ei.value.code == "evict-nonresident"

    def test_evict_pinned(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        s = self._storage(rt, a)
        s.pinned = True
        with pytest.raises(SanitizerViolation) as ei:
            rt._evict(s)
        assert ei.value.code == "evict-pinned"

    def test_evict_constant(self):
        rt, (c, _, _, _) = _sanitized_runtime()
        s = self._storage(rt, c)
        with pytest.raises(SanitizerViolation) as ei:
            rt._evict(s)
        assert ei.value.code in ("evict-constant", "evict-pinned")

    def test_evict_locked(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        s = self._storage(rt, a)
        s.locks += 1
        with pytest.raises(SanitizerViolation) as ei:
            rt._evict(s)
        assert ei.value.code == "evict-locked"

    def test_index_desync(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        sid = rt.tensors[a].sid
        rt.index.members.discard(sid)         # index forgets a candidate
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "index-desync"
        assert sid in ei.value.state["missing"]

    def test_broken_uf_root_sum(self):
        rt, (_, a, _, _) = _sanitized_runtime("h_dtr_eq")
        s = self._storage(rt, a)
        rt._evict(s)                          # joins the evicted component
        assert s.uf_joined
        rt.uf._cost[rt.uf.find(s.uf)] += 5.0  # corrupt the cached sum
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "uf-root-sum"

    def test_byte_counter_drift(self):
        rt, _ = _sanitized_runtime()
        rt.memory += 7.0                      # phantom bytes
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "byte-conservation"

    def test_peak_below_memory(self):
        rt, _ = _sanitized_runtime()
        rt.peak_memory = rt.memory - 1.0
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "peak-below-memory"

    def test_refs_desync(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        self._storage(rt, a).refs += 1        # cached sum drifts from views
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "refs-desync"

    def test_dead_with_live_refs(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        self._storage(rt, a).dead = True
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "dead-live"

    def test_defined_view_on_evicted_storage(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        s = self._storage(rt, a)
        rt._evict(s)
        rt.tensors[a].defined = True          # lies about materialization
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "defined-nonresident"

    def test_illegal_offload_double(self):
        rt, (_, a, _, _) = _sanitized_runtime(offload=True)
        s = self._storage(rt, a)
        rt._offload(s)
        s.resident = True                     # fake a re-materialization
        with pytest.raises(SanitizerViolation) as ei:
            rt._offload(s)
        assert ei.value.code == "offload-already"

    def test_illegal_fetch_of_non_offloaded(self):
        rt, (_, a, _, _) = _sanitized_runtime(offload=True)
        s = self._storage(rt, a)
        with pytest.raises(SanitizerViolation) as ei:
            rt._fetch_in(s)
        assert ei.value.code == "fetch-not-offloaded"

    def test_resident_and_offloaded(self):
        rt, (_, a, _, _) = _sanitized_runtime(offload=True)
        s = self._storage(rt, a)
        rt._offload(s)
        s.resident = True                     # both tiers at once
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "resident-and-offloaded"

    def test_host_tier_desync(self):
        rt, (_, a, bb, _) = _sanitized_runtime(offload=True)
        s = self._storage(rt, bb)
        rt._evict(s)
        s.offloaded = True                    # flag without engine record
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "host-desync"

    def test_pool_desync(self):
        from repro.alloc import PoolAllocator
        h = by_name("h_dtr")
        rt = DTRRuntime(budget=1e9, heuristic=h, sanitize=True,
                        allocator=PoolAllocator(contiguous=True))
        c = rt.constant(10)
        (a,) = rt.call("a", 1.0, [c], [40])
        rt.sanitizer.audit()                  # consistent so far
        rt.allocator.pool.free(rt.tensors[a].sid)   # behind the runtime
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.audit()
        assert ei.value.code == "pool-desync"

    def test_compaction_must_conserve_free_bytes(self):
        rt, _ = _sanitized_runtime()

        class _Stats:
            def __init__(self, free, largest):
                self.free, self.largest_free = free, largest

            def as_dict(self):
                return {"free": self.free, "largest_free": self.largest_free}

        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.note_compaction(_Stats(100.0, 50.0),
                                         _Stats(90.0, 90.0))
        assert ei.value.code == "compaction-leak"
        with pytest.raises(SanitizerViolation) as ei:
            rt.sanitizer.note_compaction(_Stats(100.0, 50.0),
                                         _Stats(100.0, 40.0))
        assert ei.value.code == "compaction-fragmented"

    def test_violation_carries_state_dump(self):
        rt, (_, a, _, _) = _sanitized_runtime()
        s = self._storage(rt, a)
        s.pinned = True
        with pytest.raises(SanitizerViolation) as ei:
            rt._evict(s)
        e = ei.value
        assert e.state["sid"] == s.sid and e.state["pinned"] is True
        assert "clock" in e.state and "[evict-pinned]" in str(e)


# ---------------------------------------------------------------------------
# 4a. Repo lint rules
# ---------------------------------------------------------------------------

class TestRepoLint:
    def _rules(self, src, path="pkg/mod.py"):
        return [f.rule for f in lint_source(src, path)]

    def test_setattr_bypass_flagged(self):
        src = "object.__setattr__(s, 'resident', False)\n"
        assert self._rules(src) == ["setattr-bypass"]

    def test_setattr_on_self_allowed(self):
        src = ("class A:\n"
               "    def __setattr__(self, k, v):\n"
               "        object.__setattr__(self, k, v)\n")
        assert self._rules(src) == []

    def test_setattr_allowed_in_runtime_module(self):
        src = "object.__setattr__(s, 'resident', False)\n"
        assert self._rules(src, "src/repro/core/runtime.py") == []

    def test_strict_json_flagged(self):
        assert self._rules("json.dump(x, f)\n") == ["strict-json"]
        assert self._rules("json.dumps(x, allow_nan=True)\n") == \
            ["strict-json"]
        assert self._rules("json.dump(x, f, allow_nan=False)\n") == []

    def test_swallowed_exception_flagged(self):
        src = ("try:\n    f()\nexcept Exception:\n    pass\n")
        assert self._rules(src) == ["swallowed-exception"]
        src = ("try:\n    f()\nexcept:\n    pass\n")
        assert self._rules(src) == ["swallowed-exception"]

    def test_narrow_or_reraising_handlers_allowed(self):
        assert self._rules(
            "try:\n    f()\nexcept ValueError:\n    pass\n") == []
        assert self._rules(
            "try:\n    f()\nexcept Exception as e:\n    log(e)\n") == []
        assert self._rules(
            "try:\n    f()\nexcept Exception:\n    raise\n") == []

    def test_key_purity_flagged(self):
        src = ("class H(Heuristic):\n"
               "    separable = True\n"
               "    def key(self, rt, s):\n"
               "        return s.last_access / s.size\n")
        assert self._rules(src) == ["key-purity"]
        src = ("class H(Heuristic):\n"
               "    separable = True\n"
               "    def key(self, rt, s):\n"
               "        return rt.clock * s.size\n")
        assert self._rules(src) == ["key-purity"]

    def test_key_purity_allows_subscribed_fields(self):
        src = ("class H(Heuristic):\n"
               "    separable = True\n"
               "    def key(self, rt, s):\n"
               "        return (s.local_cost + s.dead_cost) / s.size\n")
        assert self._rules(src) == []
        # Non-separable heuristics may read anything.
        src = ("class H(Heuristic):\n"
               "    separable = False\n"
               "    def key(self, rt, s):\n"
               "        return s.last_access\n")
        assert self._rules(src) == []

    def test_suppression_comment(self):
        src = "json.dump(x, f)  # repro-lint: allow[strict-json]\n"
        assert self._rules(src) == []
        src = ("# repro-lint: allow[strict-json]\n"
               "json.dump(x, f)\n")
        assert self._rules(src) == []
        # A suppression names its rule; others still fire.
        src = "json.dump(x, f)  # repro-lint: allow[setattr-bypass]\n"
        assert self._rules(src) == ["strict-json"]

    def test_syntax_error_reported_not_raised(self):
        assert self._rules("def f(:\n") == ["syntax-error"]

    def test_repo_is_lint_clean(self):
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        findings = lint_paths([os.path.join(root, "src"),
                               os.path.join(root, "benchmarks")])
        assert findings == [], [str(f) for f in findings]

    def test_cli_lint_exit_codes(self, tmp_path):
        from repro.check.__main__ import main
        bad = tmp_path / "bad.py"
        bad.write_text("json.dump(x, f)\n")
        assert main(["--lint", str(bad)]) == 1
        good = tmp_path / "good.py"
        good.write_text("json.dump(x, f, allow_nan=False)\n")
        assert main(["--lint", str(good)]) == 0


# ---------------------------------------------------------------------------
# 4b. Satellite regressions
# ---------------------------------------------------------------------------

class TestExceptTighteningRegressions:
    """Unexpected errors now propagate (PR 8 `fig3_static.py` bug class)."""

    def _capture(self, monkeypatch, exc):
        import jax.numpy as jnp
        from repro.analysis import hlo_cost
        from repro.trace.capture import capture_jaxpr

        def boom(_):
            raise exc

        monkeypatch.setattr(hlo_cost, "analyze", boom)
        x = jnp.ones((4, 4), dtype=jnp.float32)
        return capture_jaxpr(lambda v: v * 2.0 + 1.0, x, name="tiny",
                             cost_model="hlo")

    def test_capture_falls_back_on_expected_errors(self, monkeypatch):
        log = self._capture(monkeypatch, RuntimeError("no backend"))
        assert log.meta["cost_model"] == "flops"

    def test_capture_propagates_unexpected_errors(self, monkeypatch):
        with pytest.raises(KeyError):
            self._capture(monkeypatch, KeyError("hlo parser bug"))

    def test_aval_bytes_tolerates_abstract_tokens(self):
        from repro.core.planner import _aval_bytes, _aval_elems

        class Token:                        # no shape/dtype at all
            pass

        class BadDtype:
            shape = (2, 2)
            dtype = object()                # jnp.dtype -> TypeError

        assert _aval_bytes(Token()) == 0
        assert _aval_elems(Token()) == 0
        assert _aval_bytes(BadDtype()) == 0

    def test_aval_bytes_propagates_real_bugs(self):
        from repro.core.planner import _aval_bytes

        class Exploding:
            @property
            def shape(self):
                raise ValueError("corrupted aval")

        with pytest.raises(ValueError):
            _aval_bytes(Exploding())


class TestOffloadDropNotification:
    """`engine.drop` writes `offloaded` through the notification hook."""

    def test_unwatched_write_does_not_ping_index(self):
        events = []

        class _Index:
            def on_storage_event(self, s, name):
                events.append(name)

        s = StorageRec(sid=0, size=8, root_tid=0)
        s._index = _Index()
        s.offloaded = True                   # not in _WATCHED: silent
        assert events == []
        s.resident = False                   # watched: must notify
        assert events == ["resident"]

    def test_drop_leaves_index_and_flags_consistent(self):
        # Offload a storage, then kill it (refs drop to zero with dead
        # children) so engine.drop runs with a subscribed index; the
        # sanitizer audit proves index parity and host-tier agreement.
        rt, (c, a, bb, d) = _sanitized_runtime(offload=True)
        s = rt.storages[rt.tensors[a].sid]
        rt._offload(s)
        assert s.offloaded and rt.offload.holds(s.sid)
        for tid in (d, bb, a):               # leaf-first: children die first
            rt.release(tid)
        assert not s.offloaded and not rt.offload.holds(s.sid)
        rt.sanitizer.audit()                 # no violation
        rt.finalize()

    def test_engine_module_has_no_setattr_bypass(self):
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(root, "src", "repro", "offload", "engine.py")
        with open(path) as f:
            findings = lint_source(f.read(), path)
        assert [f for f in findings if f.rule == "setattr-bypass"] == []


class TestStrictReportWriters:
    """Every committed report writer passes allow_nan=False (PR 6 regime)."""

    def test_perf_runtime_writer_is_strict(self, tmp_path):
        # The satellite fix: perf_runtime's json.dump must reject NaN.
        bad = {"rows": [float("nan")]}
        with pytest.raises(ValueError):
            json.dumps(bad, allow_nan=False)
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        src = open(os.path.join(root, "benchmarks",
                                "perf_runtime.py")).read()
        findings = lint_source(src, "benchmarks/perf_runtime.py")
        assert [f for f in findings if f.rule == "strict-json"] == []
        assert "allow_nan=False" in src
