"""Regenerate the golden-trace corpus + expected replay results.

Run from the repo root after an *intentional* capture-format or engine
change, then review the diff before committing:

    PYTHONPATH=src python tests/traces/make_golden.py

Traces come from three capture sources (see README "Tracing real
workloads"): the continuous-batching serve driver at two slot widths, a
jaxpr-captured train step, the eager executor (MLP training loop), and two
synthetic families (treelstm, random_dag).  ``expected.json`` pins, for a
small heuristic × budget grid per trace, the full victim sequence digest and
the replay counters — any engine change that alters a single eviction
decision shows up as a diff here.
"""
import hashlib
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))

from repro.core import graphs  # noqa: E402
from repro.core.simulator import measure_baseline, resolve_budget  # noqa: E402
from repro.trace import (capture_eager_mlp, capture_serve_trace,  # noqa: E402
                         capture_train_step, run_trace,
                         step_model_from_config)

EXPECT_GRID = [("h_dtr", 0.8), ("h_dtr_eq", 0.8), ("h_lru", 0.8),
               ("h_msps", 0.5), ("h_size", 0.5), ("h_dtr_local", 0.5)]
THRASH = 3.0   # golden replays abort fast; thrash cells are still asserted


def build_traces():
    model = step_model_from_config("qwen2-0.5b", smoke=True)
    return {
        "serve_smoke_s2": capture_serve_trace(
            model, slots=2, requests=6, gen=8, seed=0,
            name="serve_smoke_s2"),
        "serve_smoke_s4": capture_serve_trace(
            model, slots=4, requests=10, gen=8, seed=0,
            name="serve_smoke_s4"),
        "train_smoke": capture_train_step(
            "qwen2-0.5b", smoke=True, batch=2, seq=16, cost_model="flops"),
        "eager_mlp": capture_eager_mlp(),
        "treelstm": graphs.treelstm(depth=4, width=32, seed=0),
        "random_dag": graphs.random_dag(150, seed=0),
    }


def expected_for(log):
    peak, _ = measure_baseline(log)
    pinned = log.pinned_bytes()
    cells = {}
    for h, f in EXPECT_GRID:
        budget = resolve_budget(f, peak, pinned, "activation")
        res, victims = run_trace(log, h, budget, index=True,
                                 thrash_factor=THRASH)
        cells[f"{h}@{f}"] = {
            "ok": res.ok,
            "evictions": res.evictions,
            "remat_ops": res.remat_ops,
            "ops_executed": res.ops_executed,
            "compute": repr(res.compute),
            "peak_memory": repr(res.peak_memory),
            "victims_sha1": hashlib.sha1(
                ",".join(map(str, victims)).encode()).hexdigest(),
            "n_victims": len(victims),
        }
    return {"baseline_peak": repr(peak), "pinned": pinned, "cells": cells}


def main():
    expected = {}
    for name, log in sorted(build_traces().items()):
        path = os.path.join(HERE, f"{name}.log")
        with open(path, "w") as f:
            f.write(log.dumps() + "\n")
        expected[name] = expected_for(log)
        print(f"{name}: {log.op_count()} ops -> {path}")
    with open(os.path.join(HERE, "expected.json"), "w") as f:
        json.dump(expected, f, indent=1, sort_keys=True)
    print(f"expected.json: {len(expected)} traces x {len(EXPECT_GRID)} cells")


if __name__ == "__main__":
    sys.exit(main())
