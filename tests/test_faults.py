"""Tests for ``repro.faults``: chaos schedules, the recovery ladder, the
serve admission controller, and the robustness satellites (partial-progress
results, worker-death sweeps, simultaneous tier exhaustion).

The differential invariants:

* a disabled/absent schedule is **bit-exact** with the pre-faults engine
  (victims, counters, no events);
* a pinned schedule is **deterministic**: identical victims and event
  streams across runs and across the scan/index engines;
* alloc faults alone can never kill a run (the ladder absorbs them);
* a recovered *eager* run computes the same numerics as a fault-free one.
"""
import multiprocessing
import os

import pytest

from repro.core import graphs
from repro.core.simulator import (RunResult, measure_baseline,
                                  resolve_budget, simulate, sweep_parallel)
from repro.faults import FaultConfig, FaultSchedule, RecoveryConfig
from repro.launch.admission import (ADMIT, REJECT, WAIT,
                                    AdmissionController, Ticket)
from repro.offload import OffloadConfig
from repro.trace.replay import PARITY_FIELDS, run_to_dict, run_trace

from tests.test_trace_golden import load_trace


# ---------------------------------------------------------------------------
# Schedule determinism
# ---------------------------------------------------------------------------

class TestSchedule:
    CFG = FaultConfig(seed=7, alloc_rate=0.3, transfer_rate=0.3,
                      spike_rate=0.2, prefetch_rate=0.4, cost_noise=0.2,
                      budget_shrink=0.4, budget_period=16)

    def test_draws_are_pure_functions_of_seed_kind_index(self):
        a, b = FaultSchedule(self.CFG), FaultSchedule(self.CFG)
        assert ([a.alloc_fault() for _ in range(64)]
                == [b.alloc_fault() for _ in range(64)])
        assert ([a.prefetch_lost() for _ in range(64)]
                == [b.prefetch_lost() for _ in range(64)])
        assert ([a.transfer_plan("h2d", 100, 1.0) for _ in range(32)]
                == [b.transfer_plan("h2d", 100, 1.0) for _ in range(32)])

    def test_kinds_do_not_interleave(self):
        # Drawing kind B between draws of kind A must not shift A's
        # stream: per-kind counters, not a shared RNG.
        a, b = FaultSchedule(self.CFG), FaultSchedule(self.CFG)
        seq_a = [a.alloc_fault() for _ in range(32)]
        seq_b = []
        for _ in range(32):
            seq_b.append(b.alloc_fault())
            b.prefetch_lost()
            b.transfer_plan("d2h", 10, 1.0)
        assert seq_a == seq_b

    def test_channels_draw_independently(self):
        a, b = FaultSchedule(self.CFG), FaultSchedule(self.CFG)
        h2d = [a.transfer_plan("h2d", 10, 1.0) for _ in range(16)]
        for _ in range(16):
            b.transfer_plan("d2h", 10, 1.0)
        assert h2d == [b.transfer_plan("h2d", 10, 1.0) for _ in range(16)]

    def test_cost_factor_keyed_by_op_identity(self):
        s = FaultSchedule(self.CFG)
        f1 = s.cost_factor(3)
        s.cost_factor(11)
        assert s.cost_factor(3) == f1          # cached, consistent
        assert FaultSchedule(self.CFG).cost_factor(3) == f1
        assert s.cost_factor(4) != f1          # per-op, not global

    def test_transfer_retry_backoff_math(self):
        cfg = FaultConfig(seed=0, transfer_rate=1.0, spike_rate=1.0,
                          spike_mult=4.0, max_transfer_retries=3,
                          backoff_base=0.5, backoff_cap=1.0)
        extra, retries, mult = FaultSchedule(cfg).transfer_plan(
            "h2d", 100, 2.0)
        assert mult == 4.0
        assert retries == 3                    # rate 1.0 -> always the cap
        dur = 2.0 * 4.0
        want = (dur + 0.5 * dur) + (dur + 1.0 * dur) + (dur + 1.0 * dur)
        assert extra == pytest.approx(want)

    def test_budget_square_wave(self):
        cfg = FaultConfig(budget_shrink=0.4, budget_period=10,
                          budget_duty=0.3)
        s = FaultSchedule(cfg)
        assert all(s.budget_factor(i) == 1.0 for i in range(10))  # grace
        assert s.budget_factor(10) == pytest.approx(0.6)
        assert s.budget_factor(12) == pytest.approx(0.6)          # duty=3 ops
        assert s.budget_factor(13) == 1.0
        assert s.budget_factor(20) == pytest.approx(0.6)

    def test_disabled_config_refuses_schedule(self):
        assert not FaultConfig().enabled
        with pytest.raises(AssertionError):
            FaultSchedule(FaultConfig())


# ---------------------------------------------------------------------------
# Differential bit-exactness + pinned-schedule determinism
# ---------------------------------------------------------------------------

class TestDifferential:
    @pytest.mark.parametrize("name,frac", [("treelstm", 0.8),
                                           ("random_dag", 0.5),
                                           ("eager_mlp", 0.8)])
    def test_zero_rate_is_bit_exact(self, name, frac):
        log = load_trace(name)
        peak, _ = measure_baseline(log)
        budget = resolve_budget(frac, peak, log.pinned_bytes(),
                                "activation")
        plain, vic_p = run_trace(log, "h_dtr_eq", budget, thrash_factor=3.0)
        zero, vic_z = run_trace(log, "h_dtr_eq", budget, thrash_factor=3.0,
                                faults=FaultConfig(seed=9))
        assert vic_p == vic_z
        for f in PARITY_FIELDS:
            assert getattr(plain, f) == getattr(zero, f), f
        assert zero.degradations == 0 and zero.events == []

    def test_pinned_schedule_deterministic_across_runs_and_engines(self):
        log = load_trace("treelstm")
        peak, cost = measure_baseline(log)
        pinned = log.pinned_bytes()
        budget = resolve_budget(0.6, peak, pinned, "activation")
        bw = 2 * peak / cost
        off = OffloadConfig(host_budget=peak - pinned, h2d_bandwidth=bw,
                            d2h_bandwidth=bw)
        cfg = FaultConfig(seed=11, alloc_rate=0.05, transfer_rate=0.05,
                          spike_rate=0.05, prefetch_rate=0.2,
                          cost_noise=0.05, budget_shrink=0.3,
                          budget_period=64)
        runs = [run_trace(log, "h_dtr_eq", budget, thrash_factor=10.0,
                          offload=off, faults=cfg,
                          recovery=RecoveryConfig(), index=idx)
                for idx in (True, True, False)]
        (r1, v1), (r2, v2), (r3, v3) = runs
        assert v1 == v2 == v3
        assert r1.events == r2.events == r3.events
        for f in PARITY_FIELDS:
            assert getattr(r1, f) == getattr(r3, f), f

    def test_event_schema(self):
        log = load_trace("random_dag")
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.5, peak, log.pinned_bytes(), "activation")
        r = simulate(log, "h_dtr_eq", budget, thrash_factor=10.0,
                     faults=FaultConfig(seed=2, alloc_rate=0.2))
        assert r.degradations > 0
        for ev in r.events:
            assert {"kind", "op", "clock"} <= set(ev)


# ---------------------------------------------------------------------------
# Recovery ladder
# ---------------------------------------------------------------------------

class TestRecovery:
    def test_alloc_faults_alone_never_kill(self):
        # Even an absurd 50% admission-failure rate must be absorbed by
        # the headroom-eviction recovery: the fault is transient by
        # construction, so the retry always proceeds.
        log = load_trace("treelstm")
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.6, peak, log.pinned_bytes(), "activation")
        base = simulate(log, "h_dtr_eq", budget, thrash_factor=10.0)
        r = simulate(log, "h_dtr_eq", budget, thrash_factor=10.0,
                     faults=FaultConfig(seed=1, alloc_rate=0.5))
        assert base.ok and r.ok
        assert r.degradations > 0
        assert any(ev["kind"] == "alloc_fault" for ev in r.events)

    def test_alloc_fault_pool_mode_recovers_via_compaction(self):
        log = graphs.random_dag(80, seed=2)
        peak, _ = measure_baseline(log)
        r = simulate(log, "h_dtr", 0.6 * peak, thrash_factor=20.0,
                     alloc_mode="pool",
                     faults=FaultConfig(seed=4, alloc_rate=0.3))
        assert r.ok
        assert any(ev["kind"] == "alloc_fault" for ev in r.events)

    def test_budget_squeeze_emits_shrink_and_restore(self):
        log = graphs.linear_network(64)
        peak, _ = measure_baseline(log)
        r = simulate(log, "h_dtr", 0.8 * peak, thrash_factor=20.0,
                     faults=FaultConfig(budget_shrink=0.3,
                                        budget_period=16))
        assert r.ok
        shr = [ev for ev in r.events if ev["kind"] == "budget_shrink"]
        res = [ev for ev in r.events if ev["kind"] == "budget_restore"]
        assert shr and res
        assert all(ev["factor"] == pytest.approx(0.7) for ev in shr)
        assert all(ev["factor"] == 1.0 for ev in res)

    def test_thrash_guard_escalates_instead_of_dying(self):
        # h_lru grinds eager_mlp at thrash_factor 2 (golden corpus:
        # slowdown 2.7x); the guard must switch to h_dtr mid-run and
        # finish where the unguarded run hits the ThrashError cliff.
        log = load_trace("eager_mlp")
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.8, peak, log.pinned_bytes(), "activation")
        dead = run_trace(log, "h_lru", budget, thrash_factor=2.0)[0]
        assert not dead.ok and dead.error_kind == "thrash"
        rc = RecoveryConfig(thrash_window_ops=8, thrash_ratio=1.5,
                            escalation_chain=("h_dtr",))
        saved = run_trace(log, "h_lru", budget, thrash_factor=2.0,
                          recovery=rc)[0]
        assert saved.ok
        esc = [ev for ev in saved.events
               if ev["kind"] == "heuristic_escalation"]
        assert esc and esc[0]["reason"] == "thrash_guard"
        assert esc[0]["to"] == "h_dtr"

    def test_forced_offload_rung_bypasses_two_choice_key(self):
        # Unit test of the ladder rung itself: with a host tier attached
        # but priced out by the two-choice key (tiny bandwidth, so
        # ordinary pressure always evicts), the rung must still park the
        # minimum-transfer-key evictable storage on the host — freeing
        # device bytes without creating remat debt — and log the event.
        from repro.core.graph import replay
        from repro.core.heuristics import by_name
        from repro.core.runtime import DTRRuntime
        from repro.offload import OffloadEngine, wrap_heuristic
        log = graphs.linear_network(8)
        peak, cost = measure_baseline(log)
        bw = 0.01 * peak / cost          # transfers ~never win the key
        eng = OffloadEngine(OffloadConfig(host_budget=peak,
                                          h2d_bandwidth=bw,
                                          d2h_bandwidth=bw))
        h = wrap_heuristic(by_name("h_dtr", 0), eng)
        rt = DTRRuntime(budget=2 * peak, heuristic=h, offload=eng,
                        dealloc="ignore", recovery=RecoveryConfig())
        replay(log, rt)                  # generous budget: no pressure
        assert rt.offloads == 0
        pool = [s for s in rt.storages.values()
                if s.evictable() and s.size > 0]
        assert pool
        want = min(pool, key=lambda s: (eng.transfer_key(s), s.sid))
        assert rt._forced_offload(set())
        assert rt.offloads == 1 and rt.degradations == 1
        ev = [e for e in rt.events if e["kind"] == "forced_offload"]
        assert len(ev) == 1 and ev[0]["sid"] == want.sid
        assert not rt.storages[want.sid].resident
        # Excluding that victim forces the next-cheapest choice.
        assert rt._forced_offload({want.sid})
        ev2 = [e for e in rt.events if e["kind"] == "forced_offload"]
        assert ev2[-1]["sid"] != want.sid

    def test_recovery_none_is_default_and_inert(self):
        log = graphs.linear_network(24)
        peak, _ = measure_baseline(log)
        a = simulate(log, "h_dtr", 0.3 * peak, thrash_factor=50.0)
        b = simulate(log, "h_dtr", 0.3 * peak, thrash_factor=50.0,
                     recovery=None)
        assert run_to_dict(a) == run_to_dict(b)
        assert a.events == [] and a.degradations == 0


# ---------------------------------------------------------------------------
# Satellites: partial progress, error kinds, enriched diagnostics
# ---------------------------------------------------------------------------

class TestFailureReporting:
    def test_failed_run_records_partial_progress(self):
        log = load_trace("eager_mlp")
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.8, peak, log.pinned_bytes(), "activation")
        r = run_trace(log, "h_lru", budget, thrash_factor=1.5)[0]
        assert not r.ok and r.error_kind == "thrash"
        assert r.ops_executed > 0
        assert 0.0 < r.slowdown < float("inf")
        assert 0.0 < r.overhead < float("inf")
        d = run_to_dict(r)
        assert d["slowdown"] == r.slowdown     # finite -> survives to JSON

    def test_oom_error_kind_and_diagnostics(self):
        log = graphs.linear_network(16)
        peak, _ = measure_baseline(log)
        r = simulate(log, "h_dtr", 0.05 * peak, thrash_factor=50.0)
        assert not r.ok and r.error_kind == "oom"
        assert "resident=" in r.error and "pinned=" in r.error
        assert "top remats:" in r.error

    def test_thrash_error_diagnostics(self):
        log = load_trace("eager_mlp")
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.8, peak, log.pinned_bytes(), "activation")
        r = run_trace(log, "h_lru", budget, thrash_factor=1.5)[0]
        assert "thrash limit" in r.error and "degradations=" in r.error

    def test_faulted_failure_classified_as_fault(self):
        # A run that dies *with injected faults fired* is "unlucky", not
        # infeasible: squeeze the budget hard enough to kill a cell that
        # is feasible fault-free.
        log = load_trace("eager_mlp")
        peak, _ = measure_baseline(log)
        budget = resolve_budget(0.8, peak, log.pinned_bytes(), "activation")
        r = simulate(log, "h_lru", budget, thrash_factor=2.0,
                     faults=FaultConfig(seed=3, cost_noise=0.8),
                     recovery=RecoveryConfig(thrash_guard=False))
        if r.ok:
            pytest.skip("noise draw too gentle to kill the cell")
        assert r.error_kind == "fault"


# ---------------------------------------------------------------------------
# Satellite: worker death mid-sweep
# ---------------------------------------------------------------------------

def _lru_killer(payload):
    """Replacement _simulate_task: h_lru cells kill their worker."""
    if payload[2] == "h_lru":
        os._exit(17)
    from repro.core import simulator
    return _REAL_TASK(payload)


from repro.core.simulator import _simulate_task as _REAL_TASK  # noqa: E402


class TestWorkerDeath:
    def test_dead_worker_fails_only_its_cell(self, monkeypatch):
        if multiprocessing.get_start_method() != "fork":
            pytest.skip("needs fork start method to inherit the patched "
                        "task into pool workers")
        from repro.core import simulator
        monkeypatch.setattr(simulator, "_simulate_task", _lru_killer)
        log = graphs.linear_network(24)
        sweeps = simulator.sweep_parallel(
            log, ["h_dtr", "h_lru", "h_size"], [0.9, 0.5],
            processes=2, thrash_factor=50.0)
        by_h = {sw.heuristic: sw for sw in sweeps}
        assert all(r.ok for r in by_h["h_dtr"].runs)
        assert all(r.ok for r in by_h["h_size"].runs)
        for r in by_h["h_lru"].runs:
            assert not r.ok and r.error_kind == "worker"
            assert "died" in r.error
        # The surviving cells match an undisturbed serial sweep.
        serial = simulator.sweep_parallel(
            log, ["h_dtr"], [0.9, 0.5], processes=0, thrash_factor=50.0)
        assert ([run_to_dict(r) for r in by_h["h_dtr"].runs]
                == [run_to_dict(r) for r in serial[0].runs])


# ---------------------------------------------------------------------------
# Satellite: simultaneous device + host exhaustion (pool+host)
# ---------------------------------------------------------------------------

class TestSimultaneousExhaustion:
    def test_full_host_demotes_offload_to_plain_eviction(self):
        # Host tier sized for a handful of storages: once it fills, every
        # would-be offload deterministically becomes a plain eviction
        # (documented in OffloadEngine.wants_offload) — no evict-from-host
        # path, and the run either completes as pure DTR or dies with a
        # controlled OOM.
        log = graphs.random_dag(60, seed=3)
        peak, cost = measure_baseline(log)
        bw = 50.0 * peak / cost          # transfers always win the key
        sizes = sorted(
            {i.size for i in log.instrs if hasattr(i, "size")
             and getattr(i, "size", 0) > 0})
        host_cap = 3 * sizes[-1]         # room for ~3 largest storages
        cfg = OffloadConfig(host_budget=host_cap, h2d_bandwidth=bw,
                            d2h_bandwidth=bw)
        r1, r2, r3 = [
            simulate(log, "h_dtr_eq", 0.4 * peak, thrash_factor=50.0,
                     alloc_mode="pool+host", offload=cfg, index=idx)
            for idx in (True, True, False)]
        # Deterministic across runs AND engines (the documented path).
        for f in PARITY_FIELDS:
            assert getattr(r1, f) == getattr(r2, f), f
            assert getattr(r1, f) == getattr(r3, f), f
        # The host filled and pressure continued: evictions happened on
        # top of offloads even though transfers always price cheaper.
        assert r1.offloads > 0
        assert r1.evictions > 0
        if not r1.ok:
            assert r1.error_kind == "oom" and "resident=" in r1.error

    def test_exhaustion_with_nothing_evictable_is_controlled_oom(self):
        # Tiny device budget + tiny host: the first oversized allocation
        # finds both tiers exhausted and must raise the enriched OOM, not
        # hang or corrupt state.
        log = graphs.linear_network(16)
        peak, cost = measure_baseline(log)
        bw = 50.0 * peak / cost
        cfg = OffloadConfig(host_budget=0.02 * peak, h2d_bandwidth=bw,
                            d2h_bandwidth=bw)
        r = simulate(log, "h_dtr_eq", 0.05 * peak, thrash_factor=50.0,
                     alloc_mode="pool+host", offload=cfg)
        assert not r.ok and r.error_kind == "oom"
        assert "resident=" in r.error


# ---------------------------------------------------------------------------
# Eager-mode numerics under faults
# ---------------------------------------------------------------------------

class TestEagerNumerics:
    def _run_chain(self, faults=None, recovery=None):
        jnp = pytest.importorskip("jax.numpy")
        import numpy as np
        from repro.eager import DTRContext, op
        ctx = DTRContext(budget_bytes=3000, heuristic="h_dtr_eq",
                         use_wallclock_cost=False, faults=faults,
                         recovery=recovery)
        mul = op(ctx, "mul", jnp.multiply)
        add = op(ctx, "add", jnp.add)
        x = ctx.wrap(np.arange(64, dtype=np.float32).reshape(8, 8))
        ys = []
        h = x
        for i in range(12):
            h = add(mul(h, x), x)
            ys.append(h)
        outs = [np.asarray(y.value) for y in ys[-3:]]
        return outs, ctx.rt

    def test_recovered_run_matches_fault_free_numerics(self):
        import numpy as np
        clean, rt_clean = self._run_chain()
        cfg = FaultConfig(seed=5, alloc_rate=0.4, cost_noise=0.3)
        faulted, rt_f = self._run_chain(faults=cfg)
        assert rt_clean.evictions > 0          # pressure actually existed
        assert rt_f.faults.injected > 0        # faults actually fired
        for a, b in zip(clean, faulted):
            assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Eager-mode transfer faults: faulted H2D/D2H draws through the live
# executor must produce the same structured event stream the simulator
# emits replaying the recorded schedule
# ---------------------------------------------------------------------------

class TestEagerTransferFaults:
    """The eager executor and the trace simulator share one DTRRuntime, so
    a recorded eager program replayed under the same OffloadConfig /
    FaultConfig must take bit-identical transfer decisions — including the
    fault draws, which are keyed to the transfer sequence.  Every release
    the program performs is recorded, and the final fetch mirrors replay's
    output condition, so the two engines see identical pressure end to end.
    """

    BUDGET = 3000.0

    def _cfgs(self, faults):
        off = OffloadConfig(host_budget=float(1 << 20),
                            h2d_bandwidth=1024.0, d2h_bandwidth=1024.0,
                            policy="offload")
        f = FaultConfig(seed=21, transfer_rate=0.4, spike_rate=0.4) \
            if faults else None
        r = RecoveryConfig() if faults else None
        return off, f, r

    def _run_eager(self, faults=True):
        jnp = pytest.importorskip("jax.numpy")
        import numpy as np
        from repro.eager import DTRContext, op
        from repro.trace import TraceRecorder
        off, f, r = self._cfgs(faults)
        rec = TraceRecorder("eager_fault_chain")
        ctx = DTRContext(budget_bytes=self.BUDGET, heuristic="h_dtr_eq",
                         use_wallclock_cost=False, offload=off,
                         faults=f, recovery=r, recorder=rec)
        mul = op(ctx, "mul", jnp.multiply)
        add = op(ctx, "add", jnp.add)
        x = ctx.wrap(np.arange(64, dtype=np.float32).reshape(8, 8))
        h = x
        ys = []
        for _ in range(12):
            m = mul(h, x)
            h = add(m, x)
            ys.append((m, h))
        # Keeping every intermediate drives the working set past the
        # budget (pure-offload policy: victims go to host, not dropped);
        # the late use of iteration 0's output then fetches a
        # long-offloaded tensor back through the faulted h2d channel.
        h = add(h, ys[0][1])
        for m, y in ys:
            m.release()
            y.release()
        out = np.asarray(h.value)
        h.release()
        return ctx.rt, rec.finish(), out

    def test_eager_transfer_faults_match_simulator_events(self):
        rt, log, _ = self._run_eager()
        # Both channels actually drew faults through the live executor.
        assert rt.offloads > 0 and rt.fetches > 0
        kinds = {e["kind"] for e in rt.events}
        assert "transfer_spike" in kinds and "transfer_retry" in kinds
        assert {e["channel"] for e in rt.events} == {"d2h", "h2d"}
        off, f, r = self._cfgs(True)
        res, _ = run_trace(log, "h_dtr_eq", self.BUDGET,
                           offload=off, faults=f, recovery=r)
        assert res.ok
        assert res.events == rt.events          # the satellite's headline
        assert res.offloads == rt.offloads
        assert res.fetches == rt.fetches
        assert res.evictions == rt.evictions
        assert res.remat_ops == rt.remat_ops
        assert res.compute == rt.total_compute
        assert res.peak_memory == rt.peak_memory

    def test_eager_fault_schedule_is_deterministic(self):
        rt1, log1, out1 = self._run_eager()
        rt2, log2, out2 = self._run_eager()
        import numpy as np
        assert rt1.events == rt2.events
        assert log1.dumps() == log2.dumps()
        assert np.array_equal(out1, out2)

    def test_transfer_faults_never_corrupt_numerics(self):
        import numpy as np
        rt_f, _, faulted = self._run_eager(faults=True)
        rt_c, _, clean = self._run_eager(faults=False)
        assert len(rt_f.events) > 0 and len(rt_c.events) == 0
        # Same offload decisions (spikes cost time, not residency) and
        # bit-identical results.
        assert rt_f.offloads == rt_c.offloads
        assert np.array_equal(faulted, clean)


# ---------------------------------------------------------------------------
# Serve admission controller
# ---------------------------------------------------------------------------

class TestAdmission:
    def mk(self, budget=1000.0, per_tok=10.0, **kw):
        return AdmissionController(budget, per_tok, **kw)

    def test_plain_admit_within_budget(self):
        ac = self.mk()
        t = Ticket(0, prompt_len=10, gen=10)   # 200 bytes projected
        assert ac.decide(t, {}, 0) == (ADMIT, [])
        assert ac.counters()["admitted"] == 1

    def test_structurally_impossible_request_rejected(self):
        ac = self.mk(budget=100.0)
        t = Ticket(0, prompt_len=50, gen=50)   # 1000 bytes > capacity
        assert ac.decide(t, {}, 0) == (REJECT, [])
        assert ac.counters()["rejected"] == 1

    def test_preempts_cheapest_to_rematerialize(self):
        ac = self.mk(budget=450.0)
        a, b = Ticket(0, 10, 10), Ticket(1, 10, 10)    # 200 bytes each
        new = Ticket(2, 10, 10)
        # Slot 0 has replayed 15 tokens, slot 1 only 4: slot 1 is the
        # cheaper rematerialization and must be the victim.
        verdict, victims = ac.decide(new, {0: (a, 15), 1: (b, 4)}, 0)
        assert verdict == ADMIT and victims == [1]

    def test_victims_out_of_retries_are_spared(self):
        ac = self.mk(budget=450.0, max_retries=2)
        a = Ticket(0, 10, 10, retries=2)       # exhausted
        b = Ticket(1, 10, 10, retries=1)
        verdict, victims = ac.decide(Ticket(2, 10, 10),
                                     {0: (a, 1), 1: (b, 50)}, 0)
        assert verdict == ADMIT and victims == [1]   # despite higher key
        # Only exhausted tickets active and no room: nobody preemptable,
        # so the newcomer waits rather than tossing unretryable work.
        ac2 = self.mk(budget=250.0, max_retries=2)
        verdict, victims = ac2.decide(Ticket(3, 10, 10),
                                      {0: (a, 1)}, 0)
        assert verdict == WAIT and victims == []

    def test_requeue_backoff_doubles_and_caps(self):
        ac = self.mk(backoff_steps=4, backoff_cap=10)
        t = Ticket(0, 5, 5)
        ac.requeue(t, 100)
        assert (t.retries, t.eligible_step) == (1, 104)
        ac.requeue(t, 104)
        assert (t.retries, t.eligible_step) == (2, 112)
        ac.requeue(t, 112)
        assert t.eligible_step == 122          # 4*2**2=16 capped at 10
        assert ac.counters()["requeued"] == 3

    def test_backoff_blocks_until_eligible(self):
        ac = self.mk()
        t = Ticket(0, 5, 5, eligible_step=10)
        assert ac.decide(t, {}, 9) == (WAIT, [])
        assert ac.decide(t, {}, 10) == (ADMIT, [])

    def test_squeeze_makes_requests_wait_not_rejected(self):
        chaos = FaultSchedule(FaultConfig(budget_shrink=0.9,
                                          budget_period=10))
        ac = self.mk(budget=1000.0, faults=chaos)
        t = Ticket(0, 20, 20)                  # 400 bytes
        assert ac.decide(t, {}, 5) == (ADMIT, [])     # grace period
        ac2 = self.mk(budget=1000.0, faults=FaultSchedule(
            FaultConfig(budget_shrink=0.9, budget_period=10)))
        assert ac2.decide(Ticket(1, 20, 20), {}, 11) == (WAIT, [])
        assert ac2.counters()["rejected"] == 0

    def test_enforce_sheds_cheapest_until_under_budget(self):
        ac = self.mk(budget=1000.0)
        ac.kv_budget = 1000.0
        a, b, c = Ticket(0, 20, 20), Ticket(1, 20, 20), Ticket(2, 20, 20)
        active = {0: (a, 30), 1: (b, 2), 2: (c, 10)}   # 1200 used
        victims = ac.enforce(active, 0)
        assert victims == [1]                  # cheapest replay first
        ac.kv_budget = 500.0
        victims = ac.enforce(active, 0)
        assert victims == [1, 2]


# ---------------------------------------------------------------------------
# Prefetch loss -> sync-fetch fallback
# ---------------------------------------------------------------------------

class TestPrefetchLoss:
    def test_lost_prefetches_fall_back_to_sync_fetch(self):
        log = graphs.lstm(steps=24, width=8, batch=4)
        peak, cost = measure_baseline(log)
        bw = 8.0 * peak / cost
        off = OffloadConfig(host_budget=peak, h2d_bandwidth=bw,
                            d2h_bandwidth=bw, policy="offload",
                            prefetch=True)
        clean = simulate(log, "h_dtr_eq", 0.5 * peak, offload=off)
        lossy = simulate(log, "h_dtr_eq", 0.5 * peak, offload=off,
                         faults=FaultConfig(seed=1, prefetch_rate=1.0))
        assert clean.ok and lossy.ok
        assert clean.prefetch_hits > 0
        assert lossy.prefetch_hits == 0        # every prefetch was lost
        assert any(ev["kind"] == "prefetch_lost" for ev in lossy.events)
        # The accesses still happened, paying the synchronous transfer —
        # charged to the stall metric, never to recompute (pure offload
        # policy: downstream offload decisions legitimately diverge once
        # residency differs, so totals are compared within the run).
        assert lossy.fetches > 0 and lossy.stall_time > 0
        assert lossy.compute == lossy.base_compute
