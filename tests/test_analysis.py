"""Tests for the HLO analyzers (collective parse + loop-aware cost)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import parse_collectives
from repro.analysis.hlo_cost import analyze, parse_module
from repro.analysis.roofline import RooflineTerms, roofline


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestHloCost:
    def test_dot_flops_exact(self):
        a = jax.ShapeDtypeStruct((64, 128), np.float32)
        b = jax.ShapeDtypeStruct((128, 32), np.float32)
        txt = _compile(lambda x, y: x @ y, a, b)
        c = analyze(txt)
        # 2*M*N*K
        assert c.flops == pytest.approx(2 * 64 * 32 * 128, rel=0.05)

    def test_scan_trip_count_multiplies(self):
        a = jax.ShapeDtypeStruct((64, 64), np.float32)

        def f(x):
            def body(c, _):
                return c @ c, None
            out, _ = jax.lax.scan(body, x, None, length=10)
            return out

        c = analyze(_compile(f, a))
        one = 2 * 64 * 64 * 64
        assert c.flops == pytest.approx(10 * one, rel=0.2), c.flops

    def test_bytes_scale_with_tensor_size(self):
        small = jax.ShapeDtypeStruct((64, 64), np.float32)
        big = jax.ShapeDtypeStruct((512, 512), np.float32)
        f = lambda x: jnp.tanh(x) * 2 + 1
        cs = analyze(_compile(f, small))
        cb = analyze(_compile(f, big))
        assert cb.bytes_accessed > 30 * cs.bytes_accessed

    def test_parse_module_structure(self):
        a = jax.ShapeDtypeStruct((32, 32), np.float32)
        comps, entry = parse_module(_compile(lambda x: (x @ x).sum(), a))
        assert entry is not None
        assert entry in comps


class TestRoofline:
    def test_terms_and_dominant(self):
        rt = roofline({"flops": 197e12, "bytes accessed": 819e9},
                      coll_bytes=0, chips=1, model_flops=197e12)
        assert rt.compute_s == pytest.approx(1.0)
        assert rt.memory_s == pytest.approx(1.0)
        assert rt.dominant in ("compute", "memory")
        assert rt.roofline_frac == pytest.approx(1.0)

    def test_collective_dominates(self):
        rt = roofline({"flops": 1e12, "bytes accessed": 1e9},
                      coll_bytes=50e9 * 10, chips=4, model_flops=1e12)
        assert rt.dominant == "collective"
        assert rt.step_time_s == pytest.approx(10.0)


class TestCollectiveParse:
    def test_counts_and_bytes(self):
        txt = """
  %all-reduce.1 = f32[16,256]{1,0} all-reduce(%dot.1), channel_id=1
  %all-gather.2 = bf16[32,64]{1,0} all-gather(%p), dimensions={0}
  %all-gather-done.1 = bf16[32,64]{1,0} all-gather-done(%x)
"""
        st = parse_collectives(txt)
        assert st.count_by_kind["all-reduce"] == 1
        assert st.count_by_kind["all-gather"] == 1
        assert st.bytes_by_kind["all-reduce"] == 16 * 256 * 4
        assert st.bytes_by_kind["all-gather"] == 32 * 64 * 2
