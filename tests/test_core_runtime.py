"""Unit + property tests for the DTR core runtime (paper Appendix C semantics)."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis is optional: property tests skip, rest run
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core import graphs, simulator
from repro.core.graph import Log, LogBuilder, replay
from repro.core.heuristics import ALL_NAMES, HEStar, by_name, make_ablation
from repro.core.runtime import DTRRuntime, OOMError


def run(log: Log, budget: float, heuristic="h_dtr_eq", **kw) -> DTRRuntime:
    rt = DTRRuntime(budget=budget, heuristic=by_name(heuristic), **kw)
    replay(log, rt)
    return rt


# ---------------------------------------------------------------------------
# Basic engine behaviour
# ---------------------------------------------------------------------------

class TestBasics:
    def test_unconstrained_no_remat(self):
        log = graphs.mlp(depth=4)
        rt = run(log, budget=float("inf"))
        assert rt.remat_ops == 0
        assert rt.total_compute == rt.base_compute

    def test_budget_respected(self):
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        rt = run(log, budget=0.6 * peak)
        assert rt.peak_memory <= 0.6 * peak + 1e-6

    def test_remat_happens_under_pressure(self):
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        rt = run(log, budget=0.5 * peak)
        assert rt.evictions > 0
        assert rt.remat_ops > 0
        assert rt.total_compute > rt.base_compute

    def test_oom_below_feasible(self):
        log = graphs.mlp(depth=8)
        with pytest.raises(OOMError):
            run(log, budget=10.0)  # smaller than the constants alone

    def test_constants_never_evicted(self):
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        rt = run(log, budget=0.5 * peak)
        for s in rt.storages.values():
            if s.constant:
                assert s.resident or s.banished

    def test_output_condition(self):
        """Kept tensors (param grads) must be resident at the end."""
        log = graphs.mlp(depth=8)
        peak, _ = simulator.measure_baseline(log)
        rt = run(log, budget=0.5 * peak)
        for t in rt.tensors.values():
            if t.refs > 0:
                assert t.defined, f"{t.name} not resident at end"

    def test_get_rematerializes(self):
        rt = DTRRuntime(budget=100, heuristic=by_name("h_lru"))
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [40])
        (b,) = rt.call("g", 1.0, [a], [40])
        # Force eviction of a by allocating beyond budget.
        (d,) = rt.call("h", 1.0, [b], [40])
        evicted = [s for s in rt.storages.values()
                   if not s.resident and not s.banished]
        assert evicted, "expected an eviction"
        target = rt.tensors[a]
        if not target.defined:
            rt.get(a)
        assert rt.tensors[a].defined


class TestAliasesAndMutation:
    def test_alias_shares_storage(self):
        rt = DTRRuntime(budget=1000, heuristic=by_name("h_lru"))
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [40])
        (v,) = rt.call("view", 0.1, [a], [0], aliases=[a])
        assert rt.tensors[v].sid == rt.tensors[a].sid
        assert rt.size_of(v) == 0
        # Storage local cost accumulates the view op cost.
        assert rt.storages[rt.tensors[a].sid].local_cost == pytest.approx(1.1)

    def test_alias_evicted_with_storage(self):
        rt = DTRRuntime(budget=95, heuristic=by_name("h_size"))
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [40])
        (v,) = rt.call("view", 0.1, [a], [0], aliases=[a])
        (b,) = rt.call("g", 1.0, [c], [40])  # pressure: evicts a's storage
        s = rt.storages[rt.tensors[a].sid]
        if not s.resident:
            assert not rt.tensors[v].defined
            rt.get(v)  # remat: root then view
            assert rt.tensors[v].defined

    def test_mutation_rewrite(self):
        b = LogBuilder("mut")
        x = b.constant(16, name="x")
        (y,) = b.call([x], [16], 1.0, "f")
        b.mutate([y], [y], 1.0, "add_")
        (z,) = b.call([y], [16], 1.0, "g")
        log = b.auto_release(keep=[z])
        rt = DTRRuntime(budget=1000, heuristic=by_name("h_lru"))
        env = replay(log, rt)
        # y now maps to the post-mutation (copy-on-write) tensor.
        assert rt.tensors[env[y]].name == y + "'"
        assert rt.tensors[env[z]].defined


class TestDeallocPolicies:
    @pytest.mark.parametrize("policy", ["ignore", "eager", "banish"])
    def test_policies_complete(self, policy):
        log = graphs.resnet(blocks=6)
        peak, _ = simulator.measure_baseline(log)
        rt = DTRRuntime(budget=0.7 * peak, heuristic=by_name("h_dtr"),
                        dealloc=policy)
        replay(log, rt)
        assert rt.slowdown() >= 1.0

    def test_eager_eviction_fires(self):
        log = graphs.mlp(depth=6)
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_lru"),
                        dealloc="eager")
        replay(log, rt)
        assert rt.evictions > 0  # releases triggered evictions

    def test_banish_frees_permanently(self):
        rt = DTRRuntime(budget=1000, heuristic=by_name("h_lru"),
                        dealloc="banish")
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [40])
        (b,) = rt.call("g", 1.0, [a], [40])
        rt.release(a)  # no evicted dependents -> banished
        s = rt.storages[rt.tensors[a].sid]
        assert s.banished
        # Child of banished storage is pinned (non-rematerializable).
        assert rt.storages[rt.tensors[b].sid].pinned

    def test_banish_deferred_with_evicted_dependents(self):
        rt = DTRRuntime(budget=90, heuristic=by_name("h_lru"),
                        dealloc="banish")
        c = rt.constant(10)
        (a,) = rt.call("f", 1.0, [c], [40])
        (b,) = rt.call("g", 1.0, [a], [40])
        (d,) = rt.call("h", 1.0, [b], [40])  # evicts a or b
        sb = rt.storages[rt.tensors[b].sid]
        if not sb.resident:
            rt.release(b)
            assert not sb.banished  # cannot banish... wait, b itself evicted
        # Release a while b evicted: a has evicted dependent -> deferred.
        sa = rt.storages[rt.tensors[a].sid]
        if sb is not sa and not sb.resident and sa.resident:
            rt.release(a)
            assert not sa.banished
            rt.get(b)  # remat b -> retry banish of a
            assert sa.banished


class TestHeuristics:
    @pytest.mark.parametrize("h", ALL_NAMES)
    def test_all_heuristics_run(self, h):
        log = graphs.transformer(layers=2, d=8, seq=4)
        peak, _ = simulator.measure_baseline(log)
        r = simulator.simulate(log, by_name(h), budget=0.7 * peak)
        assert r.ok
        assert r.slowdown >= 1.0

    def test_dtr_beats_lru_on_low_budget(self):
        """Chain-aware heuristics support budgets where LRU thrashes/OOMs
        (the paper's central empirical claim)."""
        log = graphs.lstm(steps=24)
        peak, _ = simulator.measure_baseline(log)
        frac = 0.4
        r_dtr = simulator.simulate(log, by_name("h_dtr"), budget=frac * peak)
        r_lru = simulator.simulate(log, by_name("h_lru"), budget=frac * peak)
        assert r_dtr.ok
        assert (not r_lru.ok) or r_lru.slowdown >= r_dtr.slowdown

    def test_eq_approximates_full(self):
        """h_DTR^eq stays close to h_DTR (paper Fig. 2 finding)."""
        log = graphs.transformer(layers=4, d=16, seq=8)
        peak, _ = simulator.measure_baseline(log)
        for frac in (0.7, 0.5):
            r_full = simulator.simulate(log, by_name("h_dtr"),
                                        budget=frac * peak)
            r_eq = simulator.simulate(log, by_name("h_dtr_eq"),
                                      budget=frac * peak)
            if r_full.ok and r_eq.ok:
                assert r_eq.slowdown <= r_full.slowdown * 1.5 + 0.1

    def test_eq_fewer_metadata_accesses(self):
        """ẽ* requires far fewer metadata accesses than exact e* (App. D.3)."""
        log = graphs.treelstm(depth=5)
        peak, _ = simulator.measure_baseline(log)
        r_full = simulator.simulate(log, by_name("h_dtr"), budget=0.5 * peak)
        r_eq = simulator.simulate(log, by_name("h_dtr_eq"), budget=0.5 * peak)
        r_local = simulator.simulate(log, by_name("h_dtr_local"),
                                     budget=0.5 * peak)
        assert r_full.ok and r_eq.ok
        assert r_eq.meta_accesses < r_full.meta_accesses
        if r_local.ok:
            assert r_local.meta_accesses < r_eq.meta_accesses

    def test_ablation_grid_instantiates(self):
        log = graphs.mlp(depth=4)
        peak, _ = simulator.measure_baseline(log)
        for stale in (True, False):
            for mem in (True, False):
                for cost in ("estar", "eq", "local", "no"):
                    h = make_ablation(stale, mem, cost)
                    r = simulator.simulate(log, h, budget=0.8 * peak)
                    assert r.ok, h.name

    def test_sampling_and_small_filters(self):
        log = graphs.resnet(blocks=8)
        peak, _ = simulator.measure_baseline(log)
        r = simulator.simulate(log, by_name("h_dtr_eq"), budget=0.6 * peak,
                               ignore_small_frac=0.01, sample_sqrt=True)
        assert r.ok


# ---------------------------------------------------------------------------
# Formal bounds (Sec. 3)
# ---------------------------------------------------------------------------

class TestTheorems:
    @pytest.mark.parametrize("n", [100, 400, 900])
    def test_thm31_linear_ops_within_constant_factor(self, n):
        """DTR with h_e* and B = 2⌈√N⌉ executes O(N) ops (Thm 3.1)."""
        log = graphs.linear_network(n)
        b = 2 * math.ceil(math.sqrt(n))
        rt = DTRRuntime(budget=b, heuristic=HEStar())
        replay(log, rt)
        # 2N base ops (fwd+bwd); overhead must be a constant factor.
        assert rt.ops_executed <= 6 * n, (
            f"N={n}: {rt.ops_executed} ops exceeds 6N")

    def test_thm31_scaling_is_linear(self):
        """ops/N should not grow with N (constant-factor check)."""
        ratios = []
        for n in (200, 800, 1800):
            log = graphs.linear_network(n)
            b = 2 * math.ceil(math.sqrt(n))
            rt = DTRRuntime(budget=b, heuristic=HEStar())
            replay(log, rt)
            ratios.append(rt.ops_executed / n)
        assert ratios[-1] <= ratios[0] * 1.5 + 0.5

    def test_thm32_adversarial_blowup(self):
        """The adversary forces superlinear work (Thm 3.2)."""
        n, b = 240, 8
        rt = DTRRuntime(budget=b + 1, heuristic=by_name("h_lru"))
        ops = graphs.AdversarialDriver(n, b).run(rt)
        # Theoretical lower bound ~ N^2/(4B); check clear superlinearity.
        assert ops > 3 * n


# ---------------------------------------------------------------------------
# Property tests: random DAGs
# ---------------------------------------------------------------------------

class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), n_ops=st.integers(5, 40),
           frac=st.floats(0.4, 1.0))
    def test_random_dag_invariants(self, seed, n_ops, frac):
        """For any DAG/budget: if the run completes, (1) peak memory within
        budget, (2) compute >= baseline, (3) kept tensors resident,
        (4) constants never evicted."""
        log = graphs.random_dag(n_ops, seed=seed)
        peak, base_cost = simulator.measure_baseline(log)
        rt = DTRRuntime(budget=frac * peak, heuristic=by_name("h_dtr_eq"))
        try:
            replay(log, rt)
        except OOMError:
            return  # infeasible budget is a legal outcome
        assert rt.peak_memory <= frac * peak + 1e-6
        assert rt.total_compute >= base_cost - 1e-6
        for t in rt.tensors.values():
            if t.refs > 0:
                assert t.defined
        for s in rt.storages.values():
            if s.constant and not s.banished:
                assert s.resident

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), n_ops=st.integers(5, 30))
    def test_unconstrained_matches_baseline(self, seed, n_ops):
        """With infinite budget and 'ignore' dealloc, no op ever re-runs."""
        log = graphs.random_dag(n_ops, seed=seed)
        rt = DTRRuntime(budget=float("inf"), heuristic=by_name("h_lru"),
                        dealloc="ignore")
        replay(log, rt)
        assert rt.remat_ops == 0

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), frac=st.floats(0.3, 0.9))
    def test_heuristics_agree_on_feasibility_ordering(self, seed, frac):
        """All heuristics complete or OOM; compute is finite when ok."""
        log = graphs.random_dag(25, seed=seed)
        peak, _ = simulator.measure_baseline(log)
        for h in ("h_dtr_eq", "h_lru", "h_size"):
            r = simulator.simulate(log, by_name(h), budget=frac * peak)
            if r.ok:
                assert math.isfinite(r.slowdown)


# ---------------------------------------------------------------------------
# Log serialization round-trip
# ---------------------------------------------------------------------------

def test_log_roundtrip():
    log = graphs.transformer(layers=2, d=8, seq=4)
    text = log.dumps()
    log2 = Log.loads(text, name=log.name)
    assert len(log2) == len(log)
    r1 = simulator.simulate(log, by_name("h_dtr_eq"), budget=float("inf"))
    r2 = simulator.simulate(log2, by_name("h_dtr_eq"), budget=float("inf"))
    assert r1.compute == r2.compute
