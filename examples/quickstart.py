"""Quickstart: DTR in three layers, five minutes, one CPU.

  1. simulate the paper's algorithm on a model graph (core),
  2. run a *real* computation under a byte budget with live eviction (eager),
  3. train a small transformer with a DTR-planned jax.checkpoint policy
     (planner — the TPU-native form).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import graphs, simulator
from repro.core.heuristics import by_name
from repro.eager import DTRContext
from repro import configs
from repro.models import model as M
from repro.optim import adamw, apply_updates, clip_by_global_norm


def part1_simulate():
    print("== 1. simulated DTR on a transformer graph ==")
    log = graphs.transformer(layers=6, d=32, seq=16)
    peak, base = simulator.measure_baseline(log)
    for frac in (0.8, 0.5, 0.3):
        r = simulator.simulate(log, by_name("h_dtr_eq"), budget=frac * peak)
        status = f"slowdown {r.slowdown:.2f}x" if r.ok else "OOM"
        print(f"   budget {frac:.0%} of peak -> {status} "
              f"({r.evictions} evictions, {r.remat_ops} remats)")


def part2_eager():
    print("== 2. eager DTR: real buffers, real evictions ==")
    n = 64 * 1024 // 4
    budget = 6 * 64 * 1024
    ctx = DTRContext(budget_bytes=budget)
    x = ctx.wrap(jnp.linspace(0, 1, n))
    vals = [x]
    for i in range(24):
        vals.append(ctx.call(f"f{i}", lambda a: jnp.cos(a) * 1.01,
                             [vals[-1]])[0])
    print(f"   built 24-op chain under {budget//1024} KiB budget: "
          f"{ctx.rt.evictions} evictions")
    _ = vals[3].value   # early value: triggers rematerialization
    print(f"   accessed evicted intermediate -> {ctx.remat_runs} remat runs, "
          f"value correct: {bool(jnp.isfinite(_).all())}")


def part3_planned_training():
    print("== 3. DTR-planned remat policy on a real train step ==")
    cfg = configs.get_smoke("llama3_2_1b").replace(remat="dtr")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt = adamw(lr=1e-3)
    state = opt.init(params)
    tokens = jax.random.randint(key, (4, 64), 0, cfg.vocab)

    @jax.jit
    def step(params, state, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, {"tokens": tokens}))(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss

    for i in range(10):
        params, state, loss = step(params, state, tokens)
        if i % 3 == 0:
            print(f"   step {i}: loss {float(loss):.4f}")
    print("   (layer stack runs under jax.checkpoint with the DTR policy)")


if __name__ == "__main__":
    part1_simulate()
    part2_eager()
    part3_planned_training()
