"""Batched decode serving: prefill + KV-cache decode loop with batching.

Serves a smoke-sized LM: requests arrive with prompts, get batched, prefilled
(full forward populates nothing here — decode replays the prompt token by
token to fill the cache, which is exact for these lengths), then decoded
greedily for N tokens per request.  The serve step is the same function the
dry-run lowers at decode_32k/long_500k scale.

  PYTHONPATH=src python examples/serve.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.steps import make_serve_step
from repro.models import model as M


def main():
    cfg = configs.get_smoke("llama3_2_1b")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)

    batch, max_len, gen_len = 4, 96, 24
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    # --- batched requests (different prompt lengths, left-aligned) ---
    rng = np.random.default_rng(0)
    prompt_lens = [8, 12, 5, 9]
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in prompt_lens]

    cache = M.init_cache(cfg, batch, max_len)
    # Prefill by stepping the prompts through the decode path (batched;
    # shorter prompts pad with token 0 and get overwritten by generation).
    maxp = max(prompt_lens)
    padded = np.zeros((batch, maxp), np.int32)
    for i, p in enumerate(prompts):
        padded[i, :len(p)] = p

    t0 = time.perf_counter()
    tok = jnp.asarray(padded[:, :1])
    out_tokens = [[] for _ in range(batch)]
    for pos in range(maxp + gen_len - 1):
        nxt, cache = serve(params, cache, tok, jnp.int32(pos))
        if pos + 1 < maxp:
            # still consuming prompts: teacher-force next prompt column
            tok = jnp.asarray(padded[:, pos + 1:pos + 2])
        else:
            tok = nxt[:, :, 0] if cfg.n_codebooks else nxt
            for i in range(batch):
                out_tokens[i].append(int(np.asarray(tok)[i, 0]))
    dt = time.perf_counter() - t0

    total_steps = maxp + gen_len - 1
    print(f"served {batch} requests, {total_steps} decode steps in "
          f"{dt:.2f}s ({dt/total_steps*1e3:.1f} ms/step batched)")
    for i in range(batch):
        print(f"req{i} (prompt {prompt_lens[i]} toks) -> "
              f"{out_tokens[i][:12]}...")


if __name__ == "__main__":
    main()
