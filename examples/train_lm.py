"""End-to-end training driver: data pipeline -> model -> optimizer ->
checkpointing -> fault tolerance, with the DTR remat policy as a first-class
config knob.

Default run trains a ~20M-param llama-family model for 300 steps on CPU
(minutes); ``--arch smollm-135m --full`` trains the real 135M config (the
~100M-class run; slower on CPU, the step function is identical).  Resuming
after an interruption is exercised by just re-running the command — the
checkpoint manager restores the latest step and the data pipeline seeks its
cursor.

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --full \
      --steps 120 --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.distributed.monitor import DivergenceGuard, StragglerMonitor, Timer
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--full", action="store_true",
                    help="use the full config instead of the smoke config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--remat", default="dtr")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = (configs.get(args.arch) if args.full
           else configs.get_smoke(args.arch))
    # ~20M-class default: widen the smoke config a little.
    if not args.full:
        cfg = cfg.replace(n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                          head_dim=32, d_ff=1024, vocab=8192)
    cfg = cfg.replace(remat=args.remat, dtype="float32")
    n_params_analytic = cfg.param_count()
    print(f"arch={cfg.name} params~{n_params_analytic/1e6:.1f}M "
          f"remat={cfg.remat}")

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"materialized params: {n_params/1e6:.1f}M")

    opt = adamw(lr=cosine_schedule(3e-4, warmup=20, total=args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                       n_codebooks=cfg.n_codebooks)
    ckpt = CheckpointManager(args.ckpt_dir, every_steps=args.ckpt_every,
                             keep=2)
    monitor = StragglerMonitor()
    guard = DivergenceGuard()

    # ---- resume (fault tolerance) ----
    start, restored, extra = ckpt.restore({"params": params,
                                           "opt": opt_state})
    if start is not None:
        params, opt_state = restored["params"], restored["opt"]
        start += 1
        print(f"resumed from checkpoint at step {start - 1}")
    else:
        start = 0

    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        with Timer() as t:
            new_params, new_opt, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
        loss = float(metrics["loss"])
        gn = float(metrics["grad_norm"])
        action = guard.check(loss, gn)
        if action == "skip":
            print(f"step {step}: DIVERGENCE ({loss=:.3g} {gn=:.3g}) — "
                  f"update skipped")
            continue
        if action == "restore":
            s, restored, _ = ckpt.restore({"params": params,
                                           "opt": opt_state})
            if s is not None:
                params, opt_state = restored["params"], restored["opt"]
                print(f"step {step}: restored checkpoint from step {s}")
            continue
        params, opt_state = new_params, new_opt
        st = monitor.record(step, t.seconds, loss, gn)
        losses.append(loss)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"grad_norm {gn:.3f}  {t.seconds*1e3:.0f}ms"
                  + ("  [straggler]" if st.flagged else ""))
        ckpt.maybe_save(step, {"params": params, "opt": opt_state},
                        extra={"data_step": step})

    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first else 'NOT LEARNING'})")
    print(f"step-time ewma {monitor.ewma*1e3:.0f}ms; "
          f"{sum(s.flagged for s in monitor.history)} straggler flags")


if __name__ == "__main__":
    main()
