"""The paper's headline dynamic model: TreeLSTM under a restricted budget.

Data-dependent tree shapes mean NO static planner can precompute a schedule —
every example is a different computation graph.  The eager DTR executor
handles it exactly like the paper's PyTorch prototype: op interposition +
live eviction + recursive rematerialization.

Training is full backprop, done *through DTR*: every backward op is also
dispatched via the context, and the backward pass touches forward activations
that were evicted under the byte budget — triggering exactly the recursive
rematerializations the paper describes.

  PYTHONPATH=src python examples/dynamic_treelstm.py
"""
from __future__ import annotations

import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.eager import DTRContext, DTRArray

DIM = 96


def random_tree(rng, depth):
    if depth == 0 or rng.random() < 0.25:
        return ("leaf", rng.uniform(-1, 1))
    return ("node", random_tree(rng, depth - 1), random_tree(rng, depth - 1))


def tree_size(t):
    return 1 if t[0] == "leaf" else 1 + tree_size(t[1]) + tree_size(t[2])


def tree_sum(t):
    return t[1] if t[0] == "leaf" else tree_sum(t[1]) + tree_sum(t[2])


class TreeNet:
    """h(node) = tanh(h_l @ W_l + h_r @ W_r); h(leaf) = v * w_leaf."""

    def __init__(self, ctx: DTRContext, key):
        ks = jax.random.split(key, 3)
        s = 1.0 / np.sqrt(DIM)
        self.ctx = ctx
        self.w = {
            "leaf": ctx.wrap(jax.random.normal(ks[0], (1, DIM)) * s, name="w_leaf"),
            "l": ctx.wrap(jax.random.normal(ks[1], (DIM, DIM)) * s, name="w_l"),
            "r": ctx.wrap(jax.random.normal(ks[2], (DIM, DIM)) * s, name="w_r"),
            "out": ctx.wrap(jnp.ones((DIM, 1)) * s, name="w_out"),
        }

    # ---- forward: records (kind, inputs, outputs) trace for backward ----
    def encode(self, tree, trace) -> DTRArray:
        ctx = self.ctx
        if tree[0] == "leaf":
            x = ctx.wrap(jnp.full((1, 1), tree[1]), name="leafval")
            h = ctx.call("embed", jnp.matmul, [x, self.w["leaf"]])[0]
            trace.append(("leaf", x, h))
            return h
        hl = self.encode(tree[1], trace)
        hr = self.encode(tree[2], trace)
        a = ctx.call("mm_l", jnp.matmul, [hl, self.w["l"]])[0]
        b = ctx.call("mm_r", jnp.matmul, [hr, self.w["r"]])[0]
        s = ctx.call("add", jnp.add, [a, b])[0]
        h = ctx.call("tanh", jnp.tanh, [s])[0]
        trace.append(("node", hl, hr, s, h))
        return h

    # ---- backward: every vjp op goes through DTR too ----
    def backward(self, trace, root_grad, grads):
        ctx = self.ctx
        gmap = {}  # tid -> grad DTRArray

        def add_grad(arr, g):
            if arr.tid in gmap:
                gmap[arr.tid] = ctx.call("gacc", jnp.add,
                                         [gmap[arr.tid], g])[0]
            else:
                gmap[arr.tid] = g

        last_h = trace[-1][-1]
        add_grad(last_h, root_grad)
        for rec in reversed(trace):
            if rec[0] == "node":
                _, hl, hr, s, h = rec
                gh = gmap.pop(h.tid, None)
                if gh is None:
                    continue
                # d tanh: gs = gh * (1 - h^2)   (uses forward h -> remat!)
                gs = ctx.call("d_tanh", lambda g, hh: g * (1 - hh * hh),
                              [gh, h])[0]
                add_grad(hl, ctx.call("d_mm_l_x", lambda g, w: g @ w.T,
                                      [gs, self.w["l"]])[0])
                add_grad(hr, ctx.call("d_mm_r_x", lambda g, w: g @ w.T,
                                      [gs, self.w["r"]])[0])
                # weight grads use forward activations hl/hr (remat!)
                gwl = ctx.call("d_w_l", lambda hh, g: hh.T @ g, [hl, gs])[0]
                gwr = ctx.call("d_w_r", lambda hh, g: hh.T @ g, [hr, gs])[0]
                grads["l"] = (gwl if grads["l"] is None else
                              ctx.call("acc_wl", jnp.add,
                                       [grads["l"], gwl])[0])
                grads["r"] = (gwr if grads["r"] is None else
                              ctx.call("acc_wr", jnp.add,
                                       [grads["r"], gwr])[0])
            else:
                _, x, h = rec
                gh = gmap.pop(h.tid, None)
                if gh is None:
                    continue
                gwleaf = ctx.call("d_w_leaf", lambda xx, g: xx.T @ g,
                                  [x, gh])[0]
                grads["leaf"] = (gwleaf if grads["leaf"] is None else
                                 ctx.call("acc_wleaf", jnp.add,
                                          [grads["leaf"], gwleaf])[0])


def main():
    rng = random.Random(0)
    key = jax.random.PRNGKey(0)
    # Budget: 3 weights + 3 weight-grads + 2 working DIM² buffers + ~64
    # activation vectors.  Trees reach ~90 nodes × 4-5 tensors each, so the
    # forward activations cannot all stay resident -> forced evictions.
    budget = (8 * DIM * DIM + 64 * DIM) * 4
    # dealloc="banish": released *constants* (old weight versions, leaf
    # values) are permanently freed — the paper notes banishing is the only
    # way to free constants (Sec. 2 Deallocation).
    ctx = DTRContext(budget_bytes=budget, dealloc="banish")
    net = TreeNet(ctx, key)

    # Track per-step arrays so they can be released at step end (framework
    # refcounting -> eager eviction; keeps the op graph from growing across
    # steps).  Weight updates happen OUTSIDE DTR, per the paper's App. C.6
    # ("the weight update step outside of DTR immediately after backward").
    step_arrays: list[DTRArray] = []
    orig_call = ctx.call
    orig_wrap = ctx.wrap

    def tracked_call(name, fn, args, n_outputs=None):
        outs = orig_call(name, fn, args, n_outputs)
        step_arrays.extend(outs)
        return outs

    def tracked_wrap(x, constant=True, name="const"):
        arr = orig_wrap(x, constant=constant, name=name)
        if name == "leafval":
            step_arrays.append(arr)
        return arr

    ctx.call = tracked_call
    ctx.wrap = tracked_wrap

    lr = 0.015
    losses = []
    for step in range(60):
        tree = random_tree(rng, depth=5)
        target = np.tanh(tree_sum(tree) * 0.15)
        trace = []
        h = net.encode(tree, trace)
        pred = ctx.call("out", jnp.matmul, [h, net.w["out"]])[0]
        err = float(pred.value[0, 0]) - target
        losses.append(0.5 * err * err)

        # backprop (through DTR)
        grads = {"leaf": None, "l": None, "r": None}
        gh = ctx.call("d_out", lambda w: (err * w).T, [net.w["out"]])[0]
        g_wout = ctx.call("d_wout", lambda hh: err * hh.T, [h])[0]
        net.backward(trace, gh, grads)

        # SGD updates OUTSIDE DTR (concrete values -> fresh constants);
        # cuts the cross-step remat chain exactly as the paper prescribes.
        for k in ("leaf", "l", "r"):
            if grads[k] is not None:
                new_val = ctx.fetch(net.w[k]) - lr * ctx.fetch(grads[k])
                net.w[k].release()
                net.w[k] = ctx.wrap(new_val, name=f"w_{k}")
        new_out_val = ctx.fetch(net.w["out"]) - lr * ctx.fetch(g_wout)
        net.w["out"].release()
        net.w["out"] = ctx.wrap(new_out_val, name="w_out")

        # Release everything this step created (refcount -> eager eviction).
        for arr in step_arrays:
            arr.release()
        step_arrays.clear()

        if step % 8 == 0:
            print(f"step {step:3d} nodes={tree_size(tree):3d} "
                  f"loss={losses[-1]:.4f} evictions={ctx.rt.evictions} "
                  f"remat_runs={ctx.remat_runs}")

    first, last = np.mean(losses[:15]), np.mean(losses[-15:])
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first else 'noisy single-tree SGD'})")
    print(f"total evictions {ctx.rt.evictions}, remat runs {ctx.remat_runs}")
    assert ctx.remat_runs > 0, "budget never forced rematerialization?"


if __name__ == "__main__":
    main()
